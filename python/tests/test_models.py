"""L2 correctness: model shapes, loss behaviour, SGD-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import model as M

VISION = ["lenet5", "resnetlite", "alexnetlite"]


def _batch(name, b, rng):
    spec = L.MODELS[name]
    if name == "tinytransformer":
        x = rng.integers(0, L.TT_VOCAB, (b, L.TT_SEQ)).astype(np.int32)
        y = np.zeros(b, np.int32)
    else:
        h, w, c = spec["input_shape"]
        x = rng.standard_normal((b, h, w, c)).astype(np.float32)
        y = rng.integers(0, spec["classes"], b).astype(np.int32)
    return x, y


@pytest.mark.parametrize("name", list(L.MODELS))
def test_layer_tables_consistent(name):
    table = L.MODELS[name]["layers"]()
    names = [l.name for l in table]
    assert len(names) == len(set(names)), "duplicate layer names"
    for l in table:
        if l.compressible:
            assert l.size % l.fan_in == 0, f"{l.name}: fan_in does not divide size"


@pytest.mark.parametrize("name", list(L.MODELS))
def test_logits_shape(name):
    params = M.init_params(name, 0)
    rng = np.random.default_rng(0)
    x, _ = _batch(name, 2, rng)
    logits = M.LOGITS[name](params, jnp.asarray(x))
    if name == "tinytransformer":
        assert logits.shape == (2, L.TT_SEQ, L.TT_VOCAB)
    else:
        assert logits.shape == (2, L.MODELS[name]["classes"])
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", VISION)
def test_initial_loss_near_uniform(name):
    """Softmax CE at init should be near ln(classes) — catches init blowups."""
    params = M.init_params(name, 1)
    rng = np.random.default_rng(1)
    x, y = _batch(name, 8, rng)
    loss = float(M.loss_fn(name, params, jnp.asarray(x), jnp.asarray(y)))
    import math

    expect = math.log(L.MODELS[name]["classes"])
    assert loss < 6 * expect, f"{name}: initial loss {loss} vs ln(C) {expect}"


@pytest.mark.parametrize("name", ["lenet5", "tinytransformer"])
def test_train_step_decreases_loss(name):
    params = M.init_params(name, 2)
    rng = np.random.default_rng(2)
    x, y = _batch(name, L.MODELS[name]["batch"], rng)
    step = jax.jit(M.make_train_step(name))
    lr = jnp.float32(0.05)
    out = step(*params, x, y, lr)
    loss0 = float(out[0])
    params = list(out[1:])
    for _ in range(5):
        out = step(*params, x, y, lr)
        params = list(out[1:])
    loss1 = float(out[0])
    assert loss1 < loss0, f"{name}: {loss0} -> {loss1}"


def test_train_step_is_sgd():
    """new_params must equal params - lr * grads exactly."""
    name = "lenet5"
    params = M.init_params(name, 3)
    rng = np.random.default_rng(3)
    x, y = _batch(name, 32, rng)
    lr = jnp.float32(0.1)
    tout = jax.jit(M.make_train_step(name))(*params, x, y, lr)
    gout = jax.jit(M.make_grad_step(name))(*params, x, y)
    assert abs(float(tout[0]) - float(gout[0])) < 1e-6
    for p, np_, g in zip(params, tout[1:], gout[1:]):
        np.testing.assert_allclose(
            np.asarray(np_), np.asarray(p) - 0.1 * np.asarray(g), rtol=1e-5, atol=1e-6
        )


def test_eval_step_counts():
    name = "lenet5"
    params = M.init_params(name, 4)
    rng = np.random.default_rng(4)
    x, y = _batch(name, 64, rng)
    loss_sum, correct = jax.jit(M.make_eval_step(name))(*params, x, y)
    assert 0 <= float(correct) <= 64
    # Mean loss from sum must match loss_fn.
    mean = float(M.loss_fn(name, params, jnp.asarray(x), jnp.asarray(y)))
    assert abs(float(loss_sum) / 64 - mean) < 1e-4


def test_grad_step_unused_labels_for_transformer():
    """The transformer ignores y; grads must not depend on it."""
    name = "tinytransformer"
    params = M.init_params(name, 5)
    rng = np.random.default_rng(5)
    x, _ = _batch(name, 4, rng)
    g1 = jax.jit(M.make_grad_step(name))(*params, x, np.zeros(4, np.int32))
    g2 = jax.jit(M.make_grad_step(name))(*params, x, np.ones(4, np.int32))
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]))
