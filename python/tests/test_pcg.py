"""Cross-language RNG contract: python PCG64-DXSM mirrors the Rust core.

The vectors below were captured from ``rust/src/util/rng.rs``
(test `deterministic` extended); the property asserted here is exact
integer equality, which transfers because both sides use only integer
arithmetic. `rust/tests/properties.rs` holds the Rust half of the
contract implicitly via every seeded test in the crate.
"""

from compile.pcg import Pcg64


def test_streams_differ_and_are_deterministic():
    a = Pcg64(42, 7)
    b = Pcg64(42, 7)
    seq_a = [a.next_u64() for _ in range(64)]
    seq_b = [b.next_u64() for _ in range(64)]
    assert seq_a == seq_b
    c = Pcg64(42, 8)
    seq_c = [c.next_u64() for _ in range(64)]
    assert all(x != y for x, y in zip(seq_a, seq_c))


def test_outputs_are_64_bit():
    r = Pcg64(1, 0)
    for _ in range(1000):
        v = r.next_u64()
        assert 0 <= v < 2**64


def test_f32_in_unit_interval_with_24bit_grid():
    r = Pcg64(3, 0)
    for _ in range(1000):
        x = r.f32()
        assert 0.0 <= x < 1.0
        # exact dyadic rational on the 2^-24 grid
        assert (x * 16777216.0) == int(x * 16777216.0)


def test_fork_decorrelates():
    root = Pcg64(1, 0)
    c1 = root.fork(1)
    c2 = root.fork(2)
    s1 = [c1.next_u64() for _ in range(64)]
    s2 = [c2.next_u64() for _ in range(64)]
    assert all(x != y for x, y in zip(s1, s2))


def test_matches_rust_vectors():
    """First outputs of Pcg64::new(42, 7) captured from the Rust build.

    If this fails after touching either implementation, the cross-language
    reproducibility contract is broken — fix the implementation, do NOT
    re-capture blindly.
    """
    import json
    import os

    vec_path = os.path.join(os.path.dirname(__file__), "pcg_vectors.json")
    with open(vec_path) as f:
        vectors = json.load(f)
    for case in vectors:
        r = Pcg64(case["seed"], case["stream"])
        got = [r.next_u64() for _ in range(len(case["out"]))]
        assert got == case["out"], f"seed={case['seed']} stream={case['stream']}"
