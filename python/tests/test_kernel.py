"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; numerical agreement is asserted with
``assert_allclose``. These are the kernels the Rust coordinator executes
through the AOT artifacts, so this is the root of the correctness chain.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.projection import pick_block_cols, project
from compile.kernels.rangefinder import project_b, sketch
from compile.kernels.reconstruct import reconstruct


def _ortho(rng, l, k):
    q, _ = np.linalg.qr(rng.standard_normal((l, k)))
    return q.astype(np.float32)


dims = st.sampled_from([8, 12, 16, 24, 32, 48, 96, 128])
small = st.sampled_from([2, 3, 4, 6, 8])


@settings(max_examples=25, deadline=None)
@given(l=dims, mm=dims, k=small, seed=st.integers(0, 2**31 - 1))
def test_project_matches_ref(l, mm, k, seed):
    if k > min(l, mm):
        return
    rng = np.random.default_rng(seed)
    m = _ortho(rng, l, k)
    g = rng.standard_normal((l, mm)).astype(np.float32)
    a, e = project(m, g)
    a_ref, e_ref = ref.project_ref(jnp.asarray(m), jnp.asarray(g))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(e), np.asarray(e_ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(l=dims, mm=dims, k=small, seed=st.integers(0, 2**31 - 1))
def test_reconstruct_matches_ref(l, mm, k, seed):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((l, k)).astype(np.float32)
    a = rng.standard_normal((k, mm)).astype(np.float32)
    got = reconstruct(m, a)
    want = ref.reconstruct_ref(jnp.asarray(m), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(l=dims, mm=dims, s=small, seed=st.integers(0, 2**31 - 1))
def test_sketch_matches_ref(l, mm, s, seed):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((l, mm)).astype(np.float32)
    omega = rng.standard_normal((mm, s)).astype(np.float32)
    got = sketch(e, omega)
    want = ref.sketch_ref(jnp.asarray(e), jnp.asarray(omega))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(l=dims, mm=dims, s=small, seed=st.integers(0, 2**31 - 1))
def test_project_b_matches_ref(l, mm, s, seed):
    rng = np.random.default_rng(seed)
    q = _ortho(rng, l, min(s, l))
    e = rng.standard_normal((l, mm)).astype(np.float32)
    got = project_b(q, e)
    want = ref.project_b_ref(jnp.asarray(q), jnp.asarray(e))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_projection_identities():
    """Structural identities the paper relies on: MᵀE = 0 and Ĝ + E = G."""
    rng = np.random.default_rng(0)
    m = _ortho(rng, 96, 8)
    g = rng.standard_normal((96, 48)).astype(np.float32)
    a, e = project(m, g)
    # Eq. 7: the error is orthogonal to the basis.
    np.testing.assert_allclose(m.T @ np.asarray(e), 0.0, atol=1e-4)
    # Decomposition exactness: M·A + E = G.
    np.testing.assert_allclose(
        np.asarray(reconstruct(m, np.asarray(a))) + np.asarray(e), g, atol=1e-4
    )


def test_pick_block_cols_divides_and_fits():
    for l, k, mm in [(1152, 32, 512), (96, 8, 48), (2048, 48, 512)]:
        bm = pick_block_cols(l, k, mm)
        assert mm % bm == 0
        assert 4 * (l * k + 2 * l * bm + k * bm) <= 14 * 2**20 or bm == 1


def test_paper_layer_shapes():
    """Run the projection kernel at the real ResNetLite layer geometry
    (l=1152 — the same l the paper uses for ResNet18 layer3)."""
    rng = np.random.default_rng(1)
    l, mm, k = 1152, 128, 32
    m = _ortho(rng, l, k)
    g = rng.standard_normal((l, mm)).astype(np.float32)
    a, e = project(m, g)
    a_ref, e_ref = ref.project_ref(jnp.asarray(m), jnp.asarray(g))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(e), np.asarray(e_ref), rtol=1e-4, atol=1e-4)
