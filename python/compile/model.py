"""L2: JAX model zoo — forward/backward for every architecture in
``layers.py``, with fused SGD train steps and evaluation steps that
``aot.py`` lowers to HLO text for the Rust coordinator.

Parameters are *flat lists* of arrays in layer-table order (the artifact
calling convention: Rust passes one literal per tensor, in order). Data
layout is NHWC; conv kernels HWIO; dense kernels ``[in, out]`` applied as
``x @ W + b``; images flatten NHWC row-major before dense layers — the
Rust native trainer (``rust/src/nn``) implements identical semantics and
is cross-checked against these graphs through the artifacts.
"""

from typing import List

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .pcg import Pcg64


# --------------------------------------------------------------------------
# Parameter initialization (mirrors rust ParamStore::init)
# --------------------------------------------------------------------------

def init_params(model: str, seed: int) -> List[jnp.ndarray]:
    """He-uniform kernels / zero biases / unit scales, from forked PCG64
    streams per tensor — bit-identical to ``ParamStore::init`` in Rust for
    conv/dense/bias/norm tensors."""
    table = L.MODELS[model]["layers"]()
    root = Pcg64(seed, 0)
    params = []
    for i, layer in enumerate(table):
        r = root.fork(i)
        n = layer.size
        if layer.role in (L.CONV, L.DENSE):
            bound = (6.0 / layer.fan_in) ** 0.5
            # Fixup-style near-zero init for residual-branch output convs
            # (no batch norm in these models) — mirrors rust ParamStore::init.
            if "block" in layer.name and layer.name.endswith("conv2.kernel"):
                bound *= 0.1
            vals = [(r.f32() * 2.0 - 1.0) * bound for _ in range(n)]
            arr = jnp.asarray(vals, dtype=jnp.float32).reshape(layer.shape)
        elif layer.role == L.BIAS:
            arr = jnp.zeros(layer.shape, jnp.float32)
        elif layer.role == L.NORM:
            fill = 1.0 if layer.name.endswith("scale") else 0.0
            arr = jnp.full(layer.shape, fill, jnp.float32)
        else:  # embedding: python-side only (rust inits its own), scaled N(0,1)
            import math

            vals = []
            while len(vals) < n:
                u1 = max(r.f64(), 1e-300)
                u2 = r.f64()
                vals.append(
                    0.02 * ((-2.0 * math.log(u1)) ** 0.5) * math.cos(2 * math.pi * u2)
                )
            arr = jnp.asarray(vals[:n], dtype=jnp.float32).reshape(layer.shape)
        params.append(arr)
    return params


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def _conv2d(x, w, b, stride=1, padding="SAME"):
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _avgpool2(x):
    y = lax.reduce_window(
        x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
    return y / 4.0


def _softmax_xent(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold


# --------------------------------------------------------------------------
# Forward passes (params: flat list in layer-table order)
# --------------------------------------------------------------------------

def lenet5_logits(params, x):
    """LeNet-5 (valid convs + avg pools), input ``[B, 28, 28, 1]``."""
    (c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b, cw, cb) = params
    h = jax.nn.relu(_conv2d(x, c1w, c1b, padding="VALID"))  # 24x24x6
    h = _avgpool2(h)  # 12x12x6
    h = jax.nn.relu(_conv2d(h, c2w, c2b, padding="VALID"))  # 8x8x16
    h = _avgpool2(h)  # 4x4x16
    h = h.reshape(h.shape[0], -1)  # 256, NHWC row-major
    h = jax.nn.relu(h @ f1w + f1b)
    h = jax.nn.relu(h @ f2w + f2b)
    return h @ cw + cb


def resnetlite_logits(params, x):
    """Residual CNN, input ``[B, 32, 32, 3]`` (see rust meta.rs)."""
    p = list(params)

    def take():
        return p.pop(0), p.pop(0)

    w, b = take()
    h = jax.nn.relu(_conv2d(x, w, b))  # 32x32x32

    def block(h):
        w1, b1 = take()
        w2, b2 = take()
        y = jax.nn.relu(_conv2d(h, w1, b1))
        y = _conv2d(y, w2, b2)
        return jax.nn.relu(h + y)

    h = block(block(h))  # stage1
    w, b = take()
    h = jax.nn.relu(_conv2d(h, w, b, stride=2))  # down1: 16x16x64
    h = block(block(h))  # stage2
    w, b = take()
    h = jax.nn.relu(_conv2d(h, w, b, stride=2))  # down2: 8x8x128
    h = block(block(h))  # stage3
    h = jnp.mean(h, axis=(1, 2))  # global avg pool -> [B, 128]
    cw, cb = take()
    assert not p
    return h @ cw + cb


def alexnetlite_logits(params, x):
    """Conv stack + wide fc1, input ``[B, 32, 32, 3]``."""
    (c1w, c1b, c2w, c2b, c3w, c3b, c4w, c4b, c5w, c5b,
     f1w, f1b, f2w, f2b, cw, cb) = params
    h = jax.nn.relu(_conv2d(x, c1w, c1b))
    h = _avgpool2(h)  # 16x16x32
    h = jax.nn.relu(_conv2d(h, c2w, c2b))
    h = _avgpool2(h)  # 8x8x64
    h = jax.nn.relu(_conv2d(h, c3w, c3b))
    h = jax.nn.relu(_conv2d(h, c4w, c4b))
    h = jax.nn.relu(_conv2d(h, c5w, c5b))
    h = _avgpool2(h)  # 4x4x128
    h = h.reshape(h.shape[0], -1)  # 2048
    h = jax.nn.relu(h @ f1w + f1b)
    h = jax.nn.relu(h @ f2w + f2b)
    return h @ cw + cb


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def tinytransformer_logits(params, tokens):
    """Decoder-only LM; ``tokens: [B, seq] int32``; returns ``[B, seq, V]``."""
    d, nlayers, nheads = L.TT_D, L.TT_LAYERS, 4
    p = list(params)
    embed = p.pop(0)
    pos = p.pop(0)
    bsz, seq = tokens.shape
    h = embed[tokens] + pos[None, :seq, :]
    mask = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    for _ in range(nlayers):
        wq, bq, wk, bk, wv, bv, wo, bo = (p.pop(0) for _ in range(8))
        ln1s, ln1b = p.pop(0), p.pop(0)
        w1, b1, w2, b2 = (p.pop(0) for _ in range(4))
        ln2s, ln2b = p.pop(0), p.pop(0)

        hn = _layernorm(h, ln1s, ln1b)
        q = (hn @ wq + bq).reshape(bsz, seq, nheads, d // nheads)
        k = (hn @ wk + bk).reshape(bsz, seq, nheads, d // nheads)
        v = (hn @ wv + bv).reshape(bsz, seq, nheads, d // nheads)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (d // nheads) ** 0.5
        att = jnp.where(mask[None, None, :, :] > 0, att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz, seq, d)
        h = h + ctx @ wo + bo

        hn = _layernorm(h, ln2s, ln2b)
        h = h + jax.nn.relu(hn @ w1 + b1) @ w2 + b2
    lns, lnb = p.pop(0), p.pop(0)
    h = _layernorm(h, lns, lnb)
    wl, bl = p.pop(0), p.pop(0)
    assert not p
    return h @ wl + bl


LOGITS = {
    "lenet5": lenet5_logits,
    "resnetlite": resnetlite_logits,
    "alexnetlite": alexnetlite_logits,
    "tinytransformer": tinytransformer_logits,
}


# --------------------------------------------------------------------------
# Train / eval steps (the artifact entry points)
# --------------------------------------------------------------------------

def loss_fn(model: str, params, x, y):
    """Mean loss over the batch."""
    if model == "tinytransformer":
        logits = LOGITS[model](params, x)[:, :-1, :]
        targets = x[:, 1:]
        flat = logits.reshape(-1, logits.shape[-1])
        return jnp.mean(_softmax_xent(flat, targets.reshape(-1)))
    logits = LOGITS[model](params, x)
    return jnp.mean(_softmax_xent(logits, y))


def make_train_step(model: str):
    """(params..., x, y, lr) -> (loss, new_params...): one SGD minibatch."""

    def step(*args):
        nparams = len(L.MODELS[model]["layers"]())
        params = list(args[:nparams])
        x, y, lr = args[nparams], args[nparams + 1], args[nparams + 2]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(model, ps, x, y)
        )(params)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return (loss, *new_params)

    return step


def make_grad_step(model: str):
    """(params..., x, y) -> (loss, grads...): raw minibatch gradients.

    Used by the Fig.-1 instrumentation and by compression backends that
    need the gradient rather than the updated weights."""

    def step(*args):
        nparams = len(L.MODELS[model]["layers"]())
        params = list(args[:nparams])
        x, y = args[nparams], args[nparams + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(model, ps, x, y)
        )(params)
        return (loss, *grads)

    return step


def make_eval_step(model: str):
    """(params..., x, y) -> (loss_sum, correct): batch evaluation."""

    def step(*args):
        nparams = len(L.MODELS[model]["layers"]())
        params = list(args[:nparams])
        x, y = args[nparams], args[nparams + 1]
        if model == "tinytransformer":
            logits = LOGITS[model](params, x)[:, :-1, :]
            targets = x[:, 1:]
            flat = logits.reshape(-1, logits.shape[-1])
            flat_t = targets.reshape(-1)
            losses = _softmax_xent(flat, flat_t)
            correct = jnp.sum(
                (jnp.argmax(flat, axis=-1) == flat_t).astype(jnp.float32)
            )
            return jnp.sum(losses), correct
        logits = LOGITS[model](params, x)
        losses = _softmax_xent(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return jnp.sum(losses), correct

    return step


def example_batch(model: str, batch: int):
    """ShapeDtypeStructs for (x, y) with the model's input geometry."""
    spec = L.MODELS[model]
    if model == "tinytransformer":
        x = jax.ShapeDtypeStruct((batch, L.TT_SEQ), jnp.int32)
        y = jax.ShapeDtypeStruct((batch,), jnp.int32)  # unused but uniform
    else:
        h, w, c = spec["input_shape"]
        x = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
        y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return x, y


def param_specs(model: str):
    """ShapeDtypeStructs for the flat parameter list."""
    return [
        jax.ShapeDtypeStruct(layer.shape, jnp.float32)
        for layer in L.MODELS[model]["layers"]()
    ]
