"""Pallas kernel: decompression  Ĝ = M·A  (paper Alg. 2).

Server-side hot path: after updating its basis copy, the decompressor
rebuilds the dense gradient from the uplinked coefficients. Same blocking
as the projection kernel — M resident in VMEM, A/Ĝ streamed in column
blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .projection import pick_block_cols


def _reconstruct_kernel(m_ref, a_ref, g_ref):
    g_ref[...] = jax.lax.dot_general(
        m_ref[...], a_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def reconstruct(m, a, interpret: bool = True):
    """Ĝ = M·A via Pallas.

    Args:
      m: ``l x k`` basis.
      a: ``k x mm`` coefficients.

    Returns:
      ``l x mm`` reconstructed gradient matrix.
    """
    l, k = m.shape
    k2, mm = a.shape
    assert k == k2, f"M cols {k} != A rows {k2}"
    bm = pick_block_cols(l, k, mm)
    grid = (mm // bm,)
    return pl.pallas_call(
        _reconstruct_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bm), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((l, bm), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((l, mm), jnp.float32),
        interpret=interpret,
    )(m, a)
