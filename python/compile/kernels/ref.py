"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must agree with its oracle to float tolerance across a hypothesis-driven
sweep of shapes (``python/tests/test_kernel.py``), and the Rust linalg
substrate is cross-checked against the same semantics through the HLO
artifacts (``rust/tests/xla_runtime.rs``).
"""

import jax.numpy as jnp


def project_ref(m, g):
    """Compression projection (paper Eq. 4 & 6).

    Args:
      m: basis matrix, ``l x k``, orthonormal columns.
      g: segmented gradient matrix, ``l x mm``.

    Returns:
      (a, e): combination coefficients ``k x mm`` (Eq. 4, A = M^T G) and
      fitting error ``l x mm`` (Eq. 6, E = G - M A).
    """
    a = m.T @ g
    e = g - m @ a
    return a, e


def reconstruct_ref(m, a):
    """Decompression (paper Alg. 2 line 2): G_hat = M A."""
    return m @ a


def sketch_ref(e, omega):
    """Randomized-SVD range sketch: Y = E Ω (Halko alg. 4.4 step 1)."""
    return e @ omega


def project_b_ref(q, e):
    """Randomized-SVD small projection: B = Qᵀ E (Halko alg. 5.1 step 2)."""
    return q.T @ e


def contribution_ref(a_full):
    """Basis contribution scores (paper Eq. 11): squared row norms."""
    return jnp.sum(a_full * a_full, axis=1)
