"""L1 performance model: VMEM footprint + MXU-utilization estimates.

Pallas interpret=True gives CPU-numpy timings that say nothing about TPU
performance, so the kernel structure is evaluated analytically
(DESIGN.md §Hardware-Adaptation): for each compressed layer geometry we
report the chosen block shape, its VMEM residency, and an MXU-utilization
estimate from the matmul tiling (how full the 128×128 systolic array's
contraction/output tiles are).

Run:  python -m compile.kernels.analysis
Output is the table recorded in EXPERIMENTS.md §Perf (L1).
"""

from dataclasses import dataclass

from ..layers import MODELS
from .projection import pick_block_cols

VMEM_BYTES = 16 * 2**20  # v4/v5e-class core budget
MXU = 128  # systolic array edge


@dataclass
class KernelEstimate:
    """Analytic kernel profile for one layer geometry."""

    name: str
    l: int
    m: int
    k: int
    bm: int
    vmem_bytes: int
    mxu_util: float
    flops: int

    def row(self) -> str:
        return (
            f"{self.name:<24} l={self.l:<5} m={self.m:<4} k={self.k:<3} "
            f"bm={self.bm:<4} VMEM={self.vmem_bytes/2**20:6.2f} MiB "
            f"MXU~{self.mxu_util*100:5.1f}%  {self.flops/1e6:8.2f} MFLOP"
        )


def _tile_eff(dim: int, tile: int = MXU) -> float:
    """Fraction of the last tile that is real work (padding waste model)."""
    import math

    tiles = math.ceil(dim / tile)
    return dim / (tiles * tile)


def estimate_projection(name: str, l: int, m: int, k: int) -> KernelEstimate:
    """Fused A = MᵀG ; E = G − MA with M resident, G streamed in bm blocks.

    MXU utilization estimate: the two dot_generals contract over l (large,
    fully tiled) and produce (k × bm) and (l × bm) outputs; utilization is
    dominated by how well k and bm fill the 128-wide output tiles.
    """
    bm = pick_block_cols(l, k, m)
    vmem = 4 * (l * k + 2 * l * bm + k * bm)
    # dot1: (k×l)·(l×bm) — output tile k×bm; dot2: (l×k)·(k×bm) — contraction k.
    util_dot1 = _tile_eff(k) * _tile_eff(bm) * _tile_eff(l)
    util_dot2 = _tile_eff(l) * _tile_eff(bm) * _tile_eff(k)
    flops = 2 * l * k * m * 2  # both matmuls
    return KernelEstimate(
        name, l, m, k, bm, vmem, (util_dot1 + util_dot2) / 2, flops
    )


def layer_geometries():
    out = []
    for model in ("lenet5", "resnetlite", "alexnetlite"):
        k = {"lenet5": 8, "resnetlite": 32, "alexnetlite": 48}[model]
        for layer in MODELS[model]["layers"]():
            if not layer.compressible:
                continue
            l = layer.fan_in
            m = layer.size // l
            kk = min(k, l, m)
            # same worth-it rule as rust compress::gradestc::layer_geoms
            if kk == 0 or kk * m + kk * l // 4 >= l * m:
                continue
            out.append((f"{model}/{layer.name}", l, m, kk))
    return out


def main() -> None:
    print("L1 kernel estimates (projection kernel; see module docstring)\n")
    worst_vmem = 0
    for (name, l, m, k) in layer_geometries():
        est = estimate_projection(name, l, m, k)
        worst_vmem = max(worst_vmem, est.vmem_bytes)
        print(est.row())
    print(
        f"\nworst-case VMEM residency: {worst_vmem/2**20:.2f} MiB "
        f"(budget {VMEM_BYTES/2**20:.0f} MiB) -> "
        f"{'OK' if worst_vmem <= VMEM_BYTES else 'OVER BUDGET'}"
    )


if __name__ == "__main__":
    main()
