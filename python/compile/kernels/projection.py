"""Pallas kernel: fused compression projection  A = MᵀG ; E = G − MA.

This is GradESTC's per-round hot spot (paper §III-C: O(2klm) of the total
cost). The kernel fuses both products over one residency of the gradient
block, with the basis matrix ``M`` pinned in VMEM across the whole grid —
the TPU analogue of the paper's "keep the basis on-device" design
(DESIGN.md §Hardware-Adaptation).

Blocking scheme (per grid step j over column blocks of G):

    M  (l × k)   — VMEM-resident, same block every step (index_map → 0)
    G  (l × bm)  — streamed block j
    A  (k × bm)  — written block j
    E  (l × bm)  — written block j

VMEM footprint ≈ 4·(l·k + 2·l·bm + k·bm) bytes; ``analysis.py`` checks the
chosen ``bm`` keeps this under the 16 MB budget for every layer shape we
compress. MXU work is the two matmuls (l×k)·(k·bm) and its transpose —
k ≥ 32 keeps the systolic array's contraction dimension busy.

Must run with ``interpret=True`` on CPU: compiled mode emits a Mosaic
custom-call only TPU plugins execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _project_kernel(m_ref, g_ref, a_ref, e_ref):
    m = m_ref[...]
    g = g_ref[...]
    # A = MᵀG: contract over l. Keep f32 accumulation explicit.
    a = jax.lax.dot_general(
        m, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    a_ref[...] = a
    # E = G − M A.
    e_ref[...] = g - jax.lax.dot_general(
        m, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def pick_block_cols(l: int, k: int, mm: int, vmem_budget: int = 14 * 2**20) -> int:
    """Largest column block bm (multiple of 8, ≤ mm) within the VMEM budget."""
    bm = mm
    while bm > 8:
        footprint = 4 * (l * k + 2 * l * bm + k * bm)
        if footprint <= vmem_budget and mm % bm == 0:
            break
        bm -= 1
    # Fall back to any divisor of mm.
    while mm % bm != 0:
        bm -= 1
    return max(bm, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def project(m, g, interpret: bool = True):
    """Fused (A, E) = (MᵀG, G − M·MᵀG) via Pallas.

    Args:
      m: ``l x k`` basis (orthonormal columns).
      g: ``l x mm`` segmented gradient.
      interpret: must stay True on CPU backends.

    Returns:
      (a, e) with shapes ``k x mm`` / ``l x mm``.
    """
    l, k = m.shape
    l2, mm = g.shape
    assert l == l2, f"M rows {l} != G rows {l2}"
    bm = pick_block_cols(l, k, mm)
    grid = (mm // bm,)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, k), lambda j: (0, 0)),  # M resident
            pl.BlockSpec((l, bm), lambda j: (0, j)),  # stream G blocks
        ],
        out_specs=[
            pl.BlockSpec((k, bm), lambda j: (0, j)),
            pl.BlockSpec((l, bm), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, mm), jnp.float32),
            jax.ShapeDtypeStruct((l, mm), jnp.float32),
        ],
        interpret=interpret,
    )(m, g)
