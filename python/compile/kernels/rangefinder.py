"""Pallas kernels for the randomized-SVD range finder (Halko et al. 2011).

Two matmul-shaped stages dominate the rSVD of the fitting error E
(paper §III-B(c)):

  * ``sketch``:    Y = E·Ω      (l×mm)·(mm×s) — sample the range of E
  * ``project_b``: B = Qᵀ·E     (l×s)ᵀ·(l×mm) — compress into the sketch

The tiny QR of Y and the SVD of B are O(l·s²)/O(s²·mm) control-flow-heavy
steps that stay on the coordinator (rust ``linalg``). Grid layout mirrors
projection.py: the small operand (Ω or Q) is VMEM-resident, E streams
through in blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_inner_block(mm: int, limit: int = 512) -> int:
    bm = min(mm, limit)
    while mm % bm != 0:
        bm -= 1
    return max(bm, 1)


def _sketch_kernel(e_ref, omega_ref, y_ref):
    # Grid over contraction blocks of E's columns; the output block is the
    # whole (l, s) sketch at every step, so accumulate in place.
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jax.lax.dot_general(
        e_ref[...], omega_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def sketch(e, omega, interpret: bool = True):
    """Y = E·Ω via Pallas with accumulation over contraction blocks.

    Args:
      e: ``l x mm`` fitting error.
      omega: ``mm x s`` Gaussian test matrix.

    Returns:
      ``l x s`` range sketch.
    """
    l, mm = e.shape
    mm2, s = omega.shape
    assert mm == mm2
    bm = _pick_inner_block(mm)
    grid = (mm // bm,)
    return pl.pallas_call(
        _sketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, bm), lambda j: (0, j)),
            pl.BlockSpec((bm, s), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((l, s), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, s), jnp.float32),
        interpret=interpret,
    )(e, omega)


def _project_b_kernel(q_ref, e_ref, b_ref):
    b_ref[...] = jax.lax.dot_general(
        q_ref[...], e_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def project_b(q, e, interpret: bool = True):
    """B = Qᵀ·E via Pallas (Q resident, E streamed).

    Args:
      q: ``l x s`` orthonormal range basis.
      e: ``l x mm`` fitting error.

    Returns:
      ``s x mm``.
    """
    l, s = q.shape
    l2, mm = e.shape
    assert l == l2
    bm = _pick_inner_block(mm, 256)
    grid = (mm // bm,)
    return pl.pallas_call(
        _project_b_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, s), lambda j: (0, 0)),
            pl.BlockSpec((l, bm), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((s, bm), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((s, mm), jnp.float32),
        interpret=interpret,
    )(q, e)
