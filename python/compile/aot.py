"""AOT pipeline: lower every L2 graph and L1 kernel to HLO text + manifest.

HLO *text* (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the rust ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs, under ``--out`` (default ``../artifacts``):

  manifest.json                    artifact index + layer tables
  <model>.train_step.hlo.txt       (params…, x, y, lr) -> (loss, params…)
  <model>.grad_step.hlo.txt        (params…, x, y)     -> (loss, grads…)
  <model>.eval_step.hlo.txt        (params…, x, y)     -> (loss_sum, correct)
  kernel.project.<l>x<m>x<k>.hlo.txt       (M, G) -> (A, E)
  kernel.reconstruct.<l>x<m>x<k>.hlo.txt   (M, A) -> (Ghat,)
  kernel.sketch.<l>x<m>x<s>.hlo.txt        (E, Ω) -> (Y,)

Python runs ONCE at build time (``make artifacts``); the rust binary then
loads these files via PJRT and never calls back into python.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers as L
from . import model as M
from .kernels.projection import project
from .kernels.rangefinder import sketch
from .kernels.reconstruct import reconstruct

# Models lowered by default. The transformer is the e2e driver's model;
# vision models feed the comparison experiments.
DEFAULT_MODELS = ["lenet5", "resnetlite", "alexnetlite", "tinytransformer"]

# Compression-kernel shapes: every distinct (l, m) of resnetlite's
# compressed layers at the paper's k=32, plus a small shape used by tests.
def kernel_shapes():
    shapes = set()
    for layer in L.resnetlite():
        if layer.compressible and layer.size >= 32 * 32:
            l = layer.fan_in
            m = layer.size // l
            shapes.add((l, m, 32))
    shapes.add((96, 48, 8))  # test shape (python + rust integration tests)
    return sorted(shapes)


def to_hlo_text(fn, args) -> str:
    # keep_unused=True: the calling convention is positional and fixed
    # (Rust supplies every declared input), so jit must not prune arguments
    # a particular model ignores (e.g. the transformer's label tensor).
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(out_dir: str, name: str, text: str) -> dict:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {"file": name, "sha256_16": digest, "bytes": len(text)}


def lower_model(out_dir: str, name: str) -> dict:
    spec = L.MODELS[name]
    table = spec["layers"]()
    batch, eval_batch = spec["batch"], spec["eval_batch"]
    pspecs = M.param_specs(name)
    x, y = M.example_batch(name, batch)
    xe, ye = M.example_batch(name, eval_batch)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    entry = {
        "layers": [
            {"name": l.name, "shape": list(l.shape), "role": l.role}
            for l in table
        ],
        "input_shape": list(spec["input_shape"]),
        "classes": spec["classes"],
        "batch": batch,
        "eval_batch": eval_batch,
        "total_params": sum(l.size for l in table),
    }
    print(f"  lowering {name}.train_step ...", flush=True)
    entry["train_step"] = write(
        out_dir,
        f"{name}.train_step.hlo.txt",
        to_hlo_text(M.make_train_step(name), (*pspecs, x, y, lr)),
    )
    print(f"  lowering {name}.grad_step ...", flush=True)
    entry["grad_step"] = write(
        out_dir,
        f"{name}.grad_step.hlo.txt",
        to_hlo_text(M.make_grad_step(name), (*pspecs, x, y)),
    )
    print(f"  lowering {name}.eval_step ...", flush=True)
    entry["eval_step"] = write(
        out_dir,
        f"{name}.eval_step.hlo.txt",
        to_hlo_text(M.make_eval_step(name), (*pspecs, xe, ye)),
    )
    return entry


def lower_kernels(out_dir: str) -> dict:
    kernels = {}
    for (l, m, k) in kernel_shapes():
        mm_spec = jax.ShapeDtypeStruct((l, k), jnp.float32)
        g_spec = jax.ShapeDtypeStruct((l, m), jnp.float32)
        a_spec = jax.ShapeDtypeStruct((k, m), jnp.float32)
        tag = f"{l}x{m}x{k}"
        print(f"  lowering kernel.project.{tag} ...", flush=True)
        kernels[f"project.{tag}"] = {
            **write(
                out_dir,
                f"kernel.project.{tag}.hlo.txt",
                to_hlo_text(lambda mm, gg: project(mm, gg), (mm_spec, g_spec)),
            ),
            "kind": "project",
            "l": l,
            "m": m,
            "k": k,
        }
        kernels[f"reconstruct.{tag}"] = {
            **write(
                out_dir,
                f"kernel.reconstruct.{tag}.hlo.txt",
                to_hlo_text(lambda mm, aa: (reconstruct(mm, aa),), (mm_spec, a_spec)),
            ),
            "kind": "reconstruct",
            "l": l,
            "m": m,
            "k": k,
        }
        # Sketch kernel for the rSVD range finder at s = k + 6 oversampling.
        s = k + 6
        e_spec = jax.ShapeDtypeStruct((l, m), jnp.float32)
        o_spec = jax.ShapeDtypeStruct((m, s), jnp.float32)
        kernels[f"sketch.{l}x{m}x{s}"] = {
            **write(
                out_dir,
                f"kernel.sketch.{l}x{m}x{s}.hlo.txt",
                to_hlo_text(lambda ee, oo: (sketch(ee, oo),), (e_spec, o_spec)),
            ),
            "kind": "sketch",
            "l": l,
            "m": m,
            "s": s,
        }
    return kernels


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--models",
        default=",".join(DEFAULT_MODELS),
        help="comma-separated subset of models to lower",
    )
    ap.add_argument(
        "--skip-kernels", action="store_true", help="skip compression kernels"
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "models": {}, "kernels": {}}
    for name in [m for m in args.models.split(",") if m]:
        if name not in L.MODELS:
            print(f"unknown model '{name}'", file=sys.stderr)
            return 2
        print(f"model {name}:", flush=True)
        manifest["models"][name] = lower_model(args.out, name)
    if not args.skip_kernels:
        print("kernels:", flush=True)
        manifest["kernels"] = lower_kernels(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    total = sum(
        e.get("bytes", 0)
        for section in (manifest["models"], manifest["kernels"])
        for entry in section.values()
        for e in (
            [entry] if "file" in entry else
            [v for v in entry.values() if isinstance(v, dict) and "file" in v]
        )
    )
    print(f"wrote manifest + artifacts ({total/1e6:.1f} MB of HLO text) to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
