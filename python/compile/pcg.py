"""PCG64-DXSM mirror of ``rust/src/util/rng.rs``.

Only the integer core is mirrored (``next_u64``, ``f32``, ``fork``): the
cross-language contract is bit-exact raw streams, which keeps Rust-side
parameter initialization reproducible from python tests without trusting
transcendental-function rounding. Verified by
``python/tests/test_pcg.py`` against vectors captured from the Rust
implementation.
"""

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
PCG_MULT = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645
INC_XOR = 0x5851_F42D_4C95_7F2D_1405_7B7E_F767_814F
DXSM_MULT = 0xDA94_2042_E4DD_58B5
GOLDEN = 0x9E37_79B9_7F4A_7C15


class Pcg64:
    """PCG64-DXSM, bit-compatible with the Rust implementation."""

    def __init__(self, seed: int, stream: int = 0):
        self.inc = ((((stream & MASK64) << 1) | 1) ^ INC_XOR) & MASK128
        state = 0
        state = (state * PCG_MULT + self.inc) & MASK128
        state = (state + (seed & MASK64)) & MASK128
        state = (state * PCG_MULT + self.inc) & MASK128
        self.state = state

    def fork(self, tag: int) -> "Pcg64":
        s = ((self.state >> 64) ^ (self.state & MASK64)) & MASK64
        seed = ((s + GOLDEN) & MASK64) * ((tag | 1) & MASK64) & MASK64
        return Pcg64(seed, tag)

    def next_u64(self) -> int:
        hi = (self.state >> 64) & MASK64
        lo = (self.state & MASK64) | 1
        hi ^= hi >> 32
        hi = (hi * DXSM_MULT) & MASK64
        hi ^= hi >> 48
        hi = (hi * lo) & MASK64
        self.state = (self.state * PCG_MULT + self.inc) & MASK128
        return hi

    def f32(self) -> float:
        """Uniform f32 in [0,1) — exact dyadic rational, no rounding risk."""
        return (self.next_u64() >> 40) * (1.0 / 16777216.0)

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / 9007199254740992.0)
