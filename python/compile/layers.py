"""Canonical layer tables — python mirror of ``rust/src/model/meta.rs``.

The Rust side is the source of truth; this module re-declares the same
architectures so the JAX models (L2) and the AOT manifest agree with the
coordinator layer-by-layer. ``rust/tests/artifacts.rs`` diffs the manifest
against the Rust tables, so any drift fails CI.

Shapes use JAX conventions: conv kernels HWIO ``[kh, kw, cin, cout]``,
dense kernels ``[in, out]``.
"""

from dataclasses import dataclass
from typing import List

CONV = "conv"
DENSE = "dense"
BIAS = "bias"
EMBED = "embed"
NORM = "norm"


@dataclass(frozen=True)
class Layer:
    """One trainable tensor."""

    name: str
    shape: tuple
    role: str

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def compressible(self) -> bool:
        return self.role in (CONV, DENSE)

    @property
    def fan_in(self) -> int:
        """Segment length l: fan-in (see rust LayerMeta::segment_len)."""
        if self.role == CONV:
            return self.shape[0] * self.shape[1] * self.shape[2]
        if self.role in (DENSE, EMBED):
            return self.shape[0]
        return self.size


def _conv(name: str, kh: int, kw: int, cin: int, cout: int) -> List[Layer]:
    return [
        Layer(f"{name}.kernel", (kh, kw, cin, cout), CONV),
        Layer(f"{name}.bias", (cout,), BIAS),
    ]


def _dense(name: str, din: int, dout: int) -> List[Layer]:
    return [
        Layer(f"{name}.kernel", (din, dout), DENSE),
        Layer(f"{name}.bias", (dout,), BIAS),
    ]


def lenet5() -> List[Layer]:
    layers: List[Layer] = []
    layers += _conv("conv1", 5, 5, 1, 6)
    layers += _conv("conv2", 5, 5, 6, 16)
    layers += _dense("fc1", 256, 120)
    layers += _dense("fc2", 120, 84)
    layers += _dense("classifier", 84, 10)
    return layers


def resnetlite() -> List[Layer]:
    layers: List[Layer] = []
    layers += _conv("conv_in", 3, 3, 3, 32)
    for b in range(2):
        layers += _conv(f"stage1.block{b}.conv1", 3, 3, 32, 32)
        layers += _conv(f"stage1.block{b}.conv2", 3, 3, 32, 32)
    layers += _conv("down1", 3, 3, 32, 64)
    for b in range(2):
        layers += _conv(f"stage2.block{b}.conv1", 3, 3, 64, 64)
        layers += _conv(f"stage2.block{b}.conv2", 3, 3, 64, 64)
    layers += _conv("down2", 3, 3, 64, 128)
    for b in range(2):
        layers += _conv(f"stage3.block{b}.conv1", 3, 3, 128, 128)
        layers += _conv(f"stage3.block{b}.conv2", 3, 3, 128, 128)
    layers += _dense("classifier", 128, 10)
    return layers


def alexnetlite() -> List[Layer]:
    layers: List[Layer] = []
    layers += _conv("conv1", 3, 3, 3, 32)
    layers += _conv("conv2", 3, 3, 32, 64)
    layers += _conv("conv3", 3, 3, 64, 128)
    layers += _conv("conv4", 3, 3, 128, 128)
    layers += _conv("conv5", 3, 3, 128, 128)
    layers += _dense("fc1", 2048, 512)
    layers += _dense("fc2", 512, 256)
    layers += _dense("classifier", 256, 100)
    return layers


# TinyTransformer geometry (mirrors rust).
TT_VOCAB, TT_D, TT_LAYERS, TT_FF, TT_SEQ = 256, 128, 4, 512, 64


def tinytransformer() -> List[Layer]:
    v, d, n, ff, seq = TT_VOCAB, TT_D, TT_LAYERS, TT_FF, TT_SEQ
    layers: List[Layer] = [
        Layer("embed.table", (v, d), EMBED),
        Layer("pos.table", (seq, d), EMBED),
    ]
    for i in range(n):
        for nm in ("wq", "wk", "wv", "wo"):
            layers += _dense(f"layer{i}.attn.{nm}", d, d)
        layers += [
            Layer(f"layer{i}.ln1.scale", (d,), NORM),
            Layer(f"layer{i}.ln1.bias", (d,), NORM),
        ]
        layers += _dense(f"layer{i}.ff.w1", d, ff)
        layers += _dense(f"layer{i}.ff.w2", ff, d)
        layers += [
            Layer(f"layer{i}.ln2.scale", (d,), NORM),
            Layer(f"layer{i}.ln2.bias", (d,), NORM),
        ]
    layers += [
        Layer("ln_f.scale", (d,), NORM),
        Layer("ln_f.bias", (d,), NORM),
    ]
    layers += _dense("lm_head", d, TT_VOCAB)
    return layers


MODELS = {
    "lenet5": {
        "layers": lenet5,
        "input_shape": (28, 28, 1),
        "classes": 10,
        "batch": 32,
        "eval_batch": 64,
    },
    "resnetlite": {
        "layers": resnetlite,
        "input_shape": (32, 32, 3),
        "classes": 10,
        "batch": 32,
        "eval_batch": 64,
    },
    "alexnetlite": {
        "layers": alexnetlite,
        "input_shape": (32, 32, 3),
        "classes": 100,
        "batch": 32,
        "eval_batch": 64,
    },
    "tinytransformer": {
        "layers": tinytransformer,
        "input_shape": (TT_SEQ,),
        "classes": TT_VOCAB,
        "batch": 16,
        "eval_batch": 32,
    },
}
