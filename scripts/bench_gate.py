#!/usr/bin/env python3
"""Bench-trajectory regression gate.

Diffs a freshly produced ``BENCH_<suite>.json`` (written by the benches in
``rust/benches/`` via ``Bencher::to_json``) against the committed baseline
in ``rust/benches/baselines/`` and fails when any benchmark's median
regresses by more than the threshold (default 15%).

Usage:
    bench_gate.py <baseline.json> <current.json> [--threshold=0.15]

Exit codes: 0 = pass (or gate skipped), 1 = regression, 2 = usage/IO error.

The gate skips itself (exit 0) in two cases:

* the baseline carries ``"bootstrap": true`` — a placeholder committed
  before any reference medians existed (replace it with a real run to arm
  the gate);
* the ``HEAD_MSG`` environment variable (CI passes the head commit
  message) contains the literal tag ``[bench-baseline-reset]`` — the
  escape hatch for commits that intentionally move a baseline.

Benchmarks present in the baseline but missing from the current run are
reported as warnings, not failures, so renames only need a baseline
refresh; improvements are reported but never fail.
"""

import json
import os
import sys

SKIP_TAG = "[bench-baseline-reset]"


def load(path):
    try:
        with open(path, encoding="utf-8") as fh:
            return json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"bench_gate: cannot read {path}: {exc}", file=sys.stderr)
        sys.exit(2)


def medians(doc):
    return {b["name"]: float(b["median_ns"]) for b in doc.get("benches", [])}


def main(argv):
    args = [a for a in argv if not a.startswith("--")]
    threshold = 0.15
    for a in argv:
        if a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline_path, current_path = args

    head_msg = os.environ.get("HEAD_MSG", "")
    if SKIP_TAG in head_msg:
        print(f"bench_gate: skipped — commit message carries {SKIP_TAG}")
        return 0

    baseline = load(baseline_path)
    current = load(current_path)
    if baseline.get("bootstrap"):
        print(
            f"bench_gate: skipped — {baseline_path} is a bootstrap placeholder "
            "(no reference medians yet); replace it with a real run to arm the gate"
        )
        return 0

    base, cur = medians(baseline), medians(current)
    regressions = []
    for name in sorted(base):
        if name not in cur:
            print(f"bench_gate: WARNING: '{name}' in baseline but not in current run")
            continue
        if base[name] <= 0.0:
            continue
        delta = cur[name] / base[name] - 1.0
        tag = "REGRESSION" if delta > threshold else "ok"
        print(
            f"bench_gate: {name}: {base[name]:.0f} ns -> {cur[name]:.0f} ns "
            f"({delta:+.1%}) [{tag}]"
        )
        if delta > threshold:
            regressions.append((name, delta))
    for name in sorted(set(cur) - set(base)):
        print(f"bench_gate: new bench '{name}' (no baseline yet)")

    if regressions:
        print(
            f"bench_gate: FAIL — {len(regressions)} bench(es) regressed more than "
            f"{threshold:.0%} vs {baseline_path}; if intentional, refresh the baseline "
            f"and include {SKIP_TAG} in the commit message",
            file=sys.stderr,
        )
        return 1
    print(f"bench_gate: pass ({len(base)} baselines checked, threshold {threshold:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
