#!/usr/bin/env python3
"""Chrome ``trace_event`` JSON validator for gradestc traces.

Checks the files written by ``gradestc train --trace`` /
``gradestc exp --trace`` (see ``rust/src/telemetry/export.rs``):

* top level is an object with a ``traceEvents`` list plus the run
  identity in ``otherData`` (``backend``, ``sched``);
* every event is ``ph: "X"`` (complete span) or ``ph: "M"`` (metadata),
  with the required keys for its kind; ``X`` events carry a numeric
  ``ts`` and a non-negative ``dur``;
* both tracks are present: pid 1 (host wall-time) and pid 2 (virtual
  clock);
* per ``(pid, tid)`` track, timestamps are monotonically non-decreasing
  in file order — the order the exporter guarantees;
* spans on one track nest: a span either starts at-or-after the end of
  the previous open span (sibling) or ends at-or-before it (child).
  Partial overlap means the exporter's sort or the recorded intervals
  are broken.

``--expect <phase>`` (repeatable) additionally asserts that at least one
span with that name is present in each file — CI uses it to pin phases a
change introduced (e.g. ``--expect lane_materialize`` for the virtual-lane
plane's first-touch spans).

``--metrics <metrics.json>`` cross-checks the trace against the run's
metrics JSON (requires exactly one trace file): the ``faults`` run
counter must equal the number of ``fault`` spans on the virtual-clock
track, and every fault span must be zero-duration — a fault is an
instant (the arrival that never folded), not an interval. The
churn-smoke CI job uses this to pin the availability plane's
counter/span consistency.

Usage:
    check_trace.py [--expect <phase>]... [--metrics <metrics.json>]
                   <trace.json> [<trace.json> ...]

Exit codes: 0 = all files valid, 1 = validation failure, 2 = usage/IO.
"""

import json
import sys

X_KEYS = {"ph", "pid", "tid", "ts", "dur", "name", "cat", "args"}
M_KEYS = {"ph", "pid", "tid", "name", "args"}


def fail(path, msg):
    print(f"check_trace: {path}: {msg}", file=sys.stderr)
    return False


def check_events(path, events):
    ok = True
    last_ts = {}  # (pid, tid) -> last seen ts
    open_stack = {}  # (pid, tid) -> stack of span end times
    pids = set()
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            return fail(path, f"event {i}: not an object")
        ph = ev.get("ph")
        if ph == "M":
            if not M_KEYS.issubset(ev):
                ok = fail(path, f"event {i}: metadata missing keys {sorted(M_KEYS - set(ev))}")
            continue
        if ph != "X":
            ok = fail(path, f"event {i}: unexpected ph {ph!r} (want X or M)")
            continue
        missing = X_KEYS - set(ev)
        if missing:
            ok = fail(path, f"event {i}: span missing keys {sorted(missing)}")
            continue
        ts, dur = ev["ts"], ev["dur"]
        if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
            ok = fail(path, f"event {i}: ts/dur not numeric")
            continue
        if dur < 0:
            ok = fail(path, f"event {i} ({ev['name']}): negative dur {dur}")
        n_spans += 1
        pids.add(ev["pid"])
        key = (ev["pid"], ev["tid"])
        prev = last_ts.get(key)
        if prev is not None and ts < prev:
            ok = fail(path, f"event {i} ({ev['name']}): ts {ts} regressed below {prev} on track {key}")
        last_ts[key] = ts

        # Nesting: pop every open span this one starts at-or-after the
        # end of; what remains open must fully contain it.
        stack = open_stack.setdefault(key, [])
        while stack and ts >= stack[-1]:
            stack.pop()
        end = ts + dur
        if stack and end > stack[-1]:
            ok = fail(
                path,
                f"event {i} ({ev['name']}): span [{ts}, {end}] partially overlaps "
                f"the enclosing span ending at {stack[-1]} on track {key}",
            )
        stack.append(end)

    if n_spans == 0:
        ok = fail(path, "no X (span) events at all")
    for pid, label in ((1, "host wall-time"), (2, "virtual clock")):
        if pid not in pids:
            ok = fail(path, f"missing track pid {pid} ({label})")
    return ok


def check_file(path, expect=()):
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"check_trace: cannot read {path}: {exc}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(doc, dict):
        return fail(path, "top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return fail(path, "traceEvents missing or not a list")
    other = doc.get("otherData", {})
    if not isinstance(other, dict) or "backend" not in other or "sched" not in other:
        return fail(path, "otherData must carry backend and sched")
    if not check_events(path, events):
        return False
    names = {e.get("name") for e in events if isinstance(e, dict) and e.get("ph") == "X"}
    ok = True
    for phase in expect:
        if phase not in names:
            ok = fail(path, f"expected at least one {phase!r} span, found none")
    if not ok:
        return False
    n_spans = sum(1 for e in events if isinstance(e, dict) and e.get("ph") == "X")
    print(f"check_trace: {path}: ok ({n_spans} spans, sched={other['sched']}, backend={other['backend']})")
    return True


def check_fault_consistency(trace_path, metrics_path):
    """``run.counters.faults`` == zero-duration ``fault`` spans on the
    virtual-clock track."""
    try:
        with open(trace_path, encoding="utf-8") as fh:
            trace = json.load(fh)
        with open(metrics_path, encoding="utf-8") as fh:
            metrics = json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"check_trace: cannot read {trace_path}/{metrics_path}: {exc}", file=sys.stderr)
        sys.exit(2)
    events = trace.get("traceEvents", [])
    fault_spans = [
        e
        for e in events
        if isinstance(e, dict) and e.get("ph") == "X" and e.get("name") == "fault" and e.get("pid") == 2
    ]
    ok = True
    for ev in fault_spans:
        if ev.get("dur") != 0:
            ok = fail(trace_path, f"fault span with non-zero dur {ev.get('dur')} (faults are instants)")
    run = metrics.get("run")
    if not isinstance(run, dict) or not isinstance(run.get("counters"), dict):
        return fail(metrics_path, "metrics JSON missing run.counters")
    counted = run["counters"].get("faults", 0)
    if counted != len(fault_spans):
        ok = fail(
            metrics_path,
            f"faults counter {counted} != {len(fault_spans)} fault spans in {trace_path}",
        )
    if ok:
        print(f"check_trace: {metrics_path}: faults counter consistent ({counted} faults)")
    return ok


def main(argv):
    expect = []
    paths = []
    metrics = None
    it = iter(argv)
    for arg in it:
        if arg == "--expect":
            phase = next(it, None)
            if phase is None:
                print("check_trace: --expect needs a phase name", file=sys.stderr)
                return 2
            expect.append(phase)
        elif arg == "--metrics":
            metrics = next(it, None)
            if metrics is None:
                print("check_trace: --metrics needs a metrics.json path", file=sys.stderr)
                return 2
        else:
            paths.append(arg)
    if not paths:
        print(__doc__, file=sys.stderr)
        return 2
    if metrics is not None and len(paths) != 1:
        print("check_trace: --metrics requires exactly one trace file", file=sys.stderr)
        return 2
    ok = True
    for path in paths:
        ok = check_file(path, expect) and ok
    if metrics is not None:
        ok = check_fault_consistency(paths[0], metrics) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
