#!/usr/bin/env python3
"""Diagnostics-plane validator for gradestc ``diag.csv`` exports.

Checks the files written by ``gradestc train --diag`` /
``gradestc exp --diag`` / ``gradestc exp diag1`` (see
``rust/src/telemetry/export.rs``):

* the header matches the exporter's column order exactly
  (``round,layer,drift_mean_angle,...,bytes_per_loss``);
* every row parses — ``round`` an integer, metric cells either empty
  (estimator had nothing to measure) or finite numbers;
* principal angles live in [0, pi/2], with ``drift_max_angle >=
  drift_mean_angle`` per row;
* cosines live in [-1, 1]; NRMSE and energy coverage in [0, 1];
* ``churn_dr`` is a non-negative integer; ``stable_rank`` >= 1 and
  ``bytes_per_unit_energy`` > 0 where present;
* each round's rows end with exactly one ``layer = "*"`` aggregate row,
  and over those aggregate rows ``cum_uplink_bytes`` is present and
  monotonically non-decreasing;
* with ``--raw`` (uncompressed / lossless runs), every present NRMSE is
  exactly 0 and every present energy coverage exactly 1 — the fidelity
  estimator's lossless contract;
* ``--metrics <file>`` (repeatable) additionally validates a metrics
  JSON: it must carry a ``"diag"`` section with the sampled clients,
  layer names, run-level adjacent cosines (in [-1, 1], one per layer),
  the adjacent-pair count, and per-round aggregate rows.

Usage:
    check_diag.py [--raw] [--metrics <metrics.json>]... <diag.csv> [<diag.csv> ...]

Exit codes: 0 = all files valid, 1 = validation failure, 2 = usage/IO.
"""

import json
import math
import sys

EXPECTED_HEADER = (
    "round,layer,drift_mean_angle,drift_max_angle,drift_chordal,churn_dr,"
    "energy_coverage,cosine,nrmse,stable_rank,bytes_per_unit_energy,"
    "cum_uplink_bytes,loss_drop,bytes_per_loss"
)
COLUMNS = EXPECTED_HEADER.split(",")
EPS = 1e-9
HALF_PI = math.pi / 2


def fail(path, msg):
    print(f"check_diag: {path}: {msg}", file=sys.stderr)
    return False


def parse_cell(path, lineno, name, cell):
    """Empty cell -> None; otherwise a finite float (or raise via fail)."""
    if cell == "":
        return None, True
    try:
        v = float(cell)
    except ValueError:
        return None, fail(path, f"line {lineno}: {name} {cell!r} is not numeric")
    if not math.isfinite(v):
        return None, fail(path, f"line {lineno}: {name} {cell!r} is not finite")
    return v, True


def in_range(path, lineno, name, v, lo, hi):
    if v is None:
        return True
    if not (lo - EPS <= v <= hi + EPS):
        return fail(path, f"line {lineno}: {name} {v} outside [{lo}, {hi}]")
    return True


def check_csv(path, raw=False):
    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except OSError as exc:
        print(f"check_diag: cannot read {path}: {exc}", file=sys.stderr)
        sys.exit(2)
    if not lines:
        return fail(path, "empty file")
    if lines[0] != EXPECTED_HEADER:
        return fail(path, f"header mismatch:\n  got  {lines[0]}\n  want {EXPECTED_HEADER}")

    ok = True
    n_rows = 0
    prev_cum = None  # last aggregate row's cum_uplink_bytes
    round_has_agg = {}  # round -> bool (aggregate row seen)
    for lineno, line in enumerate(lines[1:], start=2):
        if not line:
            continue
        cells = line.split(",")
        if len(cells) != len(COLUMNS):
            ok = fail(path, f"line {lineno}: {len(cells)} cells, want {len(COLUMNS)}")
            continue
        row = dict(zip(COLUMNS, cells))
        n_rows += 1
        try:
            rnd = int(row["round"])
        except ValueError:
            ok = fail(path, f"line {lineno}: round {row['round']!r} is not an integer")
            continue
        layer = row["layer"]
        if not layer:
            ok = fail(path, f"line {lineno}: empty layer name")
        if round_has_agg.get(rnd):
            ok = fail(path, f"line {lineno}: row after round {rnd}'s aggregate")

        vals = {}
        for name in COLUMNS[2:]:
            vals[name], good = parse_cell(path, lineno, name, row[name])
            ok = good and ok

        ok = in_range(path, lineno, "drift_mean_angle", vals["drift_mean_angle"], 0, HALF_PI) and ok
        ok = in_range(path, lineno, "drift_max_angle", vals["drift_max_angle"], 0, HALF_PI) and ok
        mean_a, max_a = vals["drift_mean_angle"], vals["drift_max_angle"]
        if mean_a is not None and max_a is not None and max_a < mean_a - EPS:
            ok = fail(path, f"line {lineno}: max angle {max_a} < mean angle {mean_a}")
        if vals["drift_chordal"] is not None and vals["drift_chordal"] < -EPS:
            ok = fail(path, f"line {lineno}: negative chordal distance {vals['drift_chordal']}")
        churn = vals["churn_dr"]
        if churn is not None and (churn < 0 or churn != int(churn)):
            ok = fail(path, f"line {lineno}: churn_dr {churn} is not a non-negative integer")
        ok = in_range(path, lineno, "energy_coverage", vals["energy_coverage"], 0, 1) and ok
        ok = in_range(path, lineno, "cosine", vals["cosine"], -1, 1) and ok
        ok = in_range(path, lineno, "nrmse", vals["nrmse"], 0, 1) and ok
        if vals["stable_rank"] is not None and vals["stable_rank"] < 1 - EPS:
            ok = fail(path, f"line {lineno}: stable_rank {vals['stable_rank']} < 1")
        bpe = vals["bytes_per_unit_energy"]
        if bpe is not None and bpe <= 0:
            ok = fail(path, f"line {lineno}: bytes_per_unit_energy {bpe} not positive")

        if raw:
            if vals["nrmse"] not in (None, 0.0):
                ok = fail(path, f"line {lineno}: raw run but nrmse {vals['nrmse']} != 0")
            if vals["energy_coverage"] not in (None, 1.0):
                ok = fail(
                    path,
                    f"line {lineno}: raw run but energy_coverage "
                    f"{vals['energy_coverage']} != 1",
                )

        if layer == "*":
            round_has_agg[rnd] = True
            cum = vals["cum_uplink_bytes"]
            if cum is None:
                ok = fail(path, f"line {lineno}: aggregate row without cum_uplink_bytes")
            else:
                if prev_cum is not None and cum < prev_cum:
                    ok = fail(
                        path,
                        f"line {lineno}: cum_uplink_bytes regressed {prev_cum} -> {cum}",
                    )
                prev_cum = cum
        else:
            round_has_agg.setdefault(rnd, False)
            for name in ("cum_uplink_bytes", "loss_drop", "bytes_per_loss"):
                if vals[name] is not None:
                    ok = fail(path, f"line {lineno}: {name} set on a per-layer row")

    if n_rows == 0:
        ok = fail(path, "no data rows")
    missing = sorted(r for r, has in round_has_agg.items() if not has)
    if missing:
        ok = fail(path, f"rounds without an aggregate row: {missing}")
    if ok:
        print(f"check_diag: {path}: ok ({n_rows} rows, {len(round_has_agg)} rounds)")
    return ok


def check_metrics(path):
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"check_diag: cannot read {path}: {exc}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(doc, dict):
        return fail(path, "top level is not an object")
    diag = doc.get("diag")
    if not isinstance(diag, dict):
        return fail(path, "no 'diag' section (was the run armed with --diag?)")
    ok = True
    sample = diag.get("sample")
    if not isinstance(sample, list) or not all(isinstance(c, (int, float)) for c in sample):
        ok = fail(path, "diag.sample missing or not a numeric list")
    layers = diag.get("layers")
    if not isinstance(layers, list) or not all(isinstance(n, str) for n in layers):
        ok = fail(path, "diag.layers missing or not a string list")
    cosines = diag.get("run_adjacent_cosine")
    if not isinstance(cosines, list):
        ok = fail(path, "diag.run_adjacent_cosine missing or not a list")
    else:
        if isinstance(layers, list) and len(cosines) != len(layers):
            ok = fail(path, f"{len(cosines)} run cosines for {len(layers)} layers")
        for i, c in enumerate(cosines):
            if not isinstance(c, (int, float)) or not (-1 - EPS <= c <= 1 + EPS):
                ok = fail(path, f"diag.run_adjacent_cosine[{i}] = {c!r} outside [-1, 1]")
    pairs = diag.get("adjacent_pairs")
    if not isinstance(pairs, (int, float)) or pairs < 0:
        ok = fail(path, f"diag.adjacent_pairs {pairs!r} is not a non-negative number")
    rounds = diag.get("rounds")
    if not isinstance(rounds, list) or not rounds:
        ok = fail(path, "diag.rounds missing or empty")
    else:
        prev_cum = None
        for i, row in enumerate(rounds):
            if not isinstance(row, dict) or "round" not in row:
                ok = fail(path, f"diag.rounds[{i}] malformed")
                continue
            cum = row.get("cum_uplink_bytes")
            if isinstance(cum, (int, float)):
                if prev_cum is not None and cum < prev_cum:
                    ok = fail(path, f"diag.rounds[{i}]: cum bytes regressed {prev_cum} -> {cum}")
                prev_cum = cum
            n = row.get("nrmse")
            if isinstance(n, (int, float)) and not (-EPS <= n <= 1 + EPS):
                ok = fail(path, f"diag.rounds[{i}]: nrmse {n} outside [0, 1]")
            c = row.get("cosine")
            if isinstance(c, (int, float)) and not (-1 - EPS <= c <= 1 + EPS):
                ok = fail(path, f"diag.rounds[{i}]: cosine {c} outside [-1, 1]")
    if ok:
        n_rounds = len(rounds) if isinstance(rounds, list) else 0
        print(f"check_diag: {path}: ok (diag section, {n_rounds} round aggregates)")
    return ok


def main(argv):
    raw = False
    metrics = []
    paths = []
    it = iter(argv)
    for arg in it:
        if arg == "--raw":
            raw = True
        elif arg == "--metrics":
            m = next(it, None)
            if m is None:
                print("check_diag: --metrics needs a file path", file=sys.stderr)
                return 2
            metrics.append(m)
        else:
            paths.append(arg)
    if not paths and not metrics:
        print(__doc__, file=sys.stderr)
        return 2
    ok = True
    for path in paths:
        ok = check_csv(path, raw=raw) and ok
    for path in metrics:
        ok = check_metrics(path) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
