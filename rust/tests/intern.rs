//! Integration tests for the basis-interning plane
//! (`gradestc::compress::intern`): cross-lane dedup through real
//! decompressor payload streams, copy-on-write splits on divergence,
//! entry release on lane drop, and the population-scale memory bound a
//! 1k-client simulation must satisfy (server basis state ≪ clients ×
//! basis bytes). Native backend: hermetic, no artifacts needed.

use gradestc::compress::gradestc::basis_bytes_per_lane;
use gradestc::compress::{
    BasisPool, Compressor as _, Decompressor as _, GradEstcClient, GradEstcServer,
};
use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, ModelKind, NetConfig, SchedConfig,
};
use gradestc::coordinator::Simulation;
use gradestc::model::meta::{layer_table, ModelMeta};
use gradestc::util::rng::Pcg64;

fn params(k: usize) -> GradEstcParams {
    GradEstcParams { k, ..Default::default() }
}

fn random_update(meta: &ModelMeta, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect()
}

/// N server lanes receiving bit-identical payload streams must share one
/// pool entry per compressed layer — the "shared basis costs one
/// allocation" half of the tentpole — through init, incremental
/// replacements, and the COW churn they cause.
#[test]
fn lanes_on_identical_payload_streams_share_pool_entries() {
    let meta = layer_table(ModelKind::LeNet5);
    let p = params(8);
    let pool = BasisPool::new();
    let mut client = GradEstcClient::new(&meta, p.clone(), 3);
    let mut servers: Vec<GradEstcServer> = (0..16)
        .map(|_| GradEstcServer::with_pool(&meta, p.clone(), pool.clone()))
        .collect();

    let mut rng = Pcg64::seeded(41);
    for _round in 0..3 {
        let update = random_update(&meta, &mut rng);
        let (payloads, _) = client.compress(&update);
        for s in &mut servers {
            let _ = s.decode(payloads.clone());
        }
    }

    let nlayers = client.compressed_tensors().len();
    assert!(nlayers > 0);
    let stats = pool.stats();
    assert_eq!(
        stats.entries, nlayers,
        "16 lanes on one payload stream must pool to one entry per layer"
    );
    // Memory is one lane's basis set, not sixteen — and stale COW
    // generations from the replacement rounds were all released.
    assert_eq!(stats.bytes(), basis_bytes_per_lane(&meta, &p));
    // Sharing is real lockstep: every lane fingerprints identically to
    // the client, and references exactly one lane's worth of bytes.
    for s in &servers {
        assert_eq!(s.state_fingerprint(), client.state_fingerprint());
        assert_eq!(s.referenced_basis_bytes(), basis_bytes_per_lane(&meta, &p));
    }
}

/// A lane receiving a different update must copy-on-write its own entry
/// without disturbing lanes still on the shared one.
#[test]
fn divergent_update_splits_cow_entry() {
    let meta = layer_table(ModelKind::LeNet5);
    // replace_all guarantees every compressed layer's basis changes every
    // round, so divergence is total and deterministic.
    let p = GradEstcParams { k: 8, replace_all: true, ..Default::default() };
    let pool = BasisPool::new();
    let mut client_a = GradEstcClient::new(&meta, p.clone(), 3);
    let mut client_b = GradEstcClient::new(&meta, p.clone(), 99);
    let mut server_a = GradEstcServer::with_pool(&meta, p.clone(), pool.clone());
    let mut server_b = GradEstcServer::with_pool(&meta, p.clone(), pool.clone());

    let mut rng = Pcg64::seeded(42);
    // Round 1: identical stream to both lanes — fully shared. (B's client
    // advances on its own sketch RNG, so it is briefly out of lockstep
    // with server B; round 2's replace_all overwrites every basis column,
    // restoring the pairing — the test's final fingerprint checks rely on
    // that.)
    let shared = random_update(&meta, &mut rng);
    let (payloads, _) = client_a.compress(&shared);
    let _ = client_b.compress(&shared);
    let _ = server_a.decode(payloads.clone());
    let _ = server_b.decode(payloads);
    let nlayers = client_a.compressed_tensors().len();
    assert_eq!(pool.stats().entries, nlayers, "round 1 must be fully shared");

    // Round 2: B sees a different update — every shared entry must split.
    let (pa, _) = client_a.compress(&random_update(&meta, &mut rng));
    let (pb, _) = client_b.compress(&random_update(&meta, &mut rng));
    let _ = server_a.decode(pa);
    let _ = server_b.decode(pb);
    let stats = pool.stats();
    assert_eq!(stats.entries, 2 * nlayers, "divergence must split every entry");
    assert_eq!(stats.bytes(), 2 * basis_bytes_per_lane(&meta, &p));
    assert_ne!(server_a.state_fingerprint(), server_b.state_fingerprint());
    // Each lane still pairs with its own client.
    assert_eq!(server_a.state_fingerprint(), client_a.state_fingerprint());
    assert_eq!(server_b.state_fingerprint(), client_b.state_fingerprint());
}

/// Dropping a lane must release its pool entries: the pool holds weak
/// references only, so refcount zero ⇒ entry gone, no retention.
#[test]
fn dropping_lanes_releases_pool_entries() {
    let meta = layer_table(ModelKind::LeNet5);
    let p = GradEstcParams { k: 8, replace_all: true, ..Default::default() };
    let pool = BasisPool::new();
    let mut lanes: Vec<(GradEstcClient, GradEstcServer)> = (0..4)
        .map(|i| {
            (
                GradEstcClient::new(&meta, p.clone(), 7 + i),
                GradEstcServer::with_pool(&meta, p.clone(), pool.clone()),
            )
        })
        .collect();
    let mut rng = Pcg64::seeded(43);
    for (client, server) in &mut lanes {
        let (payloads, _) = client.compress(&random_update(&meta, &mut rng));
        let _ = server.decode(payloads);
    }
    let nlayers = lanes[0].0.compressed_tensors().len();
    assert_eq!(pool.stats().entries, 4 * nlayers, "distinct lanes, distinct entries");

    lanes.truncate(1);
    assert_eq!(pool.stats().entries, nlayers, "dropped lanes must release entries");
    lanes.clear();
    assert_eq!(pool.stats().entries, 0, "empty population, empty pool");
    assert_eq!(pool.stats().bytes(), 0);
}

/// The population-scale acceptance bar: a 1000-client GradESTC simulation
/// with sampled participation holds server basis state for the lanes that
/// actually participated — far below the naive `clients × basis` the
/// pre-pool per-lane model paid — while per-lane lockstep still holds.
#[test]
fn thousand_client_server_state_is_far_below_naive() {
    let clients = 1000usize;
    let per_round = 50usize;
    let rounds = 2usize;
    let cfg = ExperimentConfig {
        name: "it-intern-1k".into(),
        dataset: DatasetKind::SynthMnist,
        model: ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: clients,
        participation: per_round as f64 / clients as f64,
        rounds,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 2,
        test_samples: 32,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: CompressorKind::GradEstc(params(8)),
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 0,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    };
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run().unwrap();

    let per_lane = basis_bytes_per_lane(&layer_table(ModelKind::LeNet5), &params(8));
    let pool = sim.basis_pool_stats();
    let naive = clients * per_lane;
    assert!(pool.entries > 0, "participants must have interned bases");
    // At most `per_round × rounds` distinct lanes ever decoded a payload,
    // so resident basis memory is bounded by the participant count…
    assert!(
        pool.bytes() <= per_round * rounds * per_lane,
        "pool holds {} bytes, more than {} participants' worth",
        pool.bytes(),
        per_round * rounds
    );
    // …which is an order of magnitude under the naive per-client model.
    assert!(
        pool.bytes() * 10 <= naive,
        "pool {} bytes not ≪ naive {} bytes (1000 × {per_lane})",
        pool.bytes(),
        naive
    );
    // Lockstep is untouched by interning: every lane's paired
    // fingerprints agree (participants and never-sampled lanes alike).
    for (cid, (client_fp, server_fp)) in sim.lane_fingerprints().iter().enumerate() {
        assert_eq!(client_fp, server_fp, "client {cid}: lane state diverged");
    }
}
