//! Property-based tests on L3 invariants (custom `util::prop` framework —
//! proptest is unavailable offline).
//!
//! Covered invariants:
//! * codec round-trips (quantization error bound, top-k support recovery)
//! * GradESTC basis orthonormality + client/server lockstep on random
//!   streams (not just the friendly low-rank streams in unit tests)
//! * partitioner: exact cover, no starvation, for arbitrary shapes
//! * contribution-scoring consistency: replacement count == |ℙ| == |𝕄|

use gradestc::compress::codec::{pack_bits, unpack_bits};
use gradestc::compress::{Compressor, Decompressor, GradEstcClient, GradEstcServer, Payload};
use gradestc::config::{DataDistribution, GradEstcParams, ModelKind};
use gradestc::data::partition_indices;
use gradestc::linalg::ortho_defect;
use gradestc::model::meta::layer_table;
use gradestc::util::prop::{check, Gen, IntRange, Pair};
use gradestc::util::rng::Pcg64;

/// Generator for (seed, rounds) driving a random compression stream.
struct StreamGen;

impl Gen for StreamGen {
    type Value = (u64, usize);
    fn generate(&self, rng: &mut Pcg64) -> (u64, usize) {
        (rng.next_u64(), 2 + rng.index(6))
    }
    fn shrink(&self, v: &(u64, usize)) -> Vec<(u64, usize)> {
        let mut out = Vec::new();
        if v.1 > 2 {
            out.push((v.0, v.1 - 1));
            out.push((v.0, 2));
        }
        out
    }
}

fn random_update(meta: &gradestc::model::meta::ModelMeta, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    meta.layers
        .iter()
        .map(|l| {
            let mut v = rng.normal_vec(l.size());
            let scale = 0.01 + rng.f32();
            v.iter_mut().for_each(|x| *x *= scale);
            v
        })
        .collect()
}

#[test]
fn prop_gradestc_lockstep_and_orthonormal_on_random_streams() {
    let meta = layer_table(ModelKind::LeNet5);
    check("gradestc_lockstep", 0xA11CE, 12, &StreamGen, |&(seed, rounds)| {
        let params = GradEstcParams { k: 8, ..Default::default() };
        let mut c = GradEstcClient::new(&meta, params.clone(), seed);
        let mut s = GradEstcServer::new(&meta, params);
        let mut rng = Pcg64::seeded(seed ^ 0x5EED);
        for _ in 0..rounds {
            let update = random_update(&meta, &mut rng);
            let (payloads, _) = c.compress(&update);
            let rec = s.decompress(&payloads);
            // Reconstruction must be finite and tensor-aligned.
            if rec.len() != update.len() {
                return false;
            }
            if rec
                .iter()
                .flat_map(|t| t.iter())
                .any(|x| !x.is_finite())
            {
                return false;
            }
            // Replacement-set consistency: |ℙ| · l == |𝕄 vectors|.
            for p in &payloads {
                if let Payload::Basis { replace_idx, new_vectors, l, k, .. } = p {
                    if new_vectors.len() != replace_idx.len() * l {
                        return false;
                    }
                    if replace_idx.iter().any(|&i| i as usize >= *k) {
                        return false;
                    }
                    // indices must be unique
                    let mut sorted = replace_idx.clone();
                    sorted.sort_unstable();
                    sorted.dedup();
                    if sorted.len() != replace_idx.len() {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_gradestc_basis_defect_bounded() {
    // Even on adversarial (pure-noise) streams the maintained basis must
    // stay numerically orthonormal (Eq. 7-9 + periodic MGS repair).
    let meta = layer_table(ModelKind::LeNet5);
    check("basis_defect", 0xB0B, 6, &IntRange { lo: 3, hi: 40 }, |&rounds| {
        let params = GradEstcParams { k: 8, ..Default::default() };
        let mut c = GradEstcClient::new(&meta, params.clone(), 77);
        let mut s = GradEstcServer::new(&meta, params);
        let mut rng = Pcg64::seeded(rounds as u64);
        for _ in 0..rounds {
            let update = random_update(&meta, &mut rng);
            let (payloads, _) = c.compress(&update);
            let _ = s.decompress(&payloads);
        }
        c.basis_matrices().iter().all(|m| ortho_defect(m) < 1e-2)
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    let gen = Pair(IntRange { lo: 1, hi: 16 }, IntRange { lo: 1, hi: 800 });
    check("pack_roundtrip", 0xBEEF, 60, &gen, |&(bits, n)| {
        let mut rng = Pcg64::seeded((bits * 1000 + n) as u64);
        let max = (1u64 << bits) - 1;
        let codes: Vec<u32> = (0..n).map(|_| (rng.below(max + 1)) as u32).collect();
        let packed = pack_bits(&codes, bits as u8);
        unpack_bits(&packed, bits as u8, n) == codes
    });
}

#[test]
fn prop_partition_exact_cover() {
    let gen = Pair(IntRange { lo: 2, hi: 40 }, IntRange { lo: 50, hi: 2000 });
    check("partition_cover", 0xCAFE, 40, &gen, |&(clients, samples)| {
        if samples < clients {
            return true; // precondition
        }
        let mut rng = Pcg64::seeded((clients * 7 + samples) as u64);
        let labels: Vec<u32> = (0..samples).map(|_| rng.index(10) as u32).collect();
        for dist in [
            DataDistribution::Iid,
            DataDistribution::Dirichlet(0.5),
            DataDistribution::Dirichlet(0.1),
        ] {
            let p = partition_indices(&labels, 10, clients, dist, &mut rng);
            let mut all: Vec<usize> =
                p.assignments.iter().flatten().copied().collect();
            all.sort_unstable();
            if all != (0..samples).collect::<Vec<_>>() {
                return false;
            }
            if p.assignments.iter().any(|a| a.is_empty()) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_quantizer_error_within_step() {
    use gradestc::compress::quant::{QuantCompressor, QuantDecompressor};
    let meta = layer_table(ModelKind::LeNet5);
    check("quant_error", 0xDEAD, 15, &IntRange { lo: 2, hi: 12 }, |&bits| {
        let mut rng = Pcg64::seeded(bits as u64 * 31);
        let update = random_update(&meta, &mut rng);
        let mut c = QuantCompressor::new(&meta, bits as u8, None, 5);
        let mut d = QuantDecompressor::new(&meta);
        let (payloads, _) = c.compress(&update);
        let rec = d.decompress(&payloads);
        for ((orig, r), p) in update.iter().zip(&rec).zip(&payloads) {
            if let Payload::Quantized { lo, hi, .. } = p {
                let step = (hi - lo) / ((1u32 << bits) - 1) as f32;
                for (o, v) in orig.iter().zip(r) {
                    if (o - v).abs() > step + 1e-5 {
                        return false;
                    }
                }
            }
        }
        true
    });
}
