//! Property-based tests on L3 invariants (custom `util::prop` framework —
//! proptest is unavailable offline).
//!
//! Covered invariants:
//! * codec round-trips (quantization error bound, top-k support recovery)
//! * wire format: `encode([p]).len() == p.wire_bytes()` and bit-exact
//!   decode for every payload variant, including bit-packing edge cases
//! * GradESTC basis orthonormality + client/server lockstep on random
//!   streams (not just the friendly low-rank streams in unit tests)
//! * partitioner: exact cover, no starvation, for arbitrary shapes
//! * contribution-scoring consistency: replacement count == |ℙ| == |𝕄|

use gradestc::compress::codec::{pack_bits, unpack_bits};
use gradestc::compress::{Compressor, Decompressor, GradEstcClient, GradEstcServer, Payload};
use gradestc::config::{DataDistribution, GradEstcParams, ModelKind};
use gradestc::data::partition_indices;
use gradestc::linalg::ortho_defect;
use gradestc::model::meta::layer_table;
use gradestc::net::wire;
use gradestc::util::prop::{check, Gen, IntRange, Pair};
use gradestc::util::rng::Pcg64;

/// Generator for (seed, rounds) driving a random compression stream.
struct StreamGen;

impl Gen for StreamGen {
    type Value = (u64, usize);
    fn generate(&self, rng: &mut Pcg64) -> (u64, usize) {
        (rng.next_u64(), 2 + rng.index(6))
    }
    fn shrink(&self, v: &(u64, usize)) -> Vec<(u64, usize)> {
        let mut out = Vec::new();
        if v.1 > 2 {
            out.push((v.0, v.1 - 1));
            out.push((v.0, 2));
        }
        out
    }
}

fn random_update(meta: &gradestc::model::meta::ModelMeta, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    meta.layers
        .iter()
        .map(|l| {
            let mut v = rng.normal_vec(l.size());
            let scale = 0.01 + rng.f32();
            v.iter_mut().for_each(|x| *x *= scale);
            v
        })
        .collect()
}

#[test]
fn prop_gradestc_lockstep_and_orthonormal_on_random_streams() {
    let meta = layer_table(ModelKind::LeNet5);
    check("gradestc_lockstep", 0xA11CE, 12, &StreamGen, |&(seed, rounds)| {
        let params = GradEstcParams { k: 8, ..Default::default() };
        let mut c = GradEstcClient::new(&meta, params.clone(), seed);
        let mut s = GradEstcServer::new(&meta, params);
        let mut rng = Pcg64::seeded(seed ^ 0x5EED);
        for _ in 0..rounds {
            let update = random_update(&meta, &mut rng);
            let (payloads, _) = c.compress(&update);
            let rec = s.decompress(&payloads);
            // Reconstruction must be finite and tensor-aligned.
            if rec.len() != update.len() {
                return false;
            }
            if rec
                .iter()
                .flat_map(|t| t.iter())
                .any(|x| !x.is_finite())
            {
                return false;
            }
            // Replacement-set consistency: |ℙ| · l == |𝕄 vectors|.
            for p in &payloads {
                if let Payload::Basis { replace_idx, new_vectors, l, k, .. } = p {
                    if new_vectors.len() != replace_idx.len() * l {
                        return false;
                    }
                    if replace_idx.iter().any(|&i| i as usize >= *k) {
                        return false;
                    }
                    // indices must be unique
                    let mut sorted = replace_idx.clone();
                    sorted.sort_unstable();
                    sorted.dedup();
                    if sorted.len() != replace_idx.len() {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_gradestc_basis_defect_bounded() {
    // Even on adversarial (pure-noise) streams the maintained basis must
    // stay numerically orthonormal (Eq. 7-9 + periodic MGS repair).
    let meta = layer_table(ModelKind::LeNet5);
    check("basis_defect", 0xB0B, 6, &IntRange { lo: 3, hi: 40 }, |&rounds| {
        let params = GradEstcParams { k: 8, ..Default::default() };
        let mut c = GradEstcClient::new(&meta, params.clone(), 77);
        let mut s = GradEstcServer::new(&meta, params);
        let mut rng = Pcg64::seeded(rounds as u64);
        for _ in 0..rounds {
            let update = random_update(&meta, &mut rng);
            let (payloads, _) = c.compress(&update);
            let _ = s.decompress(&payloads);
        }
        c.basis_matrices().iter().all(|m| ortho_defect(m) < 1e-2)
    });
}

/// Build one payload of the given variant with pseudo-random contents.
/// `n` drives lengths (deliberately including n % 8 ≠ 0) and `bits` the
/// quantizer width / basis segment length.
fn make_payload(variant: usize, n: usize, bits: usize) -> Payload {
    let mut rng = Pcg64::seeded((variant * 1_000_000 + n * 31 + bits) as u64);
    match variant {
        0 => Payload::Raw(rng.normal_vec(n)),
        1 => {
            let pairs = (n / 4).max(1).min(n);
            let indices: Vec<u32> = {
                let mut idx = rng.sample_indices(n, pairs);
                idx.sort_unstable();
                idx.into_iter().map(|i| i as u32).collect()
            };
            Payload::Sparse { indices, values: rng.normal_vec(pairs), len: n }
        }
        2 => {
            let max = (1u64 << bits) - 1;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max + 1) as u32).collect();
            Payload::Quantized {
                lo: -1.0,
                hi: 1.0,
                bits: bits as u8,
                packed: pack_bits(&codes, bits as u8),
                len: n,
            }
        }
        3 => {
            let codes: Vec<u32> = (0..n).map(|_| rng.index(2) as u32).collect();
            Payload::Signs { scale: 0.5, packed: pack_bits(&codes, 1), len: n }
        }
        4 => {
            let (l, k, m) = (bits, (n % 7) + 1, (n % 5) + 1);
            let d = n % (k + 1); // 0..=k replacements
            Payload::Basis {
                replace_idx: (0..d as u32).collect(),
                new_vectors: rng.normal_vec(d * l),
                coeffs: rng.normal_vec(k * m),
                l,
                k,
                m,
            }
        }
        _ => {
            let (l, k, m) = (bits, (n % 6) + 1, (n % 4) + 1);
            let refit = (n % 2 == 0).then(|| rng.normal_vec(k * l));
            Payload::SvdCoeffs { coeffs: rng.normal_vec(k * m), refit_basis: refit, l, k, m }
        }
    }
}

/// Satellite acceptance: the encoded length of every payload variant equals
/// `wire_bytes()` exactly, and decode is a bit-exact inverse — across
/// random lengths (including len % 8 ≠ 0) and every bit width 1..=16.
#[test]
fn prop_wire_encode_length_equals_wire_bytes() {
    let gen = Pair(
        IntRange { lo: 0, hi: 5 },                                  // variant
        Pair(IntRange { lo: 1, hi: 700 }, IntRange { lo: 1, hi: 16 }), // (n, bits)
    );
    check("wire_len_identity", 0x5EED_CAFE, 150, &gen, |&(variant, (n, bits))| {
        let p = make_payload(variant, n, bits);
        let buf = wire::encode(std::slice::from_ref(&p));
        buf.len() as u64 == p.wire_bytes()
            && wire::decode(&buf).map(|back| back == vec![p]).unwrap_or(false)
    });
}

/// The edge cases the satellite calls out, pinned explicitly: 1-bit and
/// 16-bit packing at lengths straddling byte boundaries.
#[test]
fn wire_bitpacking_edges_exact() {
    for &len in &[1usize, 7, 8, 9, 15, 16, 17, 63, 65] {
        for &bits in &[1usize, 16] {
            let p = make_payload(2, len, bits);
            let buf = wire::encode(std::slice::from_ref(&p));
            assert_eq!(buf.len() as u64, p.wire_bytes(), "quantized len={len} bits={bits}");
            assert_eq!(wire::decode(&buf).unwrap(), vec![p]);
        }
        let s = make_payload(3, len, 1);
        let buf = wire::encode(std::slice::from_ref(&s));
        assert_eq!(buf.len() as u64, s.wire_bytes(), "signs len={len}");
        assert_eq!(wire::decode(&buf).unwrap(), vec![s]);
    }
}

/// End-to-end over a real compressor stream: everything GradESTC emits —
/// init-round Basis refreshes, steady-state coefficient rounds, raw
/// passthrough tensors — survives encode→decode bit-exactly with the
/// claimed lengths.
#[test]
fn prop_wire_roundtrips_gradestc_stream() {
    let meta = layer_table(ModelKind::LeNet5);
    check("wire_gradestc_stream", 0x31A7, 8, &StreamGen, |&(seed, rounds)| {
        let params = GradEstcParams { k: 8, ..Default::default() };
        let mut c = GradEstcClient::new(&meta, params, seed);
        let mut rng = Pcg64::seeded(seed ^ 0xF00D);
        for _ in 0..rounds {
            let update = random_update(&meta, &mut rng);
            let (payloads, _) = c.compress(&update);
            let buf = wire::encode(&payloads);
            let claimed: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
            if buf.len() as u64 != claimed {
                return false;
            }
            match wire::decode(&buf) {
                Ok(back) if back == payloads => {}
                _ => return false,
            }
        }
        true
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    let gen = Pair(IntRange { lo: 1, hi: 16 }, IntRange { lo: 1, hi: 800 });
    check("pack_roundtrip", 0xBEEF, 60, &gen, |&(bits, n)| {
        let mut rng = Pcg64::seeded((bits * 1000 + n) as u64);
        let max = (1u64 << bits) - 1;
        let codes: Vec<u32> = (0..n).map(|_| (rng.below(max + 1)) as u32).collect();
        let packed = pack_bits(&codes, bits as u8);
        unpack_bits(&packed, bits as u8, n) == codes
    });
}

#[test]
fn prop_partition_exact_cover() {
    let gen = Pair(IntRange { lo: 2, hi: 40 }, IntRange { lo: 50, hi: 2000 });
    check("partition_cover", 0xCAFE, 40, &gen, |&(clients, samples)| {
        if samples < clients {
            return true; // precondition
        }
        let mut rng = Pcg64::seeded((clients * 7 + samples) as u64);
        let labels: Vec<u32> = (0..samples).map(|_| rng.index(10) as u32).collect();
        for dist in [
            DataDistribution::Iid,
            DataDistribution::Dirichlet(0.5),
            DataDistribution::Dirichlet(0.1),
        ] {
            let p = partition_indices(&labels, 10, clients, dist, &mut rng);
            let mut all: Vec<usize> =
                p.assignments.iter().flatten().copied().collect();
            all.sort_unstable();
            if all != (0..samples).collect::<Vec<_>>() {
                return false;
            }
            if p.assignments.iter().any(|a| a.is_empty()) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_quantizer_error_within_step() {
    use gradestc::compress::quant::{QuantCompressor, QuantDecompressor};
    let meta = layer_table(ModelKind::LeNet5);
    check("quant_error", 0xDEAD, 15, &IntRange { lo: 2, hi: 12 }, |&bits| {
        let mut rng = Pcg64::seeded(bits as u64 * 31);
        let update = random_update(&meta, &mut rng);
        let mut c = QuantCompressor::new(&meta, bits as u8, None, 5);
        let mut d = QuantDecompressor::new(&meta);
        let (payloads, _) = c.compress(&update);
        let rec = d.decompress(&payloads);
        for ((orig, r), p) in update.iter().zip(&rec).zip(&payloads) {
            if let Payload::Quantized { lo, hi, .. } = p {
                let step = (hi - lo) / ((1u32 << bits) - 1) as f32;
                for (o, v) in orig.iter().zip(r) {
                    if (o - v).abs() > step + 1e-5 {
                        return false;
                    }
                }
            }
        }
        true
    });
}
