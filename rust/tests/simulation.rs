//! Integration tests over the full coordinator (native backend: hermetic,
//! no artifacts needed).

use gradestc::config::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
};
use gradestc::coordinator::Simulation;

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 6,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn fedavg_learns() {
    let mut sim = Simulation::build(base_cfg("it-fedavg", CompressorKind::None)).unwrap();
    let report = sim.run().unwrap();
    assert!(
        report.best_accuracy > 0.5,
        "fedavg best acc {}",
        report.best_accuracy
    );
    // First and last eval must show improvement.
    let rounds = sim.recorder.rounds();
    assert!(rounds.last().unwrap().test_accuracy > rounds[0].test_accuracy);
}

#[test]
fn every_compressor_trains_end_to_end() {
    let comps = vec![
        CompressorKind::TopK { frac: 0.1 },
        CompressorKind::FedPaq { bits: 8 },
        CompressorKind::SignSgd,
        CompressorKind::SvdFed { k: 8, gamma: 0.6 },
        CompressorKind::FedQClip { bits: 8, clip: 2.5 },
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        CompressorKind::GradEstc(GradEstcParams {
            k: 8,
            error_feedback: true,
            ..Default::default()
        }),
    ];
    for comp in comps {
        let name = comp.name().to_string();
        let mut cfg = base_cfg(&format!("it-{name}"), comp);
        cfg.rounds = 5;
        let mut sim = Simulation::build(cfg).unwrap();
        let report = sim.run().unwrap();
        assert!(
            report.best_accuracy > 0.35,
            "{name}: best acc {} too low",
            report.best_accuracy
        );
        assert!(report.total_uplink > 0);
    }
}

#[test]
fn gradestc_beats_fedavg_on_uplink_with_comparable_accuracy() {
    let mut fa = Simulation::build(base_cfg("it-cmp-fedavg", CompressorKind::None)).unwrap();
    let r_fa = fa.run().unwrap();
    let mut ge = Simulation::build(base_cfg(
        "it-cmp-gradestc",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    ))
    .unwrap();
    let r_ge = ge.run().unwrap();
    assert!(
        (r_ge.total_uplink as f64) < 0.5 * r_fa.total_uplink as f64,
        "gradestc uplink {} not ≪ fedavg {}",
        r_ge.total_uplink,
        r_fa.total_uplink
    );
    assert!(
        r_ge.best_accuracy > r_fa.best_accuracy - 0.08,
        "gradestc acc {} vs fedavg {}",
        r_ge.best_accuracy,
        r_fa.best_accuracy
    );
}

#[test]
fn uplink_accounting_consistent() {
    let mut sim = Simulation::build(base_cfg(
        "it-accounting",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    ))
    .unwrap();
    let report = sim.run().unwrap();
    // Ledger total == Σ per-round records == report total.
    let per_round: u64 = sim.recorder.rounds().iter().map(|r| r.uplink_bytes).sum();
    assert_eq!(per_round, report.total_uplink);
    assert_eq!(sim.total_uplink(), report.total_uplink);
    // Downlink: broadcast × participants × rounds.
    let expect_down = (4 * sim.global.numel() as u64) * 4 * 6;
    let down: u64 = sim.recorder.rounds().iter().map(|r| r.downlink_bytes).sum();
    assert_eq!(down, expect_down);
}

#[test]
fn partial_participation_runs() {
    let mut cfg = base_cfg("it-partial", CompressorKind::None);
    cfg.num_clients = 10;
    cfg.participation = 0.3;
    cfg.rounds = 4;
    let mut sim = Simulation::build(cfg).unwrap();
    let report = sim.run().unwrap();
    // 3 of 10 clients → uplink ≈ 3 × model bytes per round.
    let model_bytes = 4 * sim.global.numel() as u64;
    let per_round = sim.recorder.rounds()[0].uplink_bytes;
    let overhead = 4 * 10 * 8; // payload frame headers
    assert!(per_round <= 3 * model_bytes + overhead, "{per_round} vs {model_bytes}");
    assert!(report.total_uplink > 0);
}

#[test]
fn noniid_degrades_gracefully() {
    let mut iid = base_cfg("it-iid", CompressorKind::None);
    iid.rounds = 5;
    let mut skew = iid.clone();
    skew.name = "it-skew".into();
    skew.distribution = DataDistribution::Dirichlet(0.1);
    let r_iid = Simulation::build(iid).unwrap().run().unwrap();
    let r_skew = Simulation::build(skew).unwrap().run().unwrap();
    // Non-IID must still learn (well above chance), even if slower.
    assert!(r_skew.best_accuracy > 0.3, "non-iid acc {}", r_skew.best_accuracy);
    assert!(r_iid.best_accuracy >= r_skew.best_accuracy - 0.05);
}

#[test]
fn deterministic_given_seed() {
    let run = || {
        let mut sim = Simulation::build(base_cfg(
            "it-det",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ))
        .unwrap();
        sim.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_uplink, b.total_uplink);
    assert!((a.best_accuracy - b.best_accuracy).abs() < 1e-12);
}

#[test]
fn config_roundtrips_through_json_and_rebuilds() {
    let cfg = base_cfg(
        "it-json",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    let j = cfg.to_json().to_pretty();
    let parsed =
        ExperimentConfig::from_json(&gradestc::config::Json::parse(&j).unwrap()).unwrap();
    assert_eq!(cfg, parsed);
    // And the parsed config still builds a working simulation.
    let mut sim = Simulation::build(parsed).unwrap();
    let rec = sim.step(0).unwrap();
    assert!(rec.train_loss.is_finite());
}
