//! Integration tests over the full coordinator (native backend: hermetic,
//! no artifacts needed).

use gradestc::config::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
};
use gradestc::coordinator::{Simulation, Simulation2Hook};
use gradestc::metrics::RoundRecord;

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 6,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
    }
}

#[test]
fn fedavg_learns() {
    let mut sim = Simulation::build(base_cfg("it-fedavg", CompressorKind::None)).unwrap();
    let report = sim.run().unwrap();
    assert!(
        report.best_accuracy > 0.5,
        "fedavg best acc {}",
        report.best_accuracy
    );
    // First and last eval must show improvement.
    let rounds = sim.recorder.rounds();
    assert!(rounds.last().unwrap().test_accuracy > rounds[0].test_accuracy);
}

#[test]
fn every_compressor_trains_end_to_end() {
    let comps = vec![
        CompressorKind::TopK { frac: 0.1 },
        CompressorKind::FedPaq { bits: 8 },
        CompressorKind::SignSgd,
        CompressorKind::SvdFed { k: 8, gamma: 0.6 },
        CompressorKind::FedQClip { bits: 8, clip: 2.5 },
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        CompressorKind::GradEstc(GradEstcParams {
            k: 8,
            error_feedback: true,
            ..Default::default()
        }),
    ];
    for comp in comps {
        let name = comp.name().to_string();
        let mut cfg = base_cfg(&format!("it-{name}"), comp);
        cfg.rounds = 5;
        let mut sim = Simulation::build(cfg).unwrap();
        let report = sim.run().unwrap();
        assert!(
            report.best_accuracy > 0.35,
            "{name}: best acc {} too low",
            report.best_accuracy
        );
        assert!(report.total_uplink > 0);
    }
}

#[test]
fn gradestc_beats_fedavg_on_uplink_with_comparable_accuracy() {
    let mut fa = Simulation::build(base_cfg("it-cmp-fedavg", CompressorKind::None)).unwrap();
    let r_fa = fa.run().unwrap();
    let mut ge = Simulation::build(base_cfg(
        "it-cmp-gradestc",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    ))
    .unwrap();
    let r_ge = ge.run().unwrap();
    assert!(
        (r_ge.total_uplink as f64) < 0.5 * r_fa.total_uplink as f64,
        "gradestc uplink {} not ≪ fedavg {}",
        r_ge.total_uplink,
        r_fa.total_uplink
    );
    assert!(
        r_ge.best_accuracy > r_fa.best_accuracy - 0.08,
        "gradestc acc {} vs fedavg {}",
        r_ge.best_accuracy,
        r_fa.best_accuracy
    );
}

#[test]
fn uplink_accounting_consistent() {
    let mut sim = Simulation::build(base_cfg(
        "it-accounting",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    ))
    .unwrap();
    let report = sim.run().unwrap();
    // Ledger total == Σ per-round records == report total.
    let per_round: u64 = sim.recorder.rounds().iter().map(|r| r.uplink_bytes).sum();
    assert_eq!(per_round, report.total_uplink);
    assert_eq!(sim.total_uplink(), report.total_uplink);
    // Downlink: broadcast × participants × rounds.
    let expect_down = (4 * sim.global.numel() as u64) * 4 * 6;
    let down: u64 = sim.recorder.rounds().iter().map(|r| r.downlink_bytes).sum();
    assert_eq!(down, expect_down);
}

#[test]
fn partial_participation_runs() {
    let mut cfg = base_cfg("it-partial", CompressorKind::None);
    cfg.num_clients = 10;
    cfg.participation = 0.3;
    cfg.rounds = 4;
    let mut sim = Simulation::build(cfg).unwrap();
    let report = sim.run().unwrap();
    // 3 of 10 clients → uplink ≈ 3 × model bytes per round.
    let model_bytes = 4 * sim.global.numel() as u64;
    let per_round = sim.recorder.rounds()[0].uplink_bytes;
    let overhead = 4 * 10 * 8; // payload frame headers
    assert!(per_round <= 3 * model_bytes + overhead, "{per_round} vs {model_bytes}");
    assert!(report.total_uplink > 0);
}

#[test]
fn noniid_degrades_gracefully() {
    let mut iid = base_cfg("it-iid", CompressorKind::None);
    iid.rounds = 5;
    let mut skew = iid.clone();
    skew.name = "it-skew".into();
    skew.distribution = DataDistribution::Dirichlet(0.1);
    let r_iid = Simulation::build(iid).unwrap().run().unwrap();
    let r_skew = Simulation::build(skew).unwrap().run().unwrap();
    // Non-IID must still learn (well above chance), even if slower.
    assert!(r_skew.best_accuracy > 0.3, "non-iid acc {}", r_skew.best_accuracy);
    assert!(r_iid.best_accuracy >= r_skew.best_accuracy - 0.05);
}

#[test]
fn deterministic_given_seed() {
    let run = || {
        let mut sim = Simulation::build(base_cfg(
            "it-det",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ))
        .unwrap();
        sim.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_uplink, b.total_uplink);
    assert!((a.best_accuracy - b.best_accuracy).abs() < 1e-12);
}

/// Assert two round traces are bit-identical (floats compared by bits so
/// NaN evals also count as equal).
fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round, "{label}");
        let r = x.round;
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{label}: train_loss, round {r}"
        );
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: test_accuracy, round {r}"
        );
        assert_eq!(
            x.test_loss.to_bits(),
            y.test_loss.to_bits(),
            "{label}: test_loss, round {r}"
        );
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(
            x.sim_time_s.to_bits(),
            y.sim_time_s.to_bits(),
            "{label}: sim_time, round {r}"
        );
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
    }
}

/// Run a config at a given worker count, returning the full round trace
/// plus the summary report.
fn run_with_workers(
    mut cfg: ExperimentConfig,
    workers: usize,
) -> (Vec<RoundRecord>, gradestc::metrics::RunReport) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    let report = sim.run().unwrap();
    (sim.recorder.rounds().to_vec(), report)
}

/// Tentpole acceptance: the parallel round engine is bit-deterministic in
/// the worker count for the paper's method (per-client compressor state —
/// the GradESTC basis — must evolve in lockstep at any parallelism).
#[test]
fn parallel_engine_bit_identical_gradestc() {
    let mut cfg = base_cfg(
        "it-par-gradestc",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    // Partial participation so lane extraction sees non-trivial subsets.
    cfg.num_clients = 8;
    cfg.participation = 0.5;
    cfg.rounds = 4;
    let (seq, seq_rep) = run_with_workers(cfg.clone(), 1);
    let (par, par_rep) = run_with_workers(cfg, 8);
    assert_rounds_bitwise_equal(&seq, &par, "gradestc w1 vs w8");
    assert_eq!(seq_rep.total_uplink, par_rep.total_uplink);
    assert_eq!(
        seq_rep.best_accuracy.to_bits(),
        par_rep.best_accuracy.to_bits()
    );
    assert_eq!(seq_rep.sum_d, par_rep.sum_d);
}

/// Same determinism bar for a stateless-baseline compressor (TopK).
#[test]
fn parallel_engine_bit_identical_topk() {
    let mut cfg = base_cfg("it-par-topk", CompressorKind::TopK { frac: 0.1 });
    cfg.rounds = 4;
    let (seq, seq_rep) = run_with_workers(cfg.clone(), 1);
    for workers in [2usize, 8] {
        let (par, par_rep) = run_with_workers(cfg.clone(), workers);
        assert_rounds_bitwise_equal(&seq, &par, &format!("topk w1 vs w{workers}"));
        assert_eq!(seq_rep.total_uplink, par_rep.total_uplink);
        assert_eq!(
            seq_rep.best_accuracy.to_bits(),
            par_rep.best_accuracy.to_bits()
        );
    }
}

/// `workers: 0` resolves to an automatic count and still runs fine.
#[test]
fn auto_workers_runs() {
    let mut cfg = base_cfg("it-auto-workers", CompressorKind::None);
    cfg.rounds = 2;
    cfg.workers = 0;
    let mut sim = Simulation::build(cfg).unwrap();
    let rec = sim.step(0).unwrap();
    assert!(rec.train_loss.is_finite());
}

/// A hook that panics must not be silently dropped: the next round still
/// invokes it (regression test for the old take()/put-back dance).
#[test]
fn round_hook_survives_panic() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = calls.clone();
    let mut cfg = base_cfg("it-hook-panic", CompressorKind::None);
    cfg.rounds = 3;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.set_round_hook(Box::new(move |round, _view: &Simulation2Hook| {
        calls2.fetch_add(1, Ordering::SeqCst);
        if round == 0 {
            panic!("hook bails on round 0");
        }
    }));
    // Round 0 panics inside the hook…
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sim.step(0)));
    assert!(caught.is_err());
    assert_eq!(calls.load(Ordering::SeqCst), 1);
    // …but the hook is still installed and fires on the next round.
    sim.step(1).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 2);
}

#[test]
fn config_roundtrips_through_json_and_rebuilds() {
    let cfg = base_cfg(
        "it-json",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    let j = cfg.to_json().to_pretty();
    let parsed =
        ExperimentConfig::from_json(&gradestc::config::Json::parse(&j).unwrap()).unwrap();
    assert_eq!(cfg, parsed);
    // And the parsed config still builds a working simulation.
    let mut sim = Simulation::build(parsed).unwrap();
    let rec = sim.step(0).unwrap();
    assert!(rec.train_loss.is_finite());
}
