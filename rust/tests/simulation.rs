//! Integration tests over the full coordinator (native backend: hermetic,
//! no artifacts needed).

use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig,
};
use gradestc::coordinator::{RoundHookView, Simulation};
use gradestc::metrics::RoundRecord;

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 6,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

#[test]
fn fedavg_learns() {
    let mut sim = Simulation::build(base_cfg("it-fedavg", CompressorKind::None)).unwrap();
    let report = sim.run().unwrap();
    assert!(
        report.best_accuracy > 0.5,
        "fedavg best acc {}",
        report.best_accuracy
    );
    // First and last eval must show improvement.
    let rounds = sim.recorder.rounds();
    assert!(rounds.last().unwrap().test_accuracy > rounds[0].test_accuracy);
}

#[test]
fn every_compressor_trains_end_to_end() {
    let comps = vec![
        CompressorKind::TopK { frac: 0.1 },
        CompressorKind::FedPaq { bits: 8 },
        CompressorKind::SignSgd,
        CompressorKind::SvdFed { k: 8, gamma: 0.6 },
        CompressorKind::FedQClip { bits: 8, clip: 2.5 },
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        CompressorKind::GradEstc(GradEstcParams {
            k: 8,
            error_feedback: true,
            ..Default::default()
        }),
    ];
    for comp in comps {
        let name = comp.name().to_string();
        let mut cfg = base_cfg(&format!("it-{name}"), comp);
        cfg.rounds = 5;
        let mut sim = Simulation::build(cfg).unwrap();
        let report = sim.run().unwrap();
        assert!(
            report.best_accuracy > 0.35,
            "{name}: best acc {} too low",
            report.best_accuracy
        );
        assert!(report.total_uplink > 0);
    }
}

#[test]
fn gradestc_beats_fedavg_on_uplink_with_comparable_accuracy() {
    let mut fa = Simulation::build(base_cfg("it-cmp-fedavg", CompressorKind::None)).unwrap();
    let r_fa = fa.run().unwrap();
    let mut ge = Simulation::build(base_cfg(
        "it-cmp-gradestc",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    ))
    .unwrap();
    let r_ge = ge.run().unwrap();
    assert!(
        (r_ge.total_uplink as f64) < 0.5 * r_fa.total_uplink as f64,
        "gradestc uplink {} not ≪ fedavg {}",
        r_ge.total_uplink,
        r_fa.total_uplink
    );
    assert!(
        r_ge.best_accuracy > r_fa.best_accuracy - 0.08,
        "gradestc acc {} vs fedavg {}",
        r_ge.best_accuracy,
        r_fa.best_accuracy
    );
}

#[test]
fn uplink_accounting_consistent() {
    let mut sim = Simulation::build(base_cfg(
        "it-accounting",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    ))
    .unwrap();
    let report = sim.run().unwrap();
    // Ledger total == Σ per-round records == report total.
    let per_round: u64 = sim.recorder.rounds().iter().map(|r| r.uplink_bytes).sum();
    assert_eq!(per_round, report.total_uplink);
    assert_eq!(sim.total_uplink(), report.total_uplink);
    // Downlink: broadcast × participants × rounds.
    let expect_down = (4 * sim.global.numel() as u64) * 4 * 6;
    let down: u64 = sim.recorder.rounds().iter().map(|r| r.downlink_bytes).sum();
    assert_eq!(down, expect_down);
}

#[test]
fn partial_participation_runs() {
    let mut cfg = base_cfg("it-partial", CompressorKind::None);
    cfg.num_clients = 10;
    cfg.participation = 0.3;
    cfg.rounds = 4;
    let mut sim = Simulation::build(cfg).unwrap();
    let report = sim.run().unwrap();
    // 3 of 10 clients → uplink ≈ 3 × model bytes per round.
    let model_bytes = 4 * sim.global.numel() as u64;
    let per_round = sim.recorder.rounds()[0].uplink_bytes;
    let overhead = 4 * 10 * 8; // payload frame headers
    assert!(per_round <= 3 * model_bytes + overhead, "{per_round} vs {model_bytes}");
    assert!(report.total_uplink > 0);
}

#[test]
fn noniid_degrades_gracefully() {
    let mut iid = base_cfg("it-iid", CompressorKind::None);
    iid.rounds = 5;
    let mut skew = iid.clone();
    skew.name = "it-skew".into();
    skew.distribution = DataDistribution::Dirichlet(0.1);
    let r_iid = Simulation::build(iid).unwrap().run().unwrap();
    let r_skew = Simulation::build(skew).unwrap().run().unwrap();
    // Non-IID must still learn (well above chance), even if slower.
    assert!(r_skew.best_accuracy > 0.3, "non-iid acc {}", r_skew.best_accuracy);
    assert!(r_iid.best_accuracy >= r_skew.best_accuracy - 0.05);
}

#[test]
fn deterministic_given_seed() {
    let run = || {
        let mut sim = Simulation::build(base_cfg(
            "it-det",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ))
        .unwrap();
        sim.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_uplink, b.total_uplink);
    assert!((a.best_accuracy - b.best_accuracy).abs() < 1e-12);
}

/// Assert two round traces are bit-identical (floats compared by bits so
/// NaN evals also count as equal).
fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round, "{label}");
        let r = x.round;
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{label}: train_loss, round {r}"
        );
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: test_accuracy, round {r}"
        );
        assert_eq!(
            x.test_loss.to_bits(),
            y.test_loss.to_bits(),
            "{label}: test_loss, round {r}"
        );
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(
            x.sim_time_s.to_bits(),
            y.sim_time_s.to_bits(),
            "{label}: sim_time, round {r}"
        );
        assert_eq!(
            x.sim_clock_s.to_bits(),
            y.sim_clock_s.to_bits(),
            "{label}: sim_clock, round {r}"
        );
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

/// Run a config at a given worker count, returning the full round trace
/// plus the summary report.
fn run_with_workers(
    mut cfg: ExperimentConfig,
    workers: usize,
) -> (Vec<RoundRecord>, gradestc::metrics::RunReport) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    let report = sim.run().unwrap();
    (sim.recorder.rounds().to_vec(), report)
}

/// Tentpole acceptance: the parallel round engine is bit-deterministic in
/// the worker count for the paper's method (per-client compressor state —
/// the GradESTC basis — must evolve in lockstep at any parallelism).
#[test]
fn parallel_engine_bit_identical_gradestc() {
    let mut cfg = base_cfg(
        "it-par-gradestc",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    // Partial participation so lane extraction sees non-trivial subsets.
    cfg.num_clients = 8;
    cfg.participation = 0.5;
    cfg.rounds = 4;
    let (seq, seq_rep) = run_with_workers(cfg.clone(), 1);
    let (par, par_rep) = run_with_workers(cfg, 8);
    assert_rounds_bitwise_equal(&seq, &par, "gradestc w1 vs w8");
    assert_eq!(seq_rep.total_uplink, par_rep.total_uplink);
    assert_eq!(
        seq_rep.best_accuracy.to_bits(),
        par_rep.best_accuracy.to_bits()
    );
    assert_eq!(seq_rep.sum_d, par_rep.sum_d);
}

/// Same determinism bar for a stateless-baseline compressor (TopK).
#[test]
fn parallel_engine_bit_identical_topk() {
    let mut cfg = base_cfg("it-par-topk", CompressorKind::TopK { frac: 0.1 });
    cfg.rounds = 4;
    let (seq, seq_rep) = run_with_workers(cfg.clone(), 1);
    for workers in [2usize, 8] {
        let (par, par_rep) = run_with_workers(cfg.clone(), workers);
        assert_rounds_bitwise_equal(&seq, &par, &format!("topk w1 vs w{workers}"));
        assert_eq!(seq_rep.total_uplink, par_rep.total_uplink);
        assert_eq!(
            seq_rep.best_accuracy.to_bits(),
            par_rep.best_accuracy.to_bits()
        );
    }
}

/// Same determinism bar through the aggregation plane's quantized fold
/// lane (FedPAQ codes are folded straight from the bit-packing).
#[test]
fn parallel_engine_bit_identical_fedpaq() {
    let mut cfg = base_cfg("it-par-fedpaq", CompressorKind::FedPaq { bits: 8 });
    cfg.rounds = 3;
    let (seq, seq_rep) = run_with_workers(cfg.clone(), 1);
    let (par, par_rep) = run_with_workers(cfg, 8);
    assert_rounds_bitwise_equal(&seq, &par, "fedpaq w1 vs w8");
    assert_eq!(seq_rep.total_uplink, par_rep.total_uplink);
    assert_eq!(
        seq_rep.best_accuracy.to_bits(),
        par_rep.best_accuracy.to_bits()
    );
}

/// Satellite regression: a round where *every* survivor misses the
/// deadline has zero total aggregate weight. The apply must be skipped —
/// never normalized by `wtotal == 0` into NaN scales — so the global model
/// stays finite and unchanged while the round is still recorded.
#[test]
fn zero_weight_round_skips_apply_without_nan() {
    let mut cfg = base_cfg(
        "it-zero-weight",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    cfg.rounds = 3;
    cfg.net.deadline_s = 1e-9; // everyone is a straggler
    let mut sim = Simulation::build(cfg).unwrap();
    let before = sim.global.clone();
    for round in 0..3 {
        let rec = sim.step(round).unwrap();
        assert!(rec.train_loss.is_finite(), "round {round}");
    }
    assert_eq!(sim.global, before, "zero-weight rounds must not move the model");
    for i in 0..sim.global.len() {
        assert!(
            sim.global.tensor(i).iter().all(|x| x.is_finite()),
            "tensor {i} poisoned by a zero-weight round"
        );
    }
    assert_eq!(sim.recorder.rounds().len(), 3, "skipped applies must still record");
}

/// Straggler lanes still advance server-side basis state: with an
/// impossibly tight deadline every upload is excluded from the aggregate,
/// yet each lane's client-compressor and server-decompressor fingerprints
/// (GradESTC basis bits) must stay equal round after round — the decode
/// runs unconditionally, only the fold weight is withheld.
#[test]
fn straggler_decode_keeps_lane_state_lockstep() {
    let kinds = [
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        CompressorKind::SvdFed { k: 8, gamma: 0.6 },
    ];
    for kind in kinds {
        let name = kind.name();
        let mut cfg = base_cfg(&format!("it-straggler-lockstep-{name}"), kind);
        cfg.rounds = 4;
        cfg.net.deadline_s = 1e-9;
        let mut sim = Simulation::build(cfg).unwrap();
        for round in 0..4 {
            sim.step(round).unwrap();
            for (cid, (client_fp, server_fp)) in
                sim.lane_fingerprints().iter().enumerate()
            {
                assert_eq!(
                    client_fp, server_fp,
                    "{name} round {round} client {cid}: lane state diverged"
                );
                assert_ne!(*client_fp, 0, "{name}: fingerprints must cover bases");
            }
        }
    }
}

/// `workers: 0` resolves to an automatic count and still runs fine.
#[test]
fn auto_workers_runs() {
    let mut cfg = base_cfg("it-auto-workers", CompressorKind::None);
    cfg.rounds = 2;
    cfg.workers = 0;
    let mut sim = Simulation::build(cfg).unwrap();
    let rec = sim.step(0).unwrap();
    assert!(rec.train_loss.is_finite());
}

/// A hook that panics must not be silently dropped: the next round still
/// invokes it (regression test for the old take()/put-back dance).
#[test]
fn round_hook_survives_panic() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = calls.clone();
    let mut cfg = base_cfg("it-hook-panic", CompressorKind::None);
    cfg.rounds = 3;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.set_round_hook(Box::new(move |round, _view: &RoundHookView| {
        calls2.fetch_add(1, Ordering::SeqCst);
        if round == 0 {
            panic!("hook bails on round 0");
        }
    }));
    // Round 0 panics inside the hook…
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sim.step(0)));
    assert!(caught.is_err());
    assert_eq!(calls.load(Ordering::SeqCst), 1);
    // …but the hook is still installed and fires on the next round.
    sim.step(1).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 2);
}

/// Acceptance bar for the transport subsystem: with the default net config
/// the ledger — now charged from actual encoded buffer lengths — must match
/// the analytical `wire_bytes()` accounting to the byte. FedAvg's uplink is
/// exactly `participants · Σ_t (FRAME_HEADER + 4·|t|)` per round, and the
/// downlink is exactly the dense model broadcast per participant.
#[test]
fn ledger_charges_match_wire_bytes_exactly() {
    let mut cfg = base_cfg("it-wire-exact", CompressorKind::None);
    cfg.rounds = 2;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run().unwrap();
    let per_client: u64 = sim
        .meta
        .layers
        .iter()
        .map(|l| gradestc::compress::codec::FRAME_HEADER + 4 * l.size() as u64)
        .sum();
    let rounds = sim.recorder.rounds();
    for r in rounds {
        assert_eq!(r.uplink_bytes, 4 * per_client, "round {}", r.round);
        assert_eq!(r.downlink_bytes, 4 * (4 * sim.global.numel() as u64), "round {}", r.round);
        assert_eq!(r.survivors, vec![0, 1, 2, 3]);
    }
}

/// Same seed + same dropout rate ⇒ identical surviving-client sets and
/// bit-identical round records at workers=1 vs workers=8 (satellite
/// determinism bar for the dropout model).
#[test]
fn dropout_deterministic_across_workers() {
    let mut cfg = base_cfg(
        "it-dropout-det",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    cfg.num_clients = 8;
    cfg.rounds = 5;
    cfg.net.dropout = 0.3;
    let (seq, seq_rep) = run_with_workers(cfg.clone(), 1);
    let (par, par_rep) = run_with_workers(cfg, 8);
    assert_rounds_bitwise_equal(&seq, &par, "dropout w1 vs w8");
    assert_eq!(seq_rep.total_uplink, par_rep.total_uplink);
    assert_eq!(seq_rep.best_accuracy.to_bits(), par_rep.best_accuracy.to_bits());
    // The rate must actually bite somewhere in the trace…
    assert!(
        seq.iter().any(|r| r.survivors.len() < 8),
        "dropout 0.3 never dropped anyone in 5 rounds"
    );
    // …and dropped clients must not be charged: the broadcast goes only to
    // survivors, so each round's downlink is survivors × model bytes.
    for r in &seq {
        assert_eq!(
            r.downlink_bytes % r.survivors.len().max(1) as u64,
            0,
            "round {}: downlink not a multiple of survivor count",
            r.round
        );
    }
}

/// Dropout reduces traffic: fewer uploads and broadcasts cross the wire.
#[test]
fn dropout_reduces_traffic() {
    let base = base_cfg("it-dropout-traffic", CompressorKind::None);
    let mut dropped = base.clone();
    dropped.name = "it-dropout-traffic-d".into();
    dropped.net.dropout = 0.5;
    dropped.num_clients = 8;
    let mut full = base.clone();
    full.num_clients = 8;
    let r_full = Simulation::build(full).unwrap().run().unwrap();
    let r_drop = Simulation::build(dropped).unwrap().run().unwrap();
    assert!(
        r_drop.total_uplink < r_full.total_uplink,
        "dropout uplink {} !< full {}",
        r_drop.total_uplink,
        r_full.total_uplink
    );
}

/// An impossibly tight straggler deadline: every update arrives late, so
/// the global model never moves — but the run completes, bytes are still
/// charged (they crossed the wire), and state stays consistent.
#[test]
fn straggler_deadline_excludes_all_updates() {
    let mut cfg = base_cfg("it-deadline", CompressorKind::None);
    cfg.rounds = 2;
    cfg.net.deadline_s = 1e-9; // below even the per-message latency
    let mut sim = Simulation::build(cfg).unwrap();
    let before = sim.global.clone();
    let rec = sim.step(0).unwrap();
    assert_eq!(sim.global, before, "late updates must not be aggregated");
    assert!(rec.uplink_bytes > 0, "stragglers' bytes still cross the wire");
    // Round time is capped at the deadline.
    assert!(rec.sim_time_s <= 1e-9);
}

/// A generous deadline changes nothing: bit-identical to no deadline.
#[test]
fn loose_deadline_is_a_noop() {
    let mut cfg = base_cfg(
        "it-deadline-loose",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    cfg.rounds = 3;
    let (plain, _) = run_with_workers(cfg.clone(), 1);
    cfg.net.deadline_s = 1e9;
    let (loose, _) = run_with_workers(cfg, 1);
    for (a, b) in plain.iter().zip(&loose) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.uplink_bytes, b.uplink_bytes);
        assert_eq!(a.survivors, b.survivors);
    }
}

/// Heterogeneous links slow the simulated clock but leave learning and
/// accounting untouched (links only affect time, never bytes or math).
#[test]
fn heterogeneous_links_only_affect_time() {
    let base = base_cfg("it-het", CompressorKind::None);
    let mut het = base.clone();
    het.name = "it-het-spread".into();
    het.net.het_spread = 1.0;
    let mut a = Simulation::build(base).unwrap();
    let mut b = Simulation::build(het).unwrap();
    let ra = a.step(0).unwrap();
    let rb = b.step(0).unwrap();
    assert_eq!(ra.uplink_bytes, rb.uplink_bytes);
    assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
    assert_ne!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
}

#[test]
fn config_roundtrips_through_json_and_rebuilds() {
    let cfg = base_cfg(
        "it-json",
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
    );
    let j = cfg.to_json().to_pretty();
    let parsed =
        ExperimentConfig::from_json(&gradestc::config::Json::parse(&j).unwrap()).unwrap();
    assert_eq!(cfg, parsed);
    // And the parsed config still builds a working simulation.
    let mut sim = Simulation::build(parsed).unwrap();
    let rec = sim.step(0).unwrap();
    assert!(rec.train_loss.is_finite());
}
