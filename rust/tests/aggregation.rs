//! Acceptance tests for the streaming compressed-domain aggregation plane:
//! the fused server fold must agree with the legacy dense pipeline
//! (`decompress` → `ParamStore::weighted_sum`) — exactly for raw / sparse /
//! quantized payloads, to ≤1e-5 relative error for fused low-rank layers —
//! and the decoded updates must stay compressed (the O(model) memory
//! claim, asserted at the API level).

use gradestc::compress::{
    build_pair, Compressor, Decompressor, GradEstcClient, LayerUpdate, Payload,
};
use gradestc::config::{CompressorKind, GradEstcParams, ModelKind};
use gradestc::coordinator::ServerAggregator;
use gradestc::model::meta::{layer_table, ModelMeta};
use gradestc::model::params::ParamStore;
use gradestc::util::rng::Pcg64;

const N_CLIENTS: usize = 4;

/// One round of per-client payload sets for a compressor kind, after
/// `warm_rounds` warm-up rounds (GradESTC/SVDFed need an init round to
/// reach their steady-state payload shapes).
fn client_payloads(
    meta: &ModelMeta,
    kind: &CompressorKind,
    warm_rounds: usize,
) -> (Vec<Vec<Payload>>, Vec<Box<dyn Decompressor>>, Vec<Box<dyn Decompressor>>) {
    let mut payloads = Vec::new();
    let mut decoders_a = Vec::new();
    let mut decoders_b = Vec::new();
    for cid in 0..N_CLIENTS {
        let mut rng = Pcg64::seeded(0x5EED + cid as u64);
        let (mut c, da) = build_pair(kind, meta, 100 + cid as u64);
        // A second, identically-seeded decompressor: one per aggregation
        // path, so both observe the same payload stream and state.
        let (_, db) = build_pair(kind, meta, 100 + cid as u64);
        let mut last = Vec::new();
        for _ in 0..=warm_rounds {
            let update: Vec<Vec<f32>> =
                meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
            let (p, _) = c.compress(&update);
            last = p;
        }
        payloads.push(last);
        decoders_a.push(da);
        decoders_b.push(db);
    }
    (payloads, decoders_a, decoders_b)
}

fn scales() -> Vec<f32> {
    (0..N_CLIENTS).map(|i| 0.1 + 0.2 * i as f32).collect()
}

/// Dense reference: legacy `decompress` + `weighted_sum` pipeline. Warm
/// decompressor state through the same payload history as the fused path.
fn dense_aggregate(
    meta: &ModelMeta,
    payloads: &[Vec<Payload>],
    decoders: &mut [Box<dyn Decompressor>],
) -> ParamStore {
    let dense: Vec<Vec<Vec<f32>>> = payloads
        .iter()
        .zip(decoders.iter_mut())
        .map(|(p, d)| d.decompress(p))
        .collect();
    let terms: Vec<&[Vec<f32>]> = dense.iter().map(|u| u.as_slice()).collect();
    ParamStore::weighted_sum(meta, &terms, &scales(), 1)
}

/// Fused path: `decode` + `ServerAggregator::fold_batch`.
fn fused_aggregate(
    meta: &ModelMeta,
    payloads: &[Vec<Payload>],
    decoders: &mut [Box<dyn Decompressor>],
    workers: usize,
) -> ParamStore {
    let batch: Vec<(f32, Vec<LayerUpdate>)> = payloads
        .iter()
        .zip(decoders.iter_mut())
        .zip(scales())
        .map(|((p, d), s)| (s, d.decode(p.clone())))
        .collect();
    let mut agg = ServerAggregator::new(meta);
    agg.fold_batch(workers, batch);
    agg.finish(meta)
}

#[test]
fn fused_aggregate_exact_for_raw_sparse_and_quantized() {
    let meta = layer_table(ModelKind::LeNet5);
    let kinds = [
        CompressorKind::None,
        CompressorKind::TopK { frac: 0.1 },
        CompressorKind::FedPaq { bits: 8 },
        CompressorKind::FedQClip { bits: 8, clip: 2.5 },
        CompressorKind::SignSgd,
    ];
    for kind in kinds {
        let (payloads, mut da, mut db) = client_payloads(&meta, &kind, 0);
        let reference = dense_aggregate(&meta, &payloads, &mut da);
        for workers in [1usize, 8] {
            let fused = fused_aggregate(&meta, &payloads, &mut db, workers);
            for t in 0..reference.len() {
                for (i, (a, b)) in
                    reference.tensor(t).iter().zip(fused.tensor(t)).enumerate()
                {
                    assert!(
                        a == b,
                        "{}: tensor {t}[{i}] {a} != {b} (workers {workers})",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn fused_aggregate_close_for_lowrank() {
    let meta = layer_table(ModelKind::LeNet5);
    let kinds = [
        CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        CompressorKind::SvdFed { k: 8, gamma: 0.9 },
    ];
    for kind in kinds {
        // Steady-state round (after one warm-up) so the fold exercises the
        // incremental-replacement payload shape, not just init.
        let (payloads, mut da, mut db) = client_payloads(&meta, &kind, 1);
        // Warm both decoder sets through the init-round payloads so their
        // basis state matches the compressors'.
        let (warm, _, _) = client_payloads(&meta, &kind, 0);
        for ((p, a), b) in warm.iter().zip(da.iter_mut()).zip(db.iter_mut()) {
            let _ = a.decompress(p);
            let _ = b.decode(p.clone());
        }
        let reference = dense_aggregate(&meta, &payloads, &mut da);
        let fused = fused_aggregate(&meta, &payloads, &mut db, 8);
        for t in 0..reference.len() {
            let num: f64 = reference
                .tensor(t)
                .iter()
                .zip(fused.tensor(t))
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let den: f64 =
                reference.tensor(t).iter().map(|&x| (x as f64).powi(2)).sum();
            let rel = (num / den.max(1e-30)).sqrt();
            assert!(rel <= 1e-5, "{}: tensor {t} rel err {rel}", kind.name());
        }
    }
}

#[test]
fn decoded_updates_stay_compressed_domain() {
    // The O(model)-memory claim at the API level: in steady state a
    // GradESTC client's decoded update owns only coefficients (k·m per
    // compressed layer; the basis is a shared server-state Arc) plus the
    // small raw tensors — far below one dense model, and the compressed
    // tensors must come back as LowRank, never as densified buffers.
    let meta = layer_table(ModelKind::LeNet5);
    let params = GradEstcParams { k: 8, ..Default::default() };
    let kind = CompressorKind::GradEstc(params.clone());
    // The expected compressed set comes from the compressor config itself,
    // not from what happened to decode as LowRank — so a layer silently
    // regressing to a dense decode fails the assertion below.
    let compressed = GradEstcClient::new(&meta, params, 0).compressed_tensors();
    assert!(!compressed.is_empty(), "config selects no compressed layers");

    let (warm, mut decoders, _) = client_payloads(&meta, &kind, 0);
    for (p, d) in warm.iter().zip(decoders.iter_mut()) {
        let _ = d.decode(p.clone());
    }
    let (payloads, _, _) = client_payloads(&meta, &kind, 1);

    let model_floats = meta.total_params();
    let mut all_clients_floats = 0usize;
    for (p, d) in payloads.iter().zip(decoders.iter_mut()) {
        let updates = d.decode(p.clone());
        // Every tensor the config compresses must stay structured.
        for (t, u) in updates.iter().enumerate() {
            if compressed.contains(&t) {
                assert!(
                    matches!(u, LayerUpdate::LowRank { .. }),
                    "tensor {t} decoded dense despite being in the compressed set"
                );
                assert_eq!(u.dense_len(), meta.layers[t].size());
            }
        }
        let owned: usize = updates.iter().map(LayerUpdate::stored_floats).sum();
        assert!(
            owned < model_floats / 2,
            "one decoded client owns {owned} floats vs model {model_floats}"
        );
        all_clients_floats += owned;
    }
    // Even all survivors together stay below one dense model: the fused
    // server phase peaks at O(model + k·m), not O(survivors × model).
    assert!(
        all_clients_floats < model_floats,
        "{N_CLIENTS} decoded clients own {all_clients_floats} floats vs model {model_floats}"
    );
}

#[test]
fn signs_decode_matches_legacy_exactly() {
    // SignSGD now decodes through the QuantDense lane (1 bit over
    // [-scale, scale]); the reconstruction must still be exactly ±scale.
    let meta = layer_table(ModelKind::LeNet5);
    let mut rng = Pcg64::seeded(77);
    let update: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
    let (mut c, mut d) = build_pair(&CompressorKind::SignSgd, &meta, 3);
    let (payloads, _) = c.compress(&update);
    let rec = d.decompress(&payloads);
    for (t, (orig, r)) in update.iter().zip(&rec).enumerate() {
        if let Payload::Signs { scale, .. } = &payloads[t] {
            for (o, v) in orig.iter().zip(r) {
                let expect = if *o >= 0.0 { *scale } else { -*scale };
                assert!(*v == expect, "tensor {t}: {v} != ±{scale}");
            }
        } else {
            assert_eq!(orig, r);
        }
    }
}
