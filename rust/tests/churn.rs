//! Chaos suite for the availability & churn plane (plane 10): default
//! knobs stay bit-identical and fault-free, armed runs stay bit-identical
//! across worker counts, mid-flight departures release their slot and
//! charge zero bytes, departed-then-returning GradESTC clients
//! re-materialize in fingerprint lockstep, the semi-sync fast-forward
//! cannot deadlock an all-offline pool, and the one incoherent
//! cross-plane combination (armed availability on the fixed legacy-shards
//! pool) is rejected at build time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use gradestc::config::{
    AvailConfig, BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig,
    GradEstcParams, LaneConfig, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::Simulation;
use gradestc::metrics::RoundRecord;
use gradestc::net::{Loopback, Transport};

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 5,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 64,
        test_samples: 64,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

fn gradestc8() -> CompressorKind {
    CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() })
}

fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label}: loss, round {r}");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: accuracy, round {r}"
        );
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(
            x.sim_clock_s.to_bits(),
            y.sim_clock_s.to_bits(),
            "{label}: sim_clock, round {r}"
        );
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

/// Run with telemetry armed; returns records, fingerprints, ledger total,
/// and the run-level fault count.
fn run_with_faults(
    mut cfg: ExperimentConfig,
    workers: usize,
) -> (Vec<RoundRecord>, Vec<(u64, u64)>, u64, u64) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    let tel = sim.enable_telemetry();
    sim.run_scheduled().unwrap();
    let faults = tel.metrics().run_counter("faults");
    (sim.recorder.rounds().to_vec(), sim.lane_fingerprints(), sim.total_uplink(), faults)
}

/// The bit-identity anchor the whole plane hangs off: with every plane-10
/// knob at its default the async scheduler runs the pre-plane-10 control
/// flow verbatim — zero faults, identical records/fingerprints/ledger at
/// 1 and 8 workers, and (with participation sampling armed) the legacy
/// draw sequence untouched.
#[test]
fn default_knobs_run_fault_free_and_bit_identical() {
    let mut cfg = base_cfg("it-churn-defaults", gradestc8());
    cfg.net.het_spread = 1.0;
    cfg.net.dropout = 0.1;
    cfg.sched.kind = SchedKind::Async { k: 3, staleness_p: 0.5 };
    assert!(!cfg.sched.avail.armed(), "default AvailConfig must be unarmed");
    assert_eq!(cfg.sched.concurrency, 1);
    assert!(!cfg.sched.adaptive_k);
    assert_eq!(cfg.sched.lr_tau, 0.0);
    let (r1, fp1, up1, f1) = run_with_faults(cfg.clone(), 1);
    let (r8, fp8, up8, f8) = run_with_faults(cfg.clone(), 8);
    assert_rounds_bitwise_equal(&r1, &r8, "defaults w1 vs w8");
    assert_eq!(fp1, fp8, "lane fingerprints diverged across worker counts");
    assert_eq!(up1, up8, "ledger totals diverged across worker counts");
    assert_eq!((f1, f8), (0, 0), "unarmed availability must never fault");

    // Participation sampling path: same bar with the sampler armed.
    let mut scfg = cfg;
    scfg.name = "it-churn-defaults-sampled".into();
    scfg.num_clients = 16;
    scfg.participation = 0.5;
    scfg.samples_per_client = 32;
    let (s1, sfp1, sup1, sf1) = run_with_faults(scfg.clone(), 1);
    let (s8, sfp8, sup8, sf8) = run_with_faults(scfg, 8);
    assert_rounds_bitwise_equal(&s1, &s8, "sampled defaults w1 vs w8");
    assert_eq!(sfp1, sfp8);
    assert_eq!(sup1, sup8);
    assert_eq!((sf1, sf8), (0, 0));
}

/// Armed availability + churn is a new determinism surface: fault
/// requeues, wake events, availability-filtered refills, and lane
/// discards all happen on the event loop — so records, fingerprints,
/// ledger, and the fault count itself must replay bit-identically at any
/// worker count.
#[test]
fn armed_churn_bit_identical_across_workers() {
    let mut cfg = base_cfg("it-churn-armed-det", gradestc8());
    cfg.rounds = 5;
    cfg.net.het_spread = 1.0;
    cfg.sched.kind = SchedKind::Async { k: 2, staleness_p: 0.5 };
    cfg.sched.avail =
        AvailConfig { duty: 0.5, period_s: 2.0, churn_per_s: 0.05, outage_s: 1.0 };
    let (r1, fp1, up1, f1) = run_with_faults(cfg.clone(), 1);
    let (r8, fp8, up8, f8) = run_with_faults(cfg, 8);
    assert_rounds_bitwise_equal(&r1, &r8, "armed churn w1 vs w8");
    assert_eq!(fp1, fp8, "lane fingerprints diverged under churn");
    assert_eq!(up1, up8, "ledger totals diverged under churn");
    assert_eq!(f1, f8, "fault count diverged across worker counts");
}

/// A transport wrapper counting every uploaded byte at the moment it
/// enters the fabric — the independent ground truth for the ledger.
struct CountingLoopback {
    inner: Loopback,
    uplink_bytes: Arc<AtomicU64>,
}

impl Transport for CountingLoopback {
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> anyhow::Result<()> {
        self.inner.broadcast(to, frame)
    }
    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)> {
        self.inner.drain_broadcasts()
    }
    fn upload(&mut self, from: usize, frame: Vec<u8>) -> anyhow::Result<()> {
        self.uplink_bytes.fetch_add(frame.len() as u64, Ordering::SeqCst);
        self.inner.upload(from, frame)
    }
    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)> {
        self.inner.drain_uploads()
    }
}

/// The fault contract: a mid-flight departure charges **zero** bytes (the
/// frame crossed the transport but never the ledger) and releases its
/// concurrency slot (the run still completes every apply — leaked slots
/// would starve the loop into the livelock bail). An aggressive duty
/// cycle (on-window 0.4 s ≈ one dense round trip) makes faults certain
/// while leaving enough successful arrivals to make progress.
#[test]
fn midflight_departure_charges_nothing_and_releases_slots() {
    let mut cfg = base_cfg("it-churn-zero-charge", CompressorKind::None);
    cfg.rounds = 5;
    cfg.net.het_spread = 0.5;
    cfg.sched.kind = SchedKind::Async { k: 2, staleness_p: 0.5 };
    cfg.sched.avail = AvailConfig { duty: 0.4, period_s: 1.0, ..Default::default() };
    let rounds = cfg.rounds;
    let mut sim = Simulation::build(cfg).unwrap();
    let tel = sim.enable_telemetry();
    let counter = Arc::new(AtomicU64::new(0));
    sim.set_transport(Box::new(CountingLoopback {
        inner: Loopback::new(),
        uplink_bytes: counter.clone(),
    }));
    sim.run_scheduled().unwrap();
    let faults = tel.metrics().run_counter("faults");
    assert!(faults > 0, "duty 0.4/period 1.0 must fault dense round trips");
    assert_eq!(
        sim.recorder.rounds().len(),
        rounds,
        "faults starved the run: slots were not released"
    );
    let crossed = counter.load(Ordering::SeqCst);
    assert!(
        sim.total_uplink() < crossed,
        "ledger {} must exclude the {faults} faulted frames' bytes (transport saw {})",
        sim.total_uplink(),
        crossed
    );
    let recorded: u64 = sim.recorder.rounds().iter().map(|r| r.uplink_bytes).sum();
    assert!(recorded <= sim.total_uplink(), "records exceed the ledger");
}

/// The re-materialization contract: a faulted GradESTC lane is discarded
/// (its client compressor advanced at dispatch with no decode to match)
/// and the returning client rebuilds from `(seed, cid)` through the lane
/// factory and shared basis pool — so after a churny run every lane's
/// paired client/server fingerprints are equal again.
#[test]
fn departed_client_rematerializes_in_fingerprint_lockstep() {
    let mut cfg = base_cfg("it-churn-lockstep", gradestc8());
    cfg.rounds = 6;
    cfg.net.het_spread = 1.0;
    cfg.sched.kind = SchedKind::Async { k: 2, staleness_p: 0.5 };
    cfg.sched.avail =
        AvailConfig { duty: 0.5, period_s: 2.0, churn_per_s: 0.15, outage_s: 1.5 };
    let mut sim = Simulation::build(cfg).unwrap();
    let tel = sim.enable_telemetry();
    sim.run_scheduled().unwrap();
    let faults = tel.metrics().run_counter("faults");
    assert!(faults > 0, "churn 0.15/s on a 0.5 duty cycle produced no fault");
    let fps = sim.lane_fingerprints();
    for (cid, (client_fp, server_fp)) in fps.iter().enumerate() {
        assert_eq!(
            client_fp, server_fp,
            "client {cid}: lane state diverged across a fault discard"
        );
    }
    // Discarded-but-never-redispatched lanes legitimately report (0, 0);
    // the run as a whole must still have live, folded lanes.
    assert!(fps.iter().any(|&(c, _)| c != 0), "no lane survived with live state");
}

/// Semi-sync under the same chaos: mid-round departure faults (never
/// folds, never charges), and a round whose sampled pool is entirely
/// offline fast-forwards the clock to the population's earliest return
/// instead of deadlocking or spinning zero-duration rounds — the run
/// always completes its configured rounds with a monotone clock, and
/// replays bit-identically across worker counts.
#[test]
fn semisync_all_offline_fast_forward_never_deadlocks() {
    let mut cfg = base_cfg("it-churn-semisync-ff", gradestc8());
    cfg.num_clients = 4;
    cfg.rounds = 6;
    cfg.net.deadline_s = 0.5;
    cfg.sched.kind = SchedKind::SemiSync;
    // Tiny duty: most dispatch instants find most of the pool offline, so
    // the all-offline fast-forward arm is exercised hard.
    cfg.sched.avail =
        AvailConfig { duty: 0.2, period_s: 3.0, churn_per_s: 0.1, outage_s: 2.0 };
    let (r1, fp1, up1, f1) = run_with_faults(cfg.clone(), 1);
    let (r8, fp8, up8, f8) = run_with_faults(cfg, 8);
    assert_eq!(r1.len(), 6, "semisync deadlocked or bailed under an offline pool");
    assert!(
        r1.windows(2).all(|w| w[0].sim_clock_s <= w[1].sim_clock_s),
        "virtual clock ran backwards"
    );
    assert!(
        r1.last().unwrap().sim_clock_s > 0.0,
        "clock never advanced: the fast-forward arm did not fire"
    );
    assert_rounds_bitwise_equal(&r1, &r8, "semisync churn w1 vs w8");
    assert_eq!(fp1, fp8);
    assert_eq!(up1, up8);
    assert_eq!(f1, f8, "fault count diverged across worker counts");
}

/// Per-client concurrency is its own determinism surface (FIFO arrival
/// clamp, counted lane pins, capacity-aware draws): `--concurrency 2`
/// must replay bit-identically across worker counts with every lane pair
/// in lockstep, and still fold exactly k per apply.
#[test]
fn concurrency_two_bit_identical_and_lockstep() {
    let mut cfg = base_cfg("it-churn-conc2", gradestc8());
    cfg.rounds = 5;
    cfg.net.het_spread = 1.0;
    cfg.sched.kind = SchedKind::Async { k: 3, staleness_p: 0.5 };
    cfg.sched.concurrency = 2;
    let (r1, fp1, up1, _) = run_with_faults(cfg.clone(), 1);
    let (r8, fp8, up8, _) = run_with_faults(cfg, 8);
    assert_rounds_bitwise_equal(&r1, &r8, "conc=2 w1 vs w8");
    assert_eq!(fp1, fp8, "lane fingerprints diverged under concurrency");
    assert_eq!(up1, up8, "ledger totals diverged under concurrency");
    for (cid, (client_fp, server_fp)) in fp1.iter().enumerate() {
        assert_eq!(client_fp, server_fp, "client {cid}: FIFO decode order broke lockstep");
    }
    assert!(r1.iter().all(|r| r.survivors.len() == 3), "every apply folds exactly k");
}

/// Everything at once — churn, concurrency 2, adaptive k, staleness-
/// adaptive server LR — the full plane-10 surface under one run, still
/// bit-identical across worker counts and still completing every apply.
#[test]
fn full_plane10_chaos_is_deterministic() {
    let mut cfg = base_cfg("it-churn-kitchen-sink", gradestc8());
    cfg.rounds = 5;
    cfg.net.het_spread = 1.0;
    cfg.net.dropout = 0.05;
    cfg.sched.kind = SchedKind::Async { k: 3, staleness_p: 0.5 };
    cfg.sched.avail =
        AvailConfig { duty: 0.6, period_s: 2.0, churn_per_s: 0.05, outage_s: 1.0 };
    cfg.sched.concurrency = 2;
    cfg.sched.adaptive_k = true;
    cfg.sched.lr_tau = 0.3;
    let (r1, fp1, up1, f1) = run_with_faults(cfg.clone(), 1);
    let (r8, fp8, up8, f8) = run_with_faults(cfg, 8);
    assert_eq!(r1.len(), 5, "the chaos run did not complete its applies");
    assert_rounds_bitwise_equal(&r1, &r8, "plane-10 chaos w1 vs w8");
    assert_eq!(fp1, fp8);
    assert_eq!(up1, up8);
    assert_eq!(f1, f8);
    for (cid, (c, s)) in fp1.iter().enumerate() {
        assert_eq!(c, s, "client {cid}: lockstep broke under the combined plane");
    }
}

/// Cross-plane coherence is enforced at build time: armed availability on
/// the fixed legacy-shards pool (which cannot re-materialize a discarded
/// lane) is rejected with an actionable error.
#[test]
fn build_rejects_armed_avail_with_legacy_shards() {
    let mut cfg = base_cfg("it-churn-legacy-reject", gradestc8());
    cfg.lanes = LaneConfig { lazy: false, max_resident: 0, legacy_shards: true };
    cfg.sched.avail = AvailConfig { duty: 0.5, ..Default::default() };
    let err = Simulation::build(cfg).err().expect("armed avail + legacy shards must not build");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("legacy-shards") || msg.contains("legacy_shards"),
        "error must name the incompatible knob: {msg}"
    );
}
