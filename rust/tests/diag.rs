//! Integration tests for the diagnostics plane (`gradestc::diag` +
//! `gradestc::telemetry::DiagProbe`): arming diagnostics never perturbs
//! results (diag-off / diag-on w1 / diag-on w8 runs are bit-identical for
//! every scheduler × compressor, with dropout, heterogeneous links, and a
//! straggler deadline on), the diagnostics themselves are
//! worker-count-invariant, lossless dense decodes report exactly-zero
//! NRMSE, the streaming adjacent-cosine estimator reproduces the Fig. 1
//! probe's `adjacent_similarity` bitwise on a live run, and every
//! exported metric respects its mathematical range (native backend:
//! hermetic, no artifacts needed).

use std::cell::RefCell;
use std::rc::Rc;

use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::{RoundHookView, Simulation};
use gradestc::diag::{sample_clients, DiagConfig, DiagState};
use gradestc::metrics::{RoundRecord, SimilarityProbe};
use gradestc::model::meta::layer_table;
use gradestc::telemetry::DiagProbe;

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 4,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

fn gradestc8() -> CompressorKind {
    CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() })
}

/// Bitwise comparison of the scalar record fields (floats by bits so NaN
/// evals also count as equal). `ext` is deliberately not compared: it is
/// observation, present only on armed runs.
fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label}: loss, round {r}");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: accuracy, round {r}"
        );
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label}: test_loss, round {r}");
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label}: sim_time, round {r}");
        assert_eq!(
            x.sim_clock_s.to_bits(),
            y.sim_clock_s.to_bits(),
            "{label}: sim_clock, round {r}"
        );
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

fn bits(v: Option<f64>) -> Option<u64> {
    v.map(f64::to_bits)
}

/// The diagnostics themselves must also be worker-count-invariant:
/// arrivals are replayed to the observer in a deterministic order, so two
/// armed runs of the same config must accumulate identical state.
fn assert_diag_states_bitwise_equal(a: &DiagState, b: &DiagState, label: &str) {
    assert_eq!(a.sample, b.sample, "{label}: sampled clients");
    assert_eq!(a.layer_names, b.layer_names, "{label}: layer names");
    assert_eq!(a.run_adj_pairs, b.run_adj_pairs, "{label}: adjacent pairs");
    let (sa, sb): (Vec<u64>, Vec<u64>) = (
        a.run_adj_sum.iter().map(|v| v.to_bits()).collect(),
        b.run_adj_sum.iter().map(|v| v.to_bits()).collect(),
    );
    assert_eq!(sa, sb, "{label}: run adjacent-cosine sums");
    assert_eq!(a.rows.len(), b.rows.len(), "{label}: row count");
    for (x, y) in a.rows.iter().zip(&b.rows) {
        let tag = format!("{label}: round {} layer {}", x.round, x.layer);
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.layer, y.layer, "{tag}");
        assert_eq!(bits(x.drift_mean_angle), bits(y.drift_mean_angle), "{tag}: mean angle");
        assert_eq!(bits(x.drift_max_angle), bits(y.drift_max_angle), "{tag}: max angle");
        assert_eq!(bits(x.drift_chordal), bits(y.drift_chordal), "{tag}: chordal");
        assert_eq!(x.churn_dr, y.churn_dr, "{tag}: churn");
        assert_eq!(bits(x.energy_coverage), bits(y.energy_coverage), "{tag}: coverage");
        assert_eq!(bits(x.cosine), bits(y.cosine), "{tag}: cosine");
        assert_eq!(bits(x.nrmse), bits(y.nrmse), "{tag}: nrmse");
        assert_eq!(bits(x.stable_rank), bits(y.stable_rank), "{tag}: stable rank");
        assert_eq!(
            bits(x.bytes_per_unit_energy),
            bits(y.bytes_per_unit_energy),
            "{tag}: bytes/energy"
        );
        assert_eq!(x.cum_uplink_bytes, y.cum_uplink_bytes, "{tag}: cum bytes");
        assert_eq!(bits(x.loss_drop), bits(y.loss_drop), "{tag}: loss drop");
        assert_eq!(bits(x.bytes_per_loss), bits(y.bytes_per_loss), "{tag}: bytes/loss");
    }
}

/// Run a config bare (no telemetry, no observer).
fn run_plain(
    mut cfg: ExperimentConfig,
    workers: usize,
) -> (Vec<RoundRecord>, Vec<(u64, u64)>, u64) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run_scheduled().unwrap();
    (sim.recorder.rounds().to_vec(), sim.lane_fingerprints(), sim.total_uplink())
}

/// Run a config with telemetry + the diagnostics probe armed.
fn run_diag(
    mut cfg: ExperimentConfig,
    workers: usize,
    dcfg: DiagConfig,
) -> (Vec<RoundRecord>, Vec<(u64, u64)>, u64, DiagState) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg.clone()).unwrap();
    let tel = sim.enable_telemetry();
    let probe = DiagProbe::new(&cfg, dcfg).with_telemetry(tel);
    let state = probe.state();
    sim.set_observer(Box::new(probe));
    sim.run_scheduled().unwrap();
    let out = state.borrow().clone();
    (sim.recorder.rounds().to_vec(), sim.lane_fingerprints(), sim.total_uplink(), out)
}

/// Tentpole acceptance: diagnostics observe without participating. For
/// every scheduler × {GradESTC, TopK}, with dropout, heterogeneous links,
/// and a straggler deadline on, the diag-off run, the armed sequential
/// run, and the armed 8-worker run produce bit-identical records, lane
/// fingerprints, and ledger totals — and the two armed runs accumulated
/// bitwise-identical diagnostics.
#[test]
fn diag_runs_bit_identical_to_plain_at_any_worker_count() {
    for kind in [
        SchedKind::Sync,
        SchedKind::SemiSync,
        SchedKind::Async { k: 3, staleness_p: 0.5 },
    ] {
        for (label, comp) in
            [("gradestc", gradestc8()), ("topk", CompressorKind::TopK { frac: 0.1 })]
        {
            let mut cfg = base_cfg(&format!("it-diag-{}-{label}", kind.name()), comp);
            cfg.net.dropout = 0.1;
            cfg.net.het_spread = 0.5;
            cfg.net.deadline_s = 2.0;
            cfg.sched.kind = kind;
            let tag = format!("{} {label}", kind.name());
            let (plain, fp_plain, up_plain) = run_plain(cfg.clone(), 1);
            let (d1, fp1, up1, st1) = run_diag(cfg.clone(), 1, DiagConfig::default());
            let (d8, fp8, up8, st8) = run_diag(cfg, 8, DiagConfig::default());
            assert!(!st1.rows.is_empty(), "{tag}: probe accumulated nothing");
            assert_rounds_bitwise_equal(&plain, &d1, &format!("{tag}: diag-off vs diag-on w1"));
            assert_rounds_bitwise_equal(&d1, &d8, &format!("{tag}: diag-on w1 vs w8"));
            assert_eq!(fp_plain, fp1, "{tag}: lane fingerprints diag-off vs diag-on");
            assert_eq!(fp1, fp8, "{tag}: lane fingerprints w1 vs w8");
            assert_eq!(up_plain, up1, "{tag}: uplink diag-off vs diag-on");
            assert_eq!(up1, up8, "{tag}: uplink w1 vs w8");
            assert_diag_states_bitwise_equal(&st1, &st8, &tag);
        }
    }
}

/// Fidelity contract: a lossless (uncompressed) run reports NRMSE of
/// exactly 0.0 and energy coverage of exactly 1.0 wherever the estimator
/// had something to measure — the invariant `scripts/check_diag.py`
/// gates on for raw runs.
#[test]
fn lossless_runs_report_exactly_zero_nrmse() {
    let cfg = base_cfg("it-diag-lossless", CompressorKind::None);
    let (_, _, _, st) = run_diag(cfg, 1, DiagConfig::default());
    let measured = st.rows.iter().filter(|r| r.nrmse.is_some()).count();
    assert!(measured > 0, "no fidelity measurements on a dense run");
    for row in &st.rows {
        if let Some(n) = row.nrmse {
            assert_eq!(n.to_bits(), 0.0f64.to_bits(), "round {} layer {}", row.round, row.layer);
        }
        if let Some(c) = row.energy_coverage {
            assert_eq!(c.to_bits(), 1.0f64.to_bits(), "round {} layer {}", row.round, row.layer);
        }
    }
}

/// Equivalence contract: the streaming adjacent-cosine estimator
/// reproduces the Fig. 1 probe's `adjacent_similarity` bitwise on a live
/// run — same gradient stream (two identical deterministic runs; the
/// simulation holds one observer slot), same kernel, same summation
/// order, same divisor.
#[test]
fn streaming_cosine_matches_fig1_probe_bitwise() {
    let mut cfg = base_cfg("it-diag-equiv", CompressorKind::None);
    cfg.rounds = 5;
    let sample = sample_clients(cfg.seed, cfg.num_clients, 1);
    assert_eq!(sample.len(), 1);
    let cid = sample[0];

    // Run 1: the legacy Fig. 1 probe fed every tensor of the sampled
    // client through the round hook.
    let meta = layer_table(cfg.model);
    let names: Vec<String> = meta.layers.iter().map(|l| l.name.clone()).collect();
    let probe = Rc::new(RefCell::new(SimilarityProbe::new(names)));
    let probe2 = probe.clone();
    let mut sim = Simulation::build(cfg.clone()).unwrap();
    sim.set_round_hook(Box::new(move |_round, view: &RoundHookView| {
        if let Some((_, tensors)) = view.updates.iter().find(|(id, _)| *id == cid) {
            probe2.borrow_mut().record_round(tensors.clone());
        }
    }));
    sim.run_scheduled().unwrap();
    let lazy = probe.borrow().adjacent_similarity();

    // Run 2: the streaming estimator, sampling the same single client.
    let (_, _, _, st) = run_diag(cfg, 1, DiagConfig { sample: 1 });
    assert_eq!(st.sample, sample, "diag sampled a different client");
    let streaming = st.adjacent_mean_per_layer();
    assert_eq!(streaming.len(), lazy.len(), "layer count");
    assert_eq!(st.run_adj_pairs as usize, probe.borrow().rounds() - 1, "pair count");
    for (l, (s, z)) in streaming.iter().zip(&lazy).enumerate() {
        assert_eq!(s.to_bits(), z.to_bits(), "layer {l} diverged");
    }
}

/// Range sanity over a real GradESTC run, sync and async: principal
/// angles live in [0, π/2] with max ≥ mean, cosines in [−1, 1], NRMSE in
/// [0, 1], cumulative uplink bytes are monotone over the aggregate rows,
/// and the drift estimator actually fired (GradESTC ships low-rank
/// bases).
#[test]
fn diag_metrics_respect_ranges_and_monotonicity() {
    for kind in [SchedKind::Sync, SchedKind::Async { k: 3, staleness_p: 0.5 }] {
        let mut cfg = base_cfg(&format!("it-diag-sanity-{}", kind.name()), gradestc8());
        cfg.rounds = 5;
        cfg.sched.kind = kind;
        let (_, _, _, st) = run_diag(cfg, 1, DiagConfig::default());
        let half_pi = std::f64::consts::FRAC_PI_2 + 1e-9;
        let mut drift_rows = 0usize;
        let mut prev_cum = 0u64;
        for row in &st.rows {
            let tag = format!("{} round {} layer {}", kind.name(), row.round, row.layer);
            if let (Some(mean), Some(max)) = (row.drift_mean_angle, row.drift_max_angle) {
                drift_rows += 1;
                assert!((0.0..=half_pi).contains(&mean), "{tag}: mean angle {mean}");
                assert!((0.0..=half_pi).contains(&max), "{tag}: max angle {max}");
                assert!(max >= mean - 1e-12, "{tag}: max {max} < mean {mean}");
                let chordal = row.drift_chordal.expect("chordal rides with angles");
                assert!(chordal >= 0.0, "{tag}: chordal {chordal}");
            }
            if let Some(c) = row.cosine {
                assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&c), "{tag}: cosine {c}");
            }
            if let Some(n) = row.nrmse {
                assert!((0.0..=1.0 + 1e-9).contains(&n), "{tag}: nrmse {n}");
            }
            if let Some(cov) = row.energy_coverage {
                assert!((0.0..=1.0 + 1e-9).contains(&cov), "{tag}: coverage {cov}");
            }
            if let Some(b) = row.bytes_per_unit_energy {
                assert!(b > 0.0, "{tag}: bytes/energy {b}");
            }
            if row.layer == "*" {
                let cum = row.cum_uplink_bytes.expect("aggregate rows carry cum bytes");
                assert!(cum >= prev_cum, "{tag}: cum bytes regressed {prev_cum} -> {cum}");
                prev_cum = cum;
            }
        }
        if matches!(kind, SchedKind::Sync) {
            assert!(drift_rows > 0, "sync gradestc run produced no drift samples");
        }
    }
}
