//! Integration tests for the virtual-lane plane
//! ([`gradestc::coordinator::LanePool`]): lazy ≡ eager bit-identity across
//! schedulers, compressors, and worker counts; LRU eviction with
//! bit-identical re-materialization under a residency cap; and the ~0-cost
//! guarantee for sampled-never clients (native backend: hermetic, no
//! artifacts needed).

use gradestc::compress::gradestc::basis_bytes_per_lane;
use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::Simulation;
use gradestc::metrics::RoundRecord;
use gradestc::model::meta::layer_table;

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 4,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 64,
        test_samples: 64,
        eval_every: 2,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

fn gradestc8() -> CompressorKind {
    CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() })
}

/// Assert two round traces are bit-identical (floats compared by bits so
/// NaN evals also count as equal).
fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label}: loss, round {r}");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: accuracy, round {r}"
        );
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(
            x.sim_clock_s.to_bits(),
            y.sim_clock_s.to_bits(),
            "{label}: sim_clock, round {r}"
        );
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

/// Build + run under the scheduler plane; returns the finished simulation.
fn run_sim(mut cfg: ExperimentConfig, workers: usize) -> Simulation {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run_scheduled().unwrap();
    sim
}

/// Tentpole acceptance: lazy materialization is unobservable. For the
/// paper's method and a stateless baseline, under dropout, heterogeneous
/// links, and (for semi-sync) a straggler deadline, across all three
/// control flows and at sequential and parallel worker counts, a lazy run
/// and an eager run produce bit-identical round records, ledger totals,
/// and paired lane fingerprints on every lane the lazy run materialized.
#[test]
fn lazy_and_eager_runs_are_bit_identical() {
    let scheds: [(&str, SchedKind, f64); 3] = [
        ("sync", SchedKind::Sync, 0.0),
        ("semisync", SchedKind::SemiSync, 2.0),
        ("async", SchedKind::Async { k: 3, staleness_p: 0.5 }, 0.0),
    ];
    for (label, comp) in
        [("gradestc", gradestc8()), ("topk", CompressorKind::TopK { frac: 0.1 })]
    {
        for (sname, kind, deadline) in &scheds {
            let mut cfg = base_cfg(&format!("it-lanes-{label}-{sname}"), comp.clone());
            cfg.net.dropout = 0.2;
            cfg.net.het_spread = 0.5;
            cfg.net.deadline_s = *deadline;
            cfg.sched.kind = *kind;
            for workers in [1usize, 8] {
                let mut lazy_cfg = cfg.clone();
                lazy_cfg.lanes.lazy = true;
                let mut eager_cfg = cfg.clone();
                eager_cfg.lanes.lazy = false;
                let lazy = run_sim(lazy_cfg, workers);
                let eager = run_sim(eager_cfg, workers);
                let tag = format!("{label} {sname} w{workers}");
                assert_rounds_bitwise_equal(
                    lazy.recorder.rounds(),
                    eager.recorder.rounds(),
                    &tag,
                );
                assert_eq!(
                    lazy.total_uplink(),
                    eager.total_uplink(),
                    "{tag}: ledger totals diverged"
                );
                // Fingerprints must agree wherever the lazy run holds a
                // lane; never-materialized slots report (0, 0), which a
                // stateless (TopK) lane also legitimately reports.
                let lf = lazy.lane_fingerprints();
                let ef = eager.lane_fingerprints();
                let mut checked = 0usize;
                for (cid, (l, e)) in lf.iter().zip(&ef).enumerate() {
                    if *l != (0, 0) {
                        assert_eq!(l, e, "{tag}: lane {cid} fingerprints diverged");
                        checked += 1;
                    }
                }
                if label == "gradestc" {
                    assert!(checked > 0, "{tag}: no stateful lane ever materialized");
                }
            }
        }
    }
}

/// A residency cap below the steady working set forces evictions, and an
/// evicted lane re-materializes bit-identically: paired client/server
/// fingerprints stay in lockstep through evict → re-dispatch cycles, and
/// the whole capped run is bit-identical at workers = 1 vs 8.
#[test]
fn capped_pool_evicts_and_rematerializes_in_lockstep() {
    let mut cfg = base_cfg("it-lanes-evict", gradestc8());
    cfg.num_clients = 16;
    cfg.participation = 0.5; // 8-lane cohorts
    cfg.rounds = 6;
    cfg.net.het_spread = 1.0;
    cfg.lanes = LaneConfig { lazy: true, max_resident: 4, legacy_shards: false };

    let seq = run_sim(cfg.clone(), 1);
    assert!(
        seq.lanes.eviction_count() > 0,
        "cap 4 against 8-lane cohorts must evict"
    );
    assert!(
        seq.lanes.materializations() > seq.lanes.resident() as u64,
        "evicted lanes must have re-materialized on later dispatches"
    );
    for (cid, (client_fp, server_fp)) in seq.lane_fingerprints().iter().enumerate() {
        assert_eq!(
            client_fp, server_fp,
            "client {cid}: lane state diverged across evict/re-materialize"
        );
    }

    let par = run_sim(cfg, 8);
    assert_rounds_bitwise_equal(
        seq.recorder.rounds(),
        par.recorder.rounds(),
        "capped lazy w1 vs w8",
    );
    assert_eq!(
        seq.lane_fingerprints(),
        par.lane_fingerprints(),
        "capped fingerprints diverged across worker counts"
    );
    assert_eq!(seq.total_uplink(), par.total_uplink());
    assert_eq!(seq.lanes.eviction_count(), par.lanes.eviction_count());
    assert_eq!(seq.lanes.materializations(), par.lanes.materializations());
}

/// With a cap that clears the per-round cohort, the resident count ends at
/// or below the cap while the population is far larger — the `exp scale2`
/// bound in miniature.
#[test]
fn residency_cap_bounds_resident_lanes() {
    let mut cfg = base_cfg("it-lanes-cap", gradestc8());
    cfg.num_clients = 32;
    cfg.participation = 0.25; // 8 concurrent
    cfg.samples_per_client = 16;
    cfg.rounds = 5;
    cfg.lanes = LaneConfig { lazy: true, max_resident: 12, legacy_shards: false };
    let sim = run_sim(cfg, 1);
    assert!(
        sim.lanes.resident() <= 12,
        "{} lanes resident — the LRU cap is 12",
        sim.lanes.resident()
    );
    assert!(sim.lanes.eviction_count() > 0, "5 sampled rounds must overflow cap 12");
    assert!(
        sim.lanes.materializations() > 12,
        "materializations follow dispatches, not the cap"
    );
}

/// Sampled-never clients cost ~0: a lazy run over a population much larger
/// than the dispatched set leaves most slots empty, and server basis
/// memory follows the materialized lanes, strictly below the naive
/// `clients × basis` baseline.
#[test]
fn sampled_never_lanes_cost_nothing() {
    let mut cfg = base_cfg("it-lanes-never", gradestc8());
    cfg.num_clients = 64;
    cfg.participation = 0.125; // 8 concurrent
    cfg.samples_per_client = 16;
    cfg.rounds = 3;
    let model = cfg.model;
    let sim = run_sim(cfg, 1);
    let n = sim.lanes.len();
    assert_eq!(n, 64);
    // 3 rounds of 8 sampled clients touch at most 24 of the 64.
    assert!(
        (sim.lanes.materializations() as usize) < n,
        "lazy lanes materialized the whole population"
    );
    assert!(sim.lanes.resident() < n);
    let fps = sim.lane_fingerprints();
    assert!(
        fps.iter().any(|&f| f == (0, 0)),
        "some lane must never have materialized"
    );
    let per_lane = basis_bytes_per_lane(
        &layer_table(model),
        &GradEstcParams { k: 8, ..Default::default() },
    );
    let pool = sim.basis_pool_stats();
    assert!(pool.entries > 0);
    assert!(
        pool.bytes() < n * per_lane,
        "pool {} bytes not below the naive {n}-lane baseline {}",
        pool.bytes(),
        n * per_lane
    );
}

/// The frozen reference path: `legacy_shards` still builds the population
/// eagerly from the pre-virtual-lane sequential RNG walk, with every lane
/// resident for the run's lifetime and no eviction machinery engaged.
#[test]
fn legacy_shards_reference_path_runs_fully_materialized() {
    let mut cfg = base_cfg("it-lanes-legacy", gradestc8());
    cfg.rounds = 2;
    cfg.lanes = LaneConfig { lazy: false, max_resident: 0, legacy_shards: true };
    let sim = run_sim(cfg, 1);
    assert_eq!(sim.lanes.resident(), 8);
    assert_eq!(sim.lanes.materializations(), 8);
    assert_eq!(sim.lanes.eviction_count(), 0);
    for (cid, (client_fp, server_fp)) in sim.lane_fingerprints().iter().enumerate() {
        assert_eq!(client_fp, server_fp, "client {cid}: lane state diverged");
        assert_ne!(*client_fp, 0, "client {cid}: legacy lanes are all materialized");
    }
}
