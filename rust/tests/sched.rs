//! Integration tests for the scheduler plane (`gradestc::sched`): sync
//! bit-equivalence with the legacy engine, async determinism across worker
//! counts, semi-sync straggler rollover, and the single-charge ledger
//! invariant (native backend: hermetic, no artifacts needed).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use gradestc::compress::gradestc::basis_bytes_per_lane;
use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::Simulation;
use gradestc::metrics::RoundRecord;
use gradestc::model::meta::layer_table;
use gradestc::net::{Loopback, Transport};

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 5,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

fn gradestc8() -> CompressorKind {
    CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() })
}

/// Assert two round traces are bit-identical (floats compared by bits so
/// NaN evals also count as equal).
fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label}: loss, round {r}");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: accuracy, round {r}"
        );
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label}: test_loss, round {r}");
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(
            x.sim_time_s.to_bits(),
            y.sim_time_s.to_bits(),
            "{label}: sim_time, round {r}"
        );
        assert_eq!(
            x.sim_clock_s.to_bits(),
            y.sim_clock_s.to_bits(),
            "{label}: sim_clock, round {r}"
        );
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

/// Run a config through the scheduler plane at a worker count; returns the
/// trace, the lane fingerprints at the end, and the ledger uplink total.
fn run_scheduled(
    mut cfg: ExperimentConfig,
    workers: usize,
) -> (Vec<RoundRecord>, Vec<(u64, u64)>, u64) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run_scheduled().unwrap();
    (sim.recorder.rounds().to_vec(), sim.lane_fingerprints(), sim.total_uplink())
}

/// Satellite acceptance: `--sched sync` is bit-identical to the legacy
/// engine — same records (including the virtual clock), same ledger
/// totals — for the paper's method and a stateless baseline, with
/// dropout, heterogeneous links, and a straggler deadline all enabled, at
/// sequential and parallel worker counts.
#[test]
fn sync_scheduler_bit_identical_to_legacy_engine() {
    for (label, comp) in
        [("gradestc", gradestc8()), ("topk", CompressorKind::TopK { frac: 0.1 })]
    {
        let mut cfg = base_cfg(&format!("it-sched-sync-{label}"), comp);
        cfg.net.dropout = 0.2;
        cfg.net.het_spread = 0.5;
        cfg.net.deadline_s = 2.0;
        for workers in [1usize, 8] {
            let mut legacy_cfg = cfg.clone();
            legacy_cfg.workers = workers;
            let mut legacy = Simulation::build(legacy_cfg).unwrap();
            legacy.run().unwrap(); // the pre-scheduler lockstep loop
            let (sched, _, sched_up) = run_scheduled(cfg.clone(), workers);
            assert_rounds_bitwise_equal(
                legacy.recorder.rounds(),
                &sched,
                &format!("{label} legacy vs sched-sync w{workers}"),
            );
            assert_eq!(
                legacy.total_uplink(),
                sched_up,
                "{label} w{workers}: ledger totals diverged"
            );
        }
    }
}

/// Tentpole determinism bar: the async scheduler's event order, records
/// (= apply sequence, survivors, virtual clock), and paired lane
/// fingerprints are bit-identical at workers = 1 vs 8, with dropout and
/// heterogeneous links on.
#[test]
fn async_scheduler_bit_identical_across_workers() {
    let mut cfg = base_cfg("it-sched-async-det", gradestc8());
    cfg.rounds = 5; // applies
    cfg.net.het_spread = 1.0;
    cfg.net.dropout = 0.1;
    cfg.sched.kind = SchedKind::Async { k: 3, staleness_p: 0.5 };
    let (seq, fp_seq, up_seq) = run_scheduled(cfg.clone(), 1);
    let (par, fp_par, up_par) = run_scheduled(cfg, 8);
    assert_rounds_bitwise_equal(&seq, &par, "async w1 vs w8");
    assert_eq!(fp_seq, fp_par, "lane fingerprints diverged across worker counts");
    assert_eq!(up_seq, up_par, "ledger totals diverged across worker counts");
    // The apply sequence folded someone every apply.
    assert!(seq.iter().all(|r| r.survivors.len() == 3), "every apply folds exactly k");
}

/// Out-of-order arrival must not break the paired compressor/decompressor
/// lockstep: after an async run every lane's client and server
/// fingerprints (GradESTC basis bits) are equal, including lanes whose
/// last upload was still in flight at shutdown — and the basis pool's
/// resident bytes stay bounded by the population's basis set (the COW
/// churn of out-of-order updates must release every stale generation).
#[test]
fn async_keeps_lane_state_lockstep() {
    let mut cfg = base_cfg("it-sched-async-lockstep", gradestc8());
    cfg.rounds = 4;
    cfg.net.het_spread = 1.5;
    cfg.sched.kind = SchedKind::Async { k: 2, staleness_p: 1.0 };
    let n = cfg.num_clients;
    let model = cfg.model;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run_scheduled().unwrap();
    for (cid, (client_fp, server_fp)) in sim.lane_fingerprints().iter().enumerate() {
        assert_eq!(client_fp, server_fp, "client {cid}: lane state diverged");
        assert_ne!(*client_fp, 0, "client {cid}: fingerprints must cover bases");
    }
    let pool = sim.basis_pool_stats();
    let per_lane =
        basis_bytes_per_lane(&layer_table(model), &GradEstcParams { k: 8, ..Default::default() });
    assert!(pool.entries > 0, "dispatched lanes must intern bases");
    assert_eq!(
        pool.bytes(),
        n * per_lane,
        "all {n} lanes ran: pool must hold exactly their live bases (no stale COW generations)"
    );
}

/// Acceptance: under heterogeneous links the async scheduler completes
/// the same workload in strictly less virtual time than sync — both per
/// record (sync waits for the slowest of 8 log-normal links every round;
/// async applies at the pace of the 2 fastest arrivals) and measured as
/// virtual time-to-target-accuracy.
#[test]
fn async_beats_sync_virtual_time_under_heterogeneous_links() {
    let mut sync_cfg = base_cfg("it-sched-tta-sync", gradestc8());
    sync_cfg.rounds = 8;
    sync_cfg.net.het_spread = 1.5;
    let (sync_recs, _, _) = run_scheduled(sync_cfg.clone(), 1);

    let mut async_cfg = sync_cfg.clone();
    async_cfg.name = "it-sched-tta-async".into();
    async_cfg.rounds = 24; // applies are much smaller steps; give parity budget
    async_cfg.sched.kind = SchedKind::Async { k: 2, staleness_p: 0.5 };
    let (async_recs, _, _) = run_scheduled(async_cfg, 1);

    // Structural: after the same number of records, the async clock is
    // strictly behind the sync clock.
    let n = sync_recs.len().min(async_recs.len());
    assert!(
        async_recs[n - 1].sim_clock_s < sync_recs[n - 1].sim_clock_s,
        "async clock {} !< sync clock {} after {n} records",
        async_recs[n - 1].sim_clock_s,
        sync_recs[n - 1].sim_clock_s
    );

    // Time-to-target-accuracy: both control flows must reach a modest
    // fixed bar, and async must get there in strictly less virtual time.
    let target = 0.40f64;
    let hit = |recs: &[RoundRecord], who: &str| -> f64 {
        recs.iter()
            .find(|r| !r.test_accuracy.is_nan() && r.test_accuracy >= target)
            .unwrap_or_else(|| panic!("{who} never reached {target}"))
            .sim_clock_s
    };
    let t_sync = hit(&sync_recs, "sync");
    let t_async = hit(&async_recs, "async");
    assert!(
        t_async < t_sync,
        "async time-to-target {t_async}s !< sync {t_sync}s"
    );
}

/// A transport wrapper that counts every uploaded byte at the moment it
/// enters the fabric — the independent ground truth for the ledger.
struct CountingLoopback {
    inner: Loopback,
    uplink_bytes: Arc<AtomicU64>,
}

impl Transport for CountingLoopback {
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> anyhow::Result<()> {
        self.inner.broadcast(to, frame)
    }
    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)> {
        self.inner.drain_broadcasts()
    }
    fn upload(&mut self, from: usize, frame: Vec<u8>) -> anyhow::Result<()> {
        self.uplink_bytes.fetch_add(frame.len() as u64, Ordering::SeqCst);
        self.inner.upload(from, frame)
    }
    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)> {
        self.inner.drain_uploads()
    }
}

/// Satellite bugfix regression: semi-sync straggler accounting never
/// double-charges. Every uploaded frame's bytes are charged exactly once
/// — in the round the upload finished crossing the wire (or the trailing
/// drain for uploads still in flight at shutdown) — so the ledger total
/// equals the transport's independent byte count exactly.
#[test]
fn semisync_ledger_charges_each_rolled_upload_once() {
    let mut cfg = base_cfg("it-sched-semisync-ledger", gradestc8());
    cfg.rounds = 5;
    cfg.net.het_spread = 1.0;
    cfg.net.deadline_s = 0.15; // tight: the slow tail straggles and rolls over
    cfg.sched.kind = SchedKind::SemiSync;
    let mut sim = Simulation::build(cfg).unwrap();
    let counter = Arc::new(AtomicU64::new(0));
    sim.set_transport(Box::new(CountingLoopback {
        inner: Loopback::new(),
        uplink_bytes: counter.clone(),
    }));
    sim.run_scheduled().unwrap();
    let crossed = counter.load(Ordering::SeqCst);
    assert!(crossed > 0, "no uplink traffic simulated");
    assert_eq!(
        sim.total_uplink(),
        crossed,
        "ledger charged {} bytes but {} crossed the transport (double- or un-charged rollover)",
        sim.total_uplink(),
        crossed
    );
    // Per-round records can sum to less than the ledger (uploads still in
    // flight at shutdown are charged outside any round) but never more.
    let recorded: u64 = sim.recorder.rounds().iter().map(|r| r.uplink_bytes).sum();
    assert!(recorded <= crossed, "records {recorded} exceed crossed bytes {crossed}");
}

/// Semi-sync rollover semantics: with an impossibly tight deadline no
/// update is on time, yet — unlike the sync engine, which discards late
/// updates forever — stragglers are folded by the round open when they
/// land: the model moves, empty-fold rounds and rollover-fold rounds
/// alternate, and paired lane state stays in lockstep throughout.
#[test]
fn semisync_rolls_stragglers_into_later_rounds() {
    let mut cfg = base_cfg("it-sched-semisync-rollover", gradestc8());
    cfg.num_clients = 4;
    cfg.rounds = 6;
    cfg.net.deadline_s = 1e-9;
    cfg.sched.kind = SchedKind::SemiSync;
    let mut sim = Simulation::build(cfg).unwrap();
    let before = sim.global.clone();
    sim.run_scheduled().unwrap();
    let recs = sim.recorder.rounds();
    assert!(recs[0].survivors.is_empty(), "round 0 cannot aggregate anyone on time");
    assert!(
        recs.iter().any(|r| !r.survivors.is_empty()),
        "stragglers were never rolled into a later round"
    );
    assert_ne!(sim.global, before, "rolled-over updates must move the model");
    for (cid, (client_fp, server_fp)) in sim.lane_fingerprints().iter().enumerate() {
        assert_eq!(client_fp, server_fp, "client {cid}: lane state diverged under rollover");
    }
    // Rollover decode order must not leak stale basis generations: the
    // pool holds exactly the 4 live lanes' bases.
    let per_lane = basis_bytes_per_lane(
        &layer_table(gradestc::config::ModelKind::LeNet5),
        &GradEstcParams { k: 8, ..Default::default() },
    );
    assert_eq!(sim.basis_pool_stats().bytes(), 4 * per_lane);
    // The virtual clock only moves forward.
    assert!(
        recs.windows(2).all(|w| w[0].sim_clock_s <= w[1].sim_clock_s),
        "virtual clock ran backwards"
    );
}

/// Semi-sync without a deadline degenerates to wait-for-everyone and
/// still learns; a non-zero compute model stretches the virtual clock but
/// never the byte accounting.
#[test]
fn semisync_no_deadline_learns_and_compute_model_only_affects_time() {
    let mut cfg = base_cfg("it-sched-semisync-plain", gradestc8());
    cfg.num_clients = 4;
    cfg.rounds = 4;
    cfg.sched.kind = SchedKind::SemiSync;
    let (plain, _, plain_up) = run_scheduled(cfg.clone(), 1);

    cfg.sched.compute_base_s = 0.5;
    cfg.sched.compute_spread = 0.5;
    let (slow, _, slow_up) = run_scheduled(cfg, 1);

    assert_eq!(plain_up, slow_up, "compute time must not change bytes");
    for (a, b) in plain.iter().zip(&slow) {
        assert_eq!(a.uplink_bytes, b.uplink_bytes);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert!(b.sim_time_s > a.sim_time_s, "compute time must stretch the round");
    }
    let best = slow
        .iter()
        .map(|r| r.test_accuracy)
        .filter(|a| !a.is_nan())
        .fold(0.0f64, f64::max);
    assert!(best > 0.35, "semisync stopped learning: best acc {best}");
}

/// Async participation sampling (PR 5): with `participation < 1.0` the
/// async scheduler keeps only `round(participation · n)` clients in
/// flight, refilling freed slots by uniform draws over the idle pool on a
/// dedicated stream — and stays bit-identical across worker counts, with
/// every lane's paired state in lockstep.
#[test]
fn async_sampling_bit_identical_across_workers() {
    let mut cfg = base_cfg("it-sched-async-sampling-det", gradestc8());
    cfg.num_clients = 32;
    cfg.participation = 0.25; // 8 concurrent out of 32
    cfg.samples_per_client = 32;
    cfg.rounds = 4;
    cfg.net.het_spread = 1.0;
    cfg.net.dropout = 0.1;
    cfg.sched.kind = SchedKind::Async { k: 4, staleness_p: 0.5 };
    let (seq, fp_seq, up_seq) = run_scheduled(cfg.clone(), 1);
    let (par, fp_par, up_par) = run_scheduled(cfg, 8);
    assert_rounds_bitwise_equal(&seq, &par, "async-sampled w1 vs w8");
    assert_eq!(fp_seq, fp_par, "lane fingerprints diverged across worker counts");
    assert_eq!(up_seq, up_par, "ledger totals diverged across worker counts");
    // Every apply still folds exactly k arrivals.
    assert!(seq.iter().all(|r| r.survivors.len() == 4));
    // The population is genuinely larger than the working set: 4 applies
    // of 4 arrivals can touch at most 16 of the 32 clients.
    let folded: std::collections::BTreeSet<usize> =
        seq.iter().flat_map(|r| r.survivors.iter().copied()).collect();
    assert!(folded.len() < 32, "sampling cannot have folded every client");
    assert!(!folded.is_empty());
}

/// Population ≫ concurrent clients is the pool's reason to exist: after a
/// sampled async run, server basis memory follows the lanes that were
/// actually dispatched, strictly below the naive `clients × basis`
/// baseline — while lockstep holds for dispatched and idle lanes alike.
#[test]
fn async_sampling_keeps_lockstep_and_bounds_pool_memory() {
    let mut cfg = base_cfg("it-sched-async-sampling-pool", gradestc8());
    cfg.num_clients = 32;
    cfg.participation = 0.25;
    cfg.samples_per_client = 32;
    cfg.rounds = 3;
    cfg.net.het_spread = 1.0;
    cfg.sched.kind = SchedKind::Async { k: 4, staleness_p: 0.5 };
    let n = cfg.num_clients;
    let model = cfg.model;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run_scheduled().unwrap();
    for (cid, (client_fp, server_fp)) in sim.lane_fingerprints().iter().enumerate() {
        assert_eq!(client_fp, server_fp, "client {cid}: lane state diverged");
    }
    let per_lane =
        basis_bytes_per_lane(&layer_table(model), &GradEstcParams { k: 8, ..Default::default() });
    let pool = sim.basis_pool_stats();
    assert!(pool.entries > 0, "dispatched lanes must intern bases");
    assert!(
        pool.bytes() < n * per_lane,
        "pool {} bytes not below the naive {n}-lane baseline {}",
        pool.bytes(),
        n * per_lane
    );
}

/// Event-loop micro-batching regression (PR 6): with homogeneous links
/// (`het_spread = 0`, the default) every dispatched cohort's uploads land
/// at the *same* virtual instant, so the async loop's co-temporal path —
/// drain the whole instant in event order, coalesce the freed slots into
/// one batched re-dispatch — is exercised on every apply. The batched
/// dispatch fans the client phase across workers, so the bar is the same
/// as everywhere else in the plane: bit-identical records, lane
/// fingerprints, and ledger totals at workers = 1 vs 8.
#[test]
fn async_cotemporal_arrivals_batch_dispatch_deterministically() {
    let mut cfg = base_cfg("it-sched-async-cotemporal", CompressorKind::None);
    cfg.rounds = 6; // applies
    cfg.sched.kind = SchedKind::Async { k: 4, staleness_p: 0.5 };
    // Deliberately no het_spread / dropout: identical links are what make
    // all 8 arrivals co-temporal and the micro-batch non-trivial.
    let (seq, fp_seq, up_seq) = run_scheduled(cfg.clone(), 1);
    let (par, fp_par, up_par) = run_scheduled(cfg.clone(), 8);
    assert_rounds_bitwise_equal(&seq, &par, "async co-temporal w1 vs w8");
    assert_eq!(fp_seq, fp_par, "lane fingerprints diverged across worker counts");
    assert_eq!(up_seq, up_par, "ledger totals diverged across worker counts");
    // Every apply folds exactly k co-temporal arrivals…
    assert!(seq.iter().all(|r| r.survivors.len() == 4), "every apply folds exactly k");
    // …and the whole 8-client cohort lands in one instant, so consecutive
    // applies alternate between the two halves of the cohort at the same
    // virtual clock reading (the batched path, not one-at-a-time refills).
    assert_eq!(
        seq[0].sim_clock_s.to_bits(),
        seq[1].sim_clock_s.to_bits(),
        "first two applies must drain the same co-temporal instant"
    );
    // The same holds with the paper's stateful compressor on the lanes.
    let mut gcfg = base_cfg("it-sched-async-cotemporal-gradestc", gradestc8());
    gcfg.rounds = 4;
    gcfg.sched.kind = SchedKind::Async { k: 4, staleness_p: 0.5 };
    let (gseq, gfp_seq, _) = run_scheduled(gcfg.clone(), 1);
    let (gpar, gfp_par, _) = run_scheduled(gcfg, 8);
    assert_rounds_bitwise_equal(&gseq, &gpar, "async co-temporal gradestc w1 vs w8");
    assert_eq!(gfp_seq, gfp_par, "gradestc lane fingerprints diverged");
}

/// The scheduled sync path is the default: `run_scheduled` on an
/// untouched config equals `run` on the same config, so callers switching
/// to the scheduler entry point (the CLI did) change nothing.
#[test]
fn default_config_run_scheduled_equals_run() {
    let cfg = base_cfg("it-sched-default", CompressorKind::TopK { frac: 0.1 });
    let mut a = Simulation::build(cfg.clone()).unwrap();
    a.run().unwrap();
    let (b, _, b_up) = run_scheduled(cfg, 1);
    assert_rounds_bitwise_equal(a.recorder.rounds(), &b, "run vs run_scheduled");
    assert_eq!(a.total_uplink(), b_up);
}
