//! Integration tests for the telemetry plane (`gradestc::telemetry`):
//! tracing never perturbs results (traced w1 / traced w8 / untraced runs
//! are bit-identical for every scheduler), the disabled path allocates
//! nothing, round snapshots ride on `RoundRecord::ext` with pool gauges
//! backed by a real sweep, the async observer sees every folded arrival
//! exactly once, and the legacy round-hook similarity probe works under
//! semisync and async via the observer adapter (native backend: hermetic,
//! no artifacts needed).

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::{RoundHookView, Simulation};
use gradestc::metrics::{RoundRecord, SimilarityProbe};
use gradestc::model::meta::layer_table;
use gradestc::telemetry::{ApplyEvent, ArrivalEvent, DispatchEvent, Observer};

fn base_cfg(name: &str, comp: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 4,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: comp,
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

fn gradestc8() -> CompressorKind {
    CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() })
}

/// Bitwise comparison of the scalar record fields (floats by bits so NaN
/// evals also count as equal). `ext` is deliberately not compared: it is
/// observation, present only on traced runs.
fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label}: loss, round {r}");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: accuracy, round {r}"
        );
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label}: test_loss, round {r}");
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "{label}: downlink, round {r}");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label}: sim_time, round {r}");
        assert_eq!(
            x.sim_clock_s.to_bits(),
            y.sim_clock_s.to_bits(),
            "{label}: sim_clock, round {r}"
        );
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

/// Run a config through the scheduler plane, optionally traced; returns
/// the records, lane fingerprints, ledger uplink total, and span count
/// (0 when untraced).
fn run_cfg(
    mut cfg: ExperimentConfig,
    workers: usize,
    traced: bool,
) -> (Vec<RoundRecord>, Vec<(u64, u64)>, u64, usize) {
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    if traced {
        sim.enable_telemetry();
    }
    sim.run_scheduled().unwrap();
    let spans = sim.telemetry().map_or(0, |t| t.span_count());
    (sim.recorder.rounds().to_vec(), sim.lane_fingerprints(), sim.total_uplink(), spans)
}

/// Tentpole acceptance: tracing observes without participating. For every
/// scheduler × {GradESTC, TopK}, with dropout, heterogeneous links, and a
/// straggler deadline on, the untraced run, the traced sequential run,
/// and the traced 8-worker run produce bit-identical records, lane
/// fingerprints, and ledger totals — and the traced runs actually
/// recorded spans.
#[test]
fn traced_runs_bit_identical_to_untraced_at_any_worker_count() {
    for kind in [
        SchedKind::Sync,
        SchedKind::SemiSync,
        SchedKind::Async { k: 3, staleness_p: 0.5 },
    ] {
        for (label, comp) in
            [("gradestc", gradestc8()), ("topk", CompressorKind::TopK { frac: 0.1 })]
        {
            let mut cfg =
                base_cfg(&format!("it-tel-{}-{label}", kind.name()), comp);
            cfg.net.dropout = 0.1;
            cfg.net.het_spread = 0.5;
            cfg.net.deadline_s = 2.0;
            cfg.sched.kind = kind;
            let tag = format!("{} {label}", kind.name());
            let (plain, fp_plain, up_plain, spans_plain) = run_cfg(cfg.clone(), 1, false);
            let (t1, fp1, up1, spans1) = run_cfg(cfg.clone(), 1, true);
            let (t8, fp8, up8, spans8) = run_cfg(cfg, 8, true);
            assert_eq!(spans_plain, 0, "{tag}: untraced run recorded spans");
            assert!(spans1 > 0, "{tag}: traced run recorded no spans");
            assert_eq!(spans1, spans8, "{tag}: span count depends on workers");
            assert_rounds_bitwise_equal(&plain, &t1, &format!("{tag}: untraced vs traced w1"));
            assert_rounds_bitwise_equal(&t1, &t8, &format!("{tag}: traced w1 vs w8"));
            assert_eq!(fp_plain, fp1, "{tag}: lane fingerprints untraced vs traced");
            assert_eq!(fp1, fp8, "{tag}: lane fingerprints w1 vs w8");
            assert_eq!(up_plain, up1, "{tag}: uplink untraced vs traced");
            assert_eq!(up1, up8, "{tag}: uplink w1 vs w8");
        }
    }
}

/// Disabled-path contract: without `enable_telemetry()` the simulation
/// holds no telemetry handle and records carry no snapshot.
#[test]
fn telemetry_disabled_by_default() {
    let cfg = base_cfg("it-tel-disabled", gradestc8());
    let mut sim = Simulation::build(cfg).unwrap();
    assert!(sim.telemetry().is_none(), "telemetry allocated without opt-in");
    sim.run_scheduled().unwrap();
    assert!(sim.telemetry().is_none());
    for rec in sim.recorder.rounds() {
        assert!(rec.ext.is_none(), "round {} carries a snapshot untraced", rec.round);
    }
}

/// Traced runs freeze one metrics snapshot per record: per-round counters
/// match the record's own fields, phase timings are populated for both
/// clocks, transport bytes fold in, and the basis-pool gauges agree with
/// a live (post-sweep) `basis_pool_stats()` — the regression for the
/// sweep-on-stats bug, driven here by the telemetry round-end path.
#[test]
fn round_snapshots_carry_counters_phases_and_pool_gauges() {
    let cfg = base_cfg("it-tel-snapshots", gradestc8());
    let mut sim = Simulation::build(cfg).unwrap();
    sim.enable_telemetry();
    sim.run_scheduled().unwrap();
    let records = sim.recorder.rounds().to_vec();
    assert!(!records.is_empty());
    for rec in &records {
        let ext = rec.ext.as_ref().unwrap_or_else(|| panic!("round {}: no snapshot", rec.round));
        assert_eq!(ext.round, rec.round as u64);
        assert_eq!(
            ext.counters["dispatches"],
            rec.survivors.len() as u64,
            "round {}: dispatch counter vs survivors",
            rec.round
        );
        assert_eq!(ext.counters["sum_d"], rec.sum_d, "round {}: sum_d counter", rec.round);
        assert!(
            ext.counters["transport.broadcast_bytes"] > 0,
            "round {}: no transport bytes",
            rec.round
        );
        for phase in ["broadcast_encode", "server_decode", "eval"] {
            assert!(
                ext.phase_host_us.contains_key(phase),
                "round {}: missing host phase {phase}",
                rec.round
            );
        }
        assert!(
            ext.phase_virt_s.contains_key("uplink_transit"),
            "round {}: no virtual-clock transit spans",
            rec.round
        );
        // GradESTC pays per-lane basis bytes on the wire.
        assert!(ext.counters["bytes.basis"] > 0, "round {}: no basis bytes", rec.round);
    }
    // The last snapshot's pool gauges were taken through `stats()` — the
    // sweep — so they must agree with the live swept stats now.
    let pool = sim.basis_pool_stats();
    let last = records.last().unwrap().ext.as_ref().unwrap();
    assert!(pool.entries > 0, "gradestc run interned no bases");
    assert_eq!(last.gauges["pool.entries"], pool.entries as f64);
    assert_eq!(last.gauges["pool.bytes"], pool.bytes() as f64);
    // End-of-run metrics document: one entry per record.
    let tel = sim.telemetry().unwrap();
    let doc = tel.metrics_json();
    assert_eq!(doc.get("sched").unwrap().as_str(), Some("sync"));
    assert_eq!(doc.get("rounds").unwrap().as_arr().unwrap().len(), records.len());
}

/// Counts observer callbacks through shared cells (`Observer` is called
/// on the event-loop thread only, so no `Send` bound is needed).
struct CountingObserver {
    dispatched: Rc<Cell<usize>>,
    arrivals: Rc<Cell<usize>>,
    applies: Rc<Cell<usize>>,
    rounds: Rc<Cell<usize>>,
}

impl Observer for CountingObserver {
    fn on_dispatch(&mut self, ev: &DispatchEvent) {
        self.dispatched.set(self.dispatched.get() + ev.cids.len());
    }
    fn on_arrival(&mut self, ev: &ArrivalEvent) {
        assert!(ev.weight >= 0.0 && ev.weight.is_finite());
        assert!(!ev.updates.is_empty(), "arrival with no layer updates");
        self.arrivals.set(self.arrivals.get() + 1);
    }
    fn on_apply(&mut self, ev: &ApplyEvent) {
        assert!(ev.folded >= 1);
        self.applies.set(self.applies.get() + 1);
    }
    fn on_round(&mut self, _round: usize, rec: &RoundRecord) {
        assert!(rec.ext.is_some(), "traced run: record without snapshot");
        self.rounds.set(self.rounds.get() + 1);
    }
}

/// Satellite acceptance: under async the observer sees every folded
/// arrival exactly once — the arrival count equals k × applies, equals
/// the telemetry fold counters, with one apply/round callback per record
/// (the shutdown drain is silent).
#[test]
fn async_observer_sees_every_fold_exactly_once() {
    let mut cfg = base_cfg("it-tel-async-observer", gradestc8());
    cfg.net.dropout = 0.1;
    cfg.net.het_spread = 1.0;
    cfg.sched.kind = SchedKind::Async { k: 3, staleness_p: 0.5 };
    let dispatched = Rc::new(Cell::new(0));
    let arrivals = Rc::new(Cell::new(0));
    let applies = Rc::new(Cell::new(0));
    let rounds = Rc::new(Cell::new(0));
    let mut sim = Simulation::build(cfg.clone()).unwrap();
    sim.enable_telemetry();
    sim.set_observer(Box::new(CountingObserver {
        dispatched: dispatched.clone(),
        arrivals: arrivals.clone(),
        applies: applies.clone(),
        rounds: rounds.clone(),
    }));
    sim.run_scheduled().unwrap();
    let records = sim.recorder.rounds();
    assert_eq!(records.len(), cfg.rounds);
    assert_eq!(arrivals.get(), 3 * records.len(), "arrivals != k × applies");
    assert_eq!(applies.get(), records.len(), "one on_apply per record");
    assert_eq!(rounds.get(), records.len(), "one on_round per record");
    assert!(dispatched.get() >= arrivals.get(), "every fold was dispatched first");
    let folds_counted: u64 = records
        .iter()
        .map(|r| r.ext.as_ref().unwrap().counters["folds"])
        .sum();
    assert_eq!(folds_counted, arrivals.get() as u64, "fold counters vs observed arrivals");
}

/// Satellite acceptance: the Fig. 1 similarity probe — installed through
/// the legacy `set_round_hook` API, now an adapter over the observer
/// stream — records gradients under semisync *and* async, where the old
/// sync-only hook never fired.
#[test]
fn similarity_probe_runs_under_semisync_and_async() {
    for kind in [SchedKind::SemiSync, SchedKind::Async { k: 2, staleness_p: 0.5 }] {
        let mut cfg = base_cfg(
            &format!("it-tel-probe-{}", kind.name()),
            CompressorKind::None,
        );
        cfg.rounds = 3;
        cfg.sched.kind = kind;
        let meta = layer_table(cfg.model);
        let names: Vec<String> = meta.layers.iter().map(|l| l.name.clone()).collect();
        let probe = Rc::new(RefCell::new(SimilarityProbe::new(names)));
        let probe2 = probe.clone();
        let mut sim = Simulation::build(cfg).unwrap();
        sim.set_round_hook(Box::new(move |_round, view: &RoundHookView| {
            if let Some((_, tensors)) = view.updates.iter().find(|(id, _)| *id == 0) {
                probe2.borrow_mut().record_round(tensors.clone());
            }
        }));
        sim.run_scheduled().unwrap();
        let recorded = probe.borrow().rounds();
        assert!(recorded > 0, "{}: probe saw no rounds for client 0", kind.name());
    }
}
