//! Integration tests for the compute-backend plane (`gradestc::linalg`):
//! the scalar-vs-blocked numerics contract over ragged shapes, and the
//! end-to-end determinism bar — every backend must produce bit-identical
//! `RoundRecord` streams at any worker count (native backend: hermetic,
//! no artifacts needed).
//!
//! Two numeric regimes are locked in (see `linalg/backend.rs` docs):
//!
//! * **bit-exact** where the blocked kernel preserves the scalar
//!   per-element operation sequence (`matmul_acc` — the server fold);
//! * **≤1e-5 relative** where fixed-lane partial sums reassociate the
//!   reduction (`matmul`, `matmul_at_b`, `matmul_a_bt`, `dot*`).

use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig,
};
use gradestc::coordinator::Simulation;
use gradestc::linalg::{Backend, BlockedBackend, Mat, ScalarBackend};
use gradestc::metrics::RoundRecord;
use gradestc::util::rng::Pcg64;

/// Ragged sweep dimensions: 1, small primes, multiples and non-multiples
/// of the blocked kernel's MR=4 / NR=16 tiles and the 8-lane dot split.
const DIMS: [usize; 7] = [1, 3, 4, 7, 16, 17, 31];

/// `|a - b| <= tol * max(1, ||b||_F)` everywhere.
fn rel_close(a: &Mat, b: &Mat, tol: f32) -> bool {
    a.max_abs_diff(b) <= tol * b.fro_norm().max(1.0)
}

/// Blocked-vs-scalar over every ragged `(m, k, n)` combination: `matmul`,
/// `matmul_at_b`, `matmul_a_bt` within 1e-5 relative, `matmul_acc`
/// bit-exact (same per-element mul-add sequence by construction).
#[test]
fn backends_agree_on_ragged_shapes() {
    let mut rng = Pcg64::seeded(0xBAC0);
    let (s, bl) = (ScalarBackend, BlockedBackend);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = Mat::randn(m, k, &mut rng);
                let b = Mat::randn(k, n, &mut rng);
                let label = format!("({m},{k},{n})");

                let cs = s.matmul(&a, &b);
                let cb = bl.matmul(&a, &b);
                assert!(rel_close(&cb, &cs, 1e-5), "matmul {label}");

                let mut accs = Mat::randn(m, n, &mut rng);
                let mut accb = accs.clone();
                s.matmul_acc(&mut accs, 0.73, &a, &b);
                bl.matmul_acc(&mut accb, 0.73, &a, &b);
                assert_eq!(accs.as_slice(), accb.as_slice(), "matmul_acc {label} not bit-exact");

                // Aᵀ·B with A stored (k, m): the compressor projection.
                let at = Mat::randn(k, m, &mut rng);
                let bt = Mat::randn(k, n, &mut rng);
                assert!(
                    rel_close(&bl.matmul_at_b(&at, &bt), &s.matmul_at_b(&at, &bt), 1e-5),
                    "matmul_at_b {label}"
                );

                // A·Bᵀ with both (·, k): the Gram-matrix path.
                let ga = Mat::randn(m, k, &mut rng);
                let gb = Mat::randn(n, k, &mut rng);
                assert!(
                    rel_close(&bl.matmul_a_bt(&ga, &gb), &s.matmul_a_bt(&ga, &gb), 1e-5),
                    "matmul_a_bt {label}"
                );
            }
        }
    }
}

/// The panel hooks agree too: `dot`/`dot_f64` across lengths straddling
/// the 8- and 4-lane splits, and `axpy` (shared implementation) bit-exact.
#[test]
fn panel_hooks_agree_on_ragged_lengths() {
    let mut rng = Pcg64::seeded(0xBAC1);
    for n in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 63, 64, 65, 257] {
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let ds = ScalarBackend.dot_f64(&x, &y);
        let db = BlockedBackend.dot_f64(&x, &y);
        assert!((ds - db).abs() <= 1e-6 * ds.abs().max(1.0), "dot_f64 n={n}");
        let fs = ScalarBackend.dot(&x, &y);
        let fb = BlockedBackend.dot(&x, &y);
        assert!(((fs - fb) as f64).abs() <= 1e-4 * (fs as f64).abs().max(1.0), "dot n={n}");

        let mut ys = y.clone();
        let mut yb = y.clone();
        ScalarBackend.axpy(&mut ys, -0.25, &x);
        BlockedBackend.axpy(&mut yb, -0.25, &x);
        assert_eq!(
            ys.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "axpy n={n} must be bit-exact (shared element-wise kernel)"
        );
    }
}

fn base_cfg(name: &str, backend: BackendKind) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 4,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 128,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        seed: 11,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend,
        lanes: LaneConfig::default(),
    }
}

fn assert_rounds_bitwise_equal(a: &[RoundRecord], b: &[RoundRecord], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: round count");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label}: loss, round {r}");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{label}: accuracy, round {r}"
        );
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label}: test_loss, round {r}");
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{label}: uplink, round {r}");
        assert_eq!(x.sum_d, y.sum_d, "{label}: sum_d, round {r}");
        assert_eq!(x.survivors, y.survivors, "{label}: survivors, round {r}");
    }
}

fn run(cfg: ExperimentConfig, workers: usize) -> Vec<RoundRecord> {
    let mut cfg = cfg;
    cfg.workers = workers;
    let mut sim = Simulation::build(cfg).unwrap();
    sim.run().unwrap();
    sim.recorder.rounds().to_vec()
}

/// The engine-wide determinism bar holds *per backend*: pinning the
/// experiment to `scalar` or to `blocked` must each yield bit-identical
/// `RoundRecord` streams at workers = 1 vs 8 — the reduction order of a
/// conforming backend is a pure function of problem shape, never of the
/// worker count.
#[test]
fn each_backend_is_bit_identical_across_worker_counts() {
    for kind in [BackendKind::Scalar, BackendKind::Blocked] {
        let cfg = base_cfg(&format!("it-backend-{}", kind.name()), kind);
        let seq = run(cfg.clone(), 1);
        let par = run(cfg, 8);
        assert_rounds_bitwise_equal(&seq, &par, &format!("{} w1 vs w8", kind.name()));
    }
}

/// End-to-end tolerance: the two backends run the same experiment to
/// comparable results — identical survivor sets (float-free), byte
/// accounting within 10% (GradESTC's rank selection `d` sits on a
/// coverage threshold, so last-ulp drift may occasionally shift a payload
/// by a column), losses within a loose tolerance (reassociated reductions
/// drift a few ulps per round; training amplifies but must not explode
/// it), and both backends actually learn.
#[test]
fn scalar_and_blocked_runs_agree_end_to_end() {
    let scalar = run(base_cfg("it-backend-xtol-s", BackendKind::Scalar), 1);
    let blocked = run(base_cfg("it-backend-xtol-b", BackendKind::Blocked), 1);
    assert_eq!(scalar.len(), blocked.len());
    for (s, b) in scalar.iter().zip(&blocked) {
        let (su, bu) = (s.uplink_bytes as f64, b.uplink_bytes as f64);
        assert!(
            (su - bu).abs() <= 0.1 * su.max(1.0),
            "round {}: scalar uplink {su} vs blocked uplink {bu}",
            s.round
        );
        assert_eq!(s.survivors, b.survivors, "round {}: survivors", s.round);
        assert!(
            (s.train_loss - b.train_loss).abs() <= 5e-2 * s.train_loss.abs().max(1.0),
            "round {}: scalar loss {} vs blocked loss {}",
            s.round,
            s.train_loss,
            b.train_loss
        );
    }
    let best = |recs: &[RoundRecord]| {
        recs.iter().map(|r| r.test_accuracy).filter(|a| !a.is_nan()).fold(0.0f64, f64::max)
    };
    assert!(best(&scalar) > 0.5, "scalar stopped learning");
    assert!(best(&blocked) > 0.5, "blocked stopped learning");
}
