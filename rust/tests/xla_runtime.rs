//! Integration: AOT artifacts → PJRT load/execute → agreement with the
//! native substrate.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise, so
//! plain `cargo test` still passes in a fresh checkout).

use gradestc::config::ModelKind;
use gradestc::coordinator::trainer::{Trainer, XlaTrainer};
use gradestc::data::synth::{SynthGenerator, SynthSpec};
use gradestc::linalg::{matmul, matmul_at_b, Mat};
use gradestc::model::meta::layer_table;
use gradestc::model::params::ParamStore;
use gradestc::nn::NativeTrainer;
use gradestc::runtime::{HostTensor, Runtime};
use gradestc::util::rng::Pcg64;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("GRADESTC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at '{dir}' — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_matches_rust_layer_tables() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    for (name, entry) in &rt.manifest().models {
        let kind = match name.as_str() {
            "lenet5" => ModelKind::LeNet5,
            "resnetlite" => ModelKind::ResNetLite,
            "alexnetlite" => ModelKind::AlexNetLite,
            "tinytransformer" => ModelKind::TinyTransformer,
            other => panic!("unknown model in manifest: {other}"),
        };
        let meta = layer_table(kind);
        assert_eq!(entry.layers.len(), meta.layers.len(), "{name}: tensor count");
        for (a, b) in entry.layers.iter().zip(&meta.layers) {
            assert_eq!(a.name, b.name, "{name}");
            assert_eq!(a.shape, b.shape, "{name}/{}", a.name);
            assert_eq!(a.role, b.role, "{name}/{}", a.name);
        }
        assert_eq!(entry.total_params, meta.total_params(), "{name}");
    }
}

#[test]
fn pallas_project_kernel_matches_native_linalg() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let Some(entry) = rt.manifest().find_kernel("project", 96, 48) else {
        panic!("test kernel project.96x48x8 missing from manifest");
    };
    let (l, m, k) = (entry.l, entry.m, entry.rank);
    let mut rng = Pcg64::seeded(11);
    // Orthonormal M via QR of a Gaussian.
    let raw = Mat::randn(l, k, &mut rng);
    let (q, _) = gradestc::linalg::householder_qr(&raw);
    let g = Mat::randn(l, m, &mut rng);

    let out = rt
        .call(
            &entry.file,
            &[
                HostTensor::f32(q.as_slice().to_vec(), &[l, k]),
                HostTensor::f32(g.as_slice().to_vec(), &[l, m]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2, "project kernel returns (A, E)");
    let a_xla = Mat::from_vec(k, m, out[0].as_f32().unwrap().to_vec());
    let e_xla = Mat::from_vec(l, m, out[1].as_f32().unwrap().to_vec());

    let a_native = matmul_at_b(&q, &g);
    let e_native = g.sub(&matmul(&q, &a_native));
    assert!(
        a_xla.max_abs_diff(&a_native) < 1e-3,
        "A diff {}",
        a_xla.max_abs_diff(&a_native)
    );
    assert!(
        e_xla.max_abs_diff(&e_native) < 1e-3,
        "E diff {}",
        e_xla.max_abs_diff(&e_native)
    );
}

#[test]
fn pallas_reconstruct_kernel_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let entry = rt.manifest().find_kernel("reconstruct", 96, 48).unwrap();
    let (l, m, k) = (entry.l, entry.m, entry.rank);
    let mut rng = Pcg64::seeded(13);
    let mmat = Mat::randn(l, k, &mut rng);
    let a = Mat::randn(k, m, &mut rng);
    let out = rt
        .call(
            &entry.file,
            &[
                HostTensor::f32(mmat.as_slice().to_vec(), &[l, k]),
                HostTensor::f32(a.as_slice().to_vec(), &[k, m]),
            ],
        )
        .unwrap();
    let ghat = Mat::from_vec(l, m, out[0].as_f32().unwrap().to_vec());
    let native = matmul(&mmat, &a);
    assert!(ghat.max_abs_diff(&native) < 1e-3);
}

#[test]
fn sketch_kernel_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let entry = rt.manifest().find_kernel("sketch", 96, 48).unwrap();
    let (l, m, s) = (entry.l, entry.m, entry.rank);
    let mut rng = Pcg64::seeded(17);
    let e = Mat::randn(l, m, &mut rng);
    let omega = Mat::randn(m, s, &mut rng);
    let out = rt
        .call(
            &entry.file,
            &[
                HostTensor::f32(e.as_slice().to_vec(), &[l, m]),
                HostTensor::f32(omega.as_slice().to_vec(), &[m, s]),
            ],
        )
        .unwrap();
    let y = Mat::from_vec(l, s, out[0].as_f32().unwrap().to_vec());
    let native = matmul(&e, &omega);
    assert!(y.max_abs_diff(&native) < 1e-2, "diff {}", y.max_abs_diff(&native));
}

/// The decisive cross-check: the XLA train step and the native Rust
/// trainer implement the same semantics. One SGD batch from identical
/// state must produce near-identical loss and parameters.
#[test]
fn xla_and_native_trainers_agree_on_lenet() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = layer_table(ModelKind::LeNet5);
    let xla = XlaTrainer::new(&dir, ModelKind::LeNet5, &meta).unwrap();
    let native = NativeTrainer::new(ModelKind::LeNet5, &meta).unwrap();

    let spec = SynthSpec::for_kind(gradestc::config::DatasetKind::SynthMnist);
    let gen = SynthGenerator::new(spec, 21);
    let mut rng = Pcg64::seeded(22);
    let data = gen.generate(xla.train_batch(), &mut rng);
    let params = ParamStore::init(&meta, &Pcg64::seeded(23));

    // Same rng seed → identical batch schedule in both backends.
    let (p_xla, loss_xla) = xla
        .local_train(&params, &data, 1, xla.train_batch(), 0.05, &mut Pcg64::seeded(9))
        .unwrap();
    let (p_nat, loss_nat) = native
        .local_train(&params, &data, 1, xla.train_batch(), 0.05, &mut Pcg64::seeded(9))
        .unwrap();

    assert!(
        (loss_xla - loss_nat).abs() < 1e-3 * (1.0 + loss_nat.abs()),
        "loss: xla {loss_xla} native {loss_nat}"
    );
    for i in 0..meta.layers.len() {
        let worst = p_xla
            .tensor(i)
            .iter()
            .zip(p_nat.tensor(i))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 5e-4, "tensor {} ({}) diff {worst}", i, meta.layers[i].name);
    }

    // Evaluation agreement.
    let test = gen.generate(128, &mut rng);
    let (el_x, ea_x) = xla.evaluate(&p_xla, &test).unwrap();
    let (el_n, ea_n) = native.evaluate(&p_nat, &test).unwrap();
    assert!((el_x - el_n).abs() < 1e-2 * (1.0 + el_n.abs()), "{el_x} vs {el_n}");
    assert!((ea_x - ea_n).abs() < 0.03, "{ea_x} vs {ea_n}");
}

#[test]
fn grad_step_matches_native_grads() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = layer_table(ModelKind::LeNet5);
    let xla = XlaTrainer::new(&dir, ModelKind::LeNet5, &meta).unwrap();
    let native = NativeTrainer::new(ModelKind::LeNet5, &meta).unwrap();
    let spec = SynthSpec::for_kind(gradestc::config::DatasetKind::SynthMnist);
    let gen = SynthGenerator::new(spec, 31);
    let mut rng = Pcg64::seeded(32);
    let data = gen.generate(xla.train_batch(), &mut rng);
    let params = ParamStore::init(&meta, &Pcg64::seeded(33));

    let (gx, lx) = xla.grads(&params, &data, 32, &mut Pcg64::seeded(4)).unwrap();
    let (gn, ln) = native.grads(&params, &data, 32, &mut Pcg64::seeded(4)).unwrap();
    assert!((lx - ln).abs() < 1e-3 * (1.0 + ln.abs()));
    for (i, (a, b)) in gx.iter().zip(&gn).enumerate() {
        let worst =
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        let scale = b.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(worst < 1e-3 + 1e-2 * scale, "tensor {i}: diff {worst} scale {scale}");
    }
}
