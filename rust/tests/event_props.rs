//! Property-style tests for the deterministic event core
//! (`gradestc::sched::EventQueue`): seeded randomized interleavings of
//! pushes and pops checked against a naive reference model, tie-group
//! push-order stability, `total_cmp` corner cases, replay bit-identity,
//! and the finite-time invariant — plus the end-to-end replay bar: the
//! async event loop is bit-identical at 1, 2, and 8 workers.

use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::Simulation;
use gradestc::sched::EventQueue;
use gradestc::util::rng::Pcg64;

/// A naive priority queue with the same contract — linear-scan min by
/// `(total_cmp(time), seq)` — used as the oracle for randomized runs.
struct NaiveQueue {
    items: Vec<(f64, u64, u64)>, // (time, seq, payload)
    next_seq: u64,
}

impl NaiveQueue {
    fn new() -> Self {
        NaiveQueue { items: Vec::new(), next_seq: 0 }
    }

    fn push(&mut self, time: f64, payload: u64) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.items.push((time, seq, payload));
        seq
    }

    fn pop(&mut self) -> Option<(f64, u64, u64)> {
        if self.items.is_empty() {
            return None;
        }
        let mut best = 0usize;
        for i in 1..self.items.len() {
            let (bt, bs, _) = self.items[best];
            let (t, s, _) = self.items[i];
            if t.total_cmp(&bt).then(s.cmp(&bs)).is_lt() {
                best = i;
            }
        }
        Some(self.items.remove(best))
    }
}

/// Times drawn from a small grid so tie groups are frequent; occasionally
/// -0.0 or a subnormal to exercise the `total_cmp` corners.
fn draw_time(rng: &mut Pcg64) -> f64 {
    match rng.index(20) {
        0 => -0.0,
        1 => 5e-324, // smallest positive subnormal
        i => (i as f64) * 0.25,
    }
}

/// Randomized interleavings against the oracle: every pop (mid-stream and
/// in the final drain) returns exactly the `(time, seq, payload)` the
/// naive model predicts — same minimum, same tie-break — and nothing is
/// lost or duplicated.
#[test]
fn randomized_interleavings_match_reference_model() {
    for seed in 0..32u64 {
        let mut rng = Pcg64::new(seed, 0xE7E27);
        let mut q = EventQueue::new();
        let mut model = NaiveQueue::new();
        let mut pushed = 0u64;
        let mut popped = 0u64;
        for op in 0..300 {
            if rng.index(10) < 6 {
                let t = draw_time(&mut rng);
                let payload = pushed;
                let sq = q.push(t, payload);
                let sm = model.push(t, payload);
                assert_eq!(sq, sm, "seed {seed} op {op}: sequence numbering diverged");
                pushed += 1;
            } else {
                let got = q.pop();
                let want = model.pop();
                match (got, want) {
                    (None, None) => {}
                    (Some((t, s, e)), Some((wt, ws, we))) => {
                        assert_eq!(
                            (t.to_bits(), s, e),
                            (wt.to_bits(), ws, we),
                            "seed {seed} op {op}: pop diverged from the reference model"
                        );
                        popped += 1;
                    }
                    (g, w) => panic!("seed {seed} op {op}: emptiness diverged ({g:?} vs {w:?})"),
                }
            }
        }
        // Final drain: total order over everything left, no event lost.
        let mut last: Option<(f64, u64)> = None;
        while let Some((t, s, e)) = q.pop() {
            let (wt, ws, we) = model.pop().expect("queue holds an event the model lost");
            assert_eq!((t.to_bits(), s, e), (wt.to_bits(), ws, we), "seed {seed}: drain diverged");
            if let Some((lt, ls)) = last {
                assert!(
                    lt.total_cmp(&t).then(ls.cmp(&s)).is_lt(),
                    "seed {seed}: drain not strictly ascending in (time, seq)"
                );
            }
            last = Some((t, s));
            popped += 1;
        }
        assert!(model.pop().is_none(), "seed {seed}: model holds an event the queue lost");
        assert_eq!(popped, pushed, "seed {seed}: {pushed} pushed but {popped} popped");
    }
}

/// Co-temporal events pop in push order regardless of how the tie group
/// is interleaved with other times.
#[test]
fn tie_groups_pop_in_push_order() {
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(seed, 0x71E5);
        let mut q = EventQueue::new();
        for payload in 0..200u64 {
            // Three distinct instants, heavily tied.
            let t = [1.0, 2.0, 3.0][rng.index(3)];
            q.push(t, (t, payload));
        }
        let mut last: Option<(u64, u64)> = None; // (time bits, seq)
        while let Some((t, s, (pt, _))) = q.pop() {
            assert_eq!(t.to_bits(), pt.to_bits(), "payload's time survives the heap");
            if let Some((lt, ls)) = last {
                if lt == t.to_bits() {
                    assert!(ls < s, "seed {seed}: tie group broke push order");
                } else {
                    assert!(f64::from_bits(lt) < t, "seed {seed}: time order broke");
                }
            }
            last = Some((t.to_bits(), s));
        }
    }
}

/// The same seeded op sequence replays to a bit-identical pop trace.
#[test]
fn randomized_replay_is_bit_identical() {
    let run = |seed: u64| -> Vec<(u64, u64, u64)> {
        let mut rng = Pcg64::new(seed, 0x2E91A7);
        let mut q = EventQueue::new();
        let mut trace = Vec::new();
        for payload in 0..400u64 {
            if rng.index(10) < 7 {
                q.push(draw_time(&mut rng), payload);
            } else if let Some((t, s, e)) = q.pop() {
                trace.push((t.to_bits(), s, e));
            }
        }
        while let Some((t, s, e)) = q.pop() {
            trace.push((t.to_bits(), s, e));
        }
        trace
    };
    for seed in [0u64, 1, 42, 0xDEAD] {
        assert_eq!(run(seed), run(seed), "seed {seed}: replay diverged");
    }
}

/// `total_cmp` corners drain in one consistent order: -0.0 strictly
/// before +0.0, subnormals between them and 0.25.
#[test]
fn negative_zero_and_subnormal_order_is_total() {
    let mut q = EventQueue::new();
    q.push(0.25, "quarter");
    q.push(0.0, "poszero");
    q.push(5e-324, "subnormal");
    q.push(-0.0, "negzero");
    let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, _, e)| e)).collect();
    assert_eq!(order, vec!["negzero", "poszero", "subnormal", "quarter"]);
}

/// The finite-time invariant: a NaN virtual time is a bug upstream and is
/// rejected at the push, not silently mis-ordered.
#[test]
#[should_panic(expected = "must be finite")]
fn nan_time_is_rejected() {
    EventQueue::new().push(f64::NAN, 0u8);
}

/// Infinities are equally rejected — virtual clocks never hold them.
#[test]
#[should_panic(expected = "must be finite")]
fn infinite_time_is_rejected() {
    EventQueue::new().push(f64::INFINITY, 0u8);
}

fn base_cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: DatasetKind::SynthMnist,
        model: gradestc::config::ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 4,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.05,
        samples_per_client: 64,
        test_samples: 64,
        eval_every: 1,
        threshold_frac: 0.9,
        compressor: CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        seed: 23,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

/// The end-to-end replay bar the queue exists for: the async event loop —
/// dropout retries, heterogeneous arrivals, co-temporal groups — produces
/// bit-identical records, lane fingerprints, and ledger totals at 1, 2,
/// and 8 workers.
#[test]
fn async_event_loop_replays_bit_identically_at_1_2_8_workers() {
    let mut cfg = base_cfg("it-eventprops-replay");
    cfg.net.het_spread = 1.0;
    cfg.net.dropout = 0.1;
    cfg.sched.kind = SchedKind::Async { k: 3, staleness_p: 0.5 };
    let run = |workers: usize| {
        let mut c = cfg.clone();
        c.workers = workers;
        let mut sim = Simulation::build(c).unwrap();
        sim.run_scheduled().unwrap();
        (
            sim.recorder
                .rounds()
                .iter()
                .map(|r| {
                    (
                        r.round,
                        r.train_loss.to_bits(),
                        r.test_accuracy.to_bits(),
                        r.uplink_bytes,
                        r.sim_clock_s.to_bits(),
                        r.survivors.clone(),
                    )
                })
                .collect::<Vec<_>>(),
            sim.lane_fingerprints(),
            sim.total_uplink(),
        )
    };
    let w1 = run(1);
    let w2 = run(2);
    let w8 = run(8);
    assert_eq!(w1, w2, "async replay diverged between 1 and 2 workers");
    assert_eq!(w1, w8, "async replay diverged between 1 and 8 workers");
}
