//! Scheduler-plane throughput: one short end-to-end run (build + rounds,
//! evaluation after the first record and the last) per control flow —
//! sync vs async-buffered — at worker counts 1 and 8, on an 8-client
//! heterogeneous-link GradESTC workload.
//!
//! Besides the usual `BENCHLINE` output this bench writes
//! `BENCH_sched.json` (in the package root — `rust/BENCH_sched.json` when
//! driven by CI) so the perf trajectory of the scheduler plane is
//! machine-tracked from its first PR. Run with
//! `cargo bench --bench sched` (`GRADESTC_BENCH_FAST=1` for the quick CI
//! budget).

use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, ModelKind, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::Simulation;
use gradestc::util::bench::Bencher;
use std::time::Duration;

fn cfg(kind: SchedKind, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench-sched".into(),
        dataset: DatasetKind::SynthMnist,
        model: ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: 8,
        participation: 1.0,
        rounds: 3,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.03,
        samples_per_client: 64,
        test_samples: 64,
        eval_every: usize::MAX,
        threshold_frac: 0.95,
        compressor: CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        seed: 7,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers,
        net: NetConfig { het_spread: 1.0, ..NetConfig::default() },
        sched: SchedConfig { kind, ..SchedConfig::default() },
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

fn main() {
    let mut b = Bencher::new("sched").budget(
        Duration::from_millis(200),
        Duration::from_millis(2000),
        5,
    );
    let cases: [(&str, SchedKind); 2] = [
        ("sync", SchedKind::Sync),
        ("async-k4", SchedKind::Async { k: 4, staleness_p: 0.5 }),
    ];
    for (sname, kind) in &cases {
        for workers in [1usize, 8] {
            b.bench(&format!("{sname}-8c-r3-w{workers}"), || {
                let mut sim = Simulation::build(cfg(*kind, workers)).unwrap();
                let report = sim.run_scheduled().unwrap();
                std::hint::black_box(report.total_uplink);
            });
        }
    }

    // Machine-readable trajectory file.
    std::fs::write("BENCH_sched.json", b.to_json("")).expect("writing BENCH_sched.json");
    println!("wrote BENCH_sched.json ({} benches)", b.results().len());
}
