//! Scale-plane trajectory: build + round throughput and resident server
//! basis memory for large client populations on the shared-basis pool.
//!
//! Two readings per control flow (sync / async-buffered) at a population
//! the per-client lane model could never have held before interning:
//! wall-clock for `build + run` (the event/dispatch machinery at
//! population scale), and a memory probe comparing the [`BasisPool`]'s
//! live bytes against the naive `clients × basis` baseline plus the
//! process RSS where `/proc/self/statm` exists.
//!
//! Besides the usual `BENCHLINE` output this bench writes
//! `BENCH_scale.json` (package root — `rust/BENCH_scale.json` under CI) so
//! the scale trajectory is machine-tracked from the pool's first PR. Run
//! with `cargo bench --bench scale` (`GRADESTC_BENCH_FAST=1` shrinks the
//! population for the quick CI budget).

use gradestc::compress::gradestc::basis_bytes_per_lane;
use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, ModelKind, NetConfig, SchedConfig, SchedKind,
};
use gradestc::coordinator::Simulation;
use gradestc::model::meta::layer_table;
use gradestc::util::bench::Bencher;
use std::time::Duration;

fn cfg(clients: usize, kind: SchedKind, rounds: usize) -> ExperimentConfig {
    let concurrent = 50.min(clients);
    ExperimentConfig {
        name: "bench-scale".into(),
        dataset: DatasetKind::SynthMnist,
        model: ModelKind::LeNet5,
        distribution: DataDistribution::Iid,
        num_clients: clients,
        participation: concurrent as f64 / clients as f64,
        rounds,
        local_epochs: 1,
        batch_size: 32,
        lr: 0.03,
        samples_per_client: 2,
        test_samples: 32,
        eval_every: usize::MAX,
        threshold_frac: 0.95,
        compressor: CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        seed: 7,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        workers: 0,
        net: NetConfig { het_spread: 1.0, ..NetConfig::default() },
        sched: SchedConfig { kind, ..SchedConfig::default() },
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

/// Process resident set in bytes (Linux; `None` elsewhere).
fn rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * 4096)
}

fn main() {
    let fast = std::env::var("GRADESTC_BENCH_FAST").ok().as_deref() == Some("1");
    let clients = if fast { 1_000 } else { 10_000 };
    let mut b = Bencher::new("scale").budget(
        Duration::from_millis(50),
        Duration::from_millis(400),
        3,
    );
    let cases: [(&str, SchedKind); 2] = [
        ("sync", SchedKind::Sync),
        ("async-k16", SchedKind::Async { k: 16, staleness_p: 0.5 }),
    ];
    for (sname, kind) in &cases {
        b.bench(&format!("{sname}-{clients}c-r2-build+run"), || {
            let mut sim = Simulation::build(cfg(clients, *kind, 2)).unwrap();
            let report = sim.run_scheduled().unwrap();
            std::hint::black_box(report.total_uplink);
        });
    }

    // Build-plane probe: eager population materialization at w1 vs w8
    // (the deterministic `parallel_map` fan-out over cids), and the lazy
    // build that defers every lane to first dispatch.
    let build_cfg = |workers: usize, lazy: bool| -> ExperimentConfig {
        let mut c = cfg(clients, SchedKind::Sync, 2);
        c.workers = workers;
        c.lanes = LaneConfig { lazy, max_resident: 0, legacy_shards: false };
        c
    };
    let eager_w1 = b
        .bench(&format!("build-eager-{clients}c-w1"), || {
            let sim = Simulation::build(build_cfg(1, false)).unwrap();
            std::hint::black_box(sim.lanes.resident());
        })
        .clone();
    let eager_w8 = b
        .bench(&format!("build-eager-{clients}c-w8"), || {
            let sim = Simulation::build(build_cfg(8, false)).unwrap();
            std::hint::black_box(sim.lanes.resident());
        })
        .clone();
    b.bench(&format!("build-lazy-{clients}c"), || {
        let sim = Simulation::build(build_cfg(8, true)).unwrap();
        std::hint::black_box(sim.lanes.resident());
    });
    let speedup = eager_w1.median_ns / eager_w8.median_ns;
    println!("SPEEDUP build-eager-{clients}c w1/w8 = {speedup:.2}x");
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if !fast && cores >= 8 {
        // The acceptance bar for the parallel build: fanning lane
        // materialization across 8 workers must win ≥4× over one.
        assert!(
            speedup >= 4.0,
            "parallel eager build speedup {speedup:.2}x < 4x at w8 ({cores} cores)"
        );
    }

    // Memory probe: one representative sync run, pool vs naive baseline.
    let mut sim = Simulation::build(cfg(clients, SchedKind::Sync, 2)).unwrap();
    sim.run_scheduled().unwrap();
    let pool = sim.basis_pool_stats();
    let naive = basis_bytes_per_lane(
        &layer_table(ModelKind::LeNet5),
        &GradEstcParams { k: 8, ..Default::default() },
    ) as u64
        * clients as u64;
    let rss = rss_bytes().unwrap_or(0);
    println!(
        "MEMLINE scale clients={clients} pool_entries={} pool_bytes={} \
         naive_basis_bytes={naive} rss_bytes={rss} lanes_resident={} \
         lanes_materialized={} lane_evictions={}",
        pool.entries,
        pool.bytes(),
        sim.lanes.resident(),
        sim.lanes.materializations(),
        sim.lanes.eviction_count()
    );

    // Machine-readable trajectory file, with the memory + lane probes
    // spliced in.
    let memory = format!(
        ",\n  \"memory\": {{\"clients\": {clients}, \"pool_entries\": {}, \
         \"pool_bytes\": {}, \"naive_basis_bytes\": {naive}, \"rss_bytes\": {rss}}},\
         \n  \"lanes\": {{\"resident\": {}, \"materialized\": {}, \
         \"evictions\": {}, \"build_speedup_w8\": {speedup:.2}}}",
        pool.entries,
        pool.bytes(),
        sim.lanes.resident(),
        sim.lanes.materializations(),
        sim.lanes.eviction_count()
    );
    std::fs::write("BENCH_scale.json", b.to_json(&memory)).expect("writing BENCH_scale.json");
    println!("wrote BENCH_scale.json ({} benches)", b.results().len());
}
