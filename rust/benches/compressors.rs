//! Compressor throughput benches — one per method in paper Table III,
//! at the real ResNetLite update geometry. These are the per-client,
//! per-round costs the paper's §III-C complexity analysis describes.

use gradestc::compress::{build_pair, Compressor as _};
use gradestc::config::{CompressorKind, GradEstcParams, ModelKind};
use gradestc::model::meta::layer_table;
use gradestc::util::bench::Bencher;
use gradestc::util::rng::Pcg64;

fn main() {
    let meta = layer_table(ModelKind::ResNetLite);
    let mut rng = Pcg64::seeded(1);
    let update: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
    let raw_bytes: u64 = update.iter().map(|t| 4 * t.len() as u64).sum();

    let methods: Vec<(&str, CompressorKind)> = vec![
        ("fedavg", CompressorKind::None),
        ("topk10", CompressorKind::TopK { frac: 0.1 }),
        ("fedpaq8", CompressorKind::FedPaq { bits: 8 }),
        ("signsgd", CompressorKind::SignSgd),
        ("svdfed_k32", CompressorKind::SvdFed { k: 32, gamma: 0.5 }),
        ("fedqclip8", CompressorKind::FedQClip { bits: 8, clip: 2.5 }),
        (
            "gradestc_k32",
            CompressorKind::GradEstc(GradEstcParams { k: 32, ..Default::default() }),
        ),
        (
            "gradestc_k32_fixedd",
            CompressorKind::GradEstc(GradEstcParams {
                k: 32,
                fixed_d: true,
                ..Default::default()
            }),
        ),
    ];

    let mut b = Bencher::new("compress-resnetlite");
    println!("update size: {:.2} MB raw\n", raw_bytes as f64 / 1e6);
    for (name, kind) in methods {
        let (mut c, _) = build_pair(&kind, &meta, 7);
        // Warm the stateful compressors past their init round so the bench
        // measures steady state (the paper's per-round regime).
        let (p0, _) = c.compress(&update);
        let steady = {
            let (p, _) = c.compress(&update);
            p.iter().map(|x| x.wire_bytes()).sum::<u64>()
        };
        b.bench_with_throughput(
            &format!("{name} (steady {:.3} MB, init {:.3} MB)",
                steady as f64 / 1e6,
                p0.iter().map(|x| x.wire_bytes()).sum::<u64>() as f64 / 1e6),
            Some((raw_bytes as f64, "B")),
            || {
                let (p, _) = c.compress(&update);
                std::hint::black_box(p);
            },
        );
    }
}
