//! End-to-end round latency: one full FL round (local training via the
//! XLA artifacts when present, compression, decompression, aggregation,
//! evaluation skipped) per model — the §Perf L3 headline number — plus a
//! worker-count sweep (1/2/4/8) over a 20-client GradESTC round that
//! measures the round engine's parallel speedup, and encode/decode
//! throughput of the wire codec for GradESTC vs Raw payload sets.
//!
//! Run with `cargo bench --bench round_latency` (`make artifacts` first to
//! include the XLA cases; the native cases and the sweep always run).

use gradestc::compress::{build_pair, Compressor as _, Decompressor as _, LayerUpdate, Payload};
use gradestc::config::{
    BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, ModelKind, NetConfig, SchedConfig,
};
use gradestc::coordinator::{ServerAggregator, Simulation};
use gradestc::model::meta::layer_table;
use gradestc::model::params::ParamStore;
use gradestc::net::wire;
use gradestc::util::bench::Bencher;
use gradestc::util::rng::Pcg64;
use std::time::Duration;

fn cfg(model: ModelKind, dataset: DatasetKind, comp: CompressorKind, xla: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench-round".into(),
        dataset,
        model,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 1_000_000, // stepped manually
        local_epochs: 1,
        batch_size: if matches!(model, ModelKind::TinyTransformer) { 16 } else { 32 },
        lr: 0.03,
        samples_per_client: 32, // one batch per client: isolates step latency
        test_samples: 64,
        eval_every: usize::MAX,
        threshold_frac: 0.95,
        compressor: comp,
        seed: 7,
        use_xla: xla,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
        sched: SchedConfig::default(),
        backend: BackendKind::Auto,
        lanes: LaneConfig::default(),
    }
}

/// One LeNet-5 update compressed by `kind`, as a ready-to-encode payload
/// set (GradESTC warmed for one round first so the bench measures the
/// steady-state coefficient payloads, not the init-round basis refresh).
fn payload_set(kind: &CompressorKind) -> Vec<Payload> {
    let meta = layer_table(ModelKind::LeNet5);
    let mut rng = Pcg64::seeded(0xBE7C);
    let (mut c, _d) = build_pair(kind, &meta, 9);
    let warm: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
    let _ = c.compress(&warm);
    let update: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
    let (payloads, _) = c.compress(&update);
    payloads
}

fn main() {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let mut b = Bencher::new("round").budget(
        Duration::from_millis(200),
        Duration::from_millis(3000),
        5,
    );
    let cases = [
        ("lenet5-gradestc", ModelKind::LeNet5, DatasetKind::SynthMnist),
        ("resnetlite-gradestc", ModelKind::ResNetLite, DatasetKind::SynthCifar10),
    ];
    for (name, model, dataset) in cases {
        for (backend, xla) in [("xla", true), ("native", false)] {
            if xla && !have_artifacts {
                eprintln!("skipping {name}/{backend}: no artifacts");
                continue;
            }
            let comp = CompressorKind::GradEstc(GradEstcParams {
                k: if matches!(model, ModelKind::LeNet5) { 8 } else { 32 },
                ..Default::default()
            });
            let mut sim = Simulation::build(cfg(model, dataset, comp, xla)).unwrap();
            let mut round = 0usize;
            // one warm round to compile executables / init bases
            sim.step(round).unwrap();
            round += 1;
            b.bench(&format!("{name}-{backend}"), || {
                let rec = sim.step(round).unwrap();
                round += 1;
                std::hint::black_box(rec.train_loss);
            });
        }
    }
    // Worker-count sweep: 20-client GradESTC round on the native backend.
    // The 1-worker case is the sequential baseline; the speedup at 2/4/8
    // workers is the round engine's headline number (results are
    // bit-identical across the sweep, only wallclock changes).
    for workers in [1usize, 2, 4, 8] {
        let comp = CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() });
        let mut c = cfg(ModelKind::LeNet5, DatasetKind::SynthMnist, comp, false);
        c.num_clients = 20;
        c.workers = workers;
        let mut sim = Simulation::build(c).unwrap();
        let mut round = 0usize;
        // one warm round to initialize the compressor bases
        sim.step(round).unwrap();
        round += 1;
        b.bench(&format!("lenet5-gradestc-20clients-w{workers}"), || {
            let rec = sim.step(round).unwrap();
            round += 1;
            std::hint::black_box(rec.train_loss);
        });
    }

    // Server-phase aggregation: dense (decompress every client to a full
    // model, then weighted_sum) vs the fused compressed-domain fold
    // (ServerAggregator folds low-rank factors via matmul_acc, one
    // accumulator per layer). Steady-state GradESTC payloads from 16
    // clients on the ResNetLite geometry — the parameter-dominant case the
    // refactor targets.
    {
        let meta = layer_table(ModelKind::ResNetLite);
        let kind = CompressorKind::GradEstc(GradEstcParams { k: 32, ..Default::default() });
        let n_clients = 16usize;
        let mut decoded: Vec<Vec<LayerUpdate>> = Vec::with_capacity(n_clients);
        for cid in 0..n_clients {
            let mut rng = Pcg64::seeded(0xA66 + cid as u64);
            let (mut c, mut d) = build_pair(&kind, &meta, cid as u64);
            let warm: Vec<Vec<f32>> =
                meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
            let (p0, _) = c.compress(&warm);
            let _ = d.decode(p0);
            let update: Vec<Vec<f32>> =
                meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
            let (p1, _) = c.compress(&update);
            decoded.push(d.decode(p1));
        }
        let scales: Vec<f32> = vec![1.0 / n_clients as f32; n_clients];
        // Same worker counts on both sides so the dense-vs-fused delta
        // isolates the compressed-domain fold, not parallel speedup.
        for workers in [1usize, 8] {
            b.bench(&format!("server-phase-dense-16clients-w{workers}"), || {
                let dense: Vec<Vec<Vec<f32>>> = decoded
                    .iter()
                    .map(|us| us.iter().map(LayerUpdate::to_dense).collect())
                    .collect();
                let terms: Vec<&[Vec<f32>]> = dense.iter().map(|u| u.as_slice()).collect();
                std::hint::black_box(ParamStore::weighted_sum(&meta, &terms, &scales, workers));
            });
            b.bench(&format!("server-phase-fused-16clients-w{workers}"), || {
                let batch: Vec<(f32, Vec<LayerUpdate>)> =
                    scales.iter().copied().zip(decoded.iter().cloned()).collect();
                let mut agg = ServerAggregator::new(&meta);
                agg.fold_batch(workers, batch);
                std::hint::black_box(agg.finish(&meta));
            });
        }
    }

    // Wire-codec throughput: encode/decode one client's payload set for
    // the paper's method vs the uncompressed baseline. The Raw set is ~25×
    // larger, so this isolates codec cost per byte on both regimes.
    let cases = [
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ),
        ("raw", CompressorKind::None),
    ];
    for (name, kind) in cases {
        let payloads = payload_set(&kind);
        let encoded = wire::encode(&payloads);
        let bytes = encoded.len() as f64;
        b.bench_with_throughput(&format!("wire-encode-{name}"), Some((bytes, "B")), || {
            std::hint::black_box(wire::encode(&payloads));
        });
        b.bench_with_throughput(&format!("wire-decode-{name}"), Some((bytes, "B")), || {
            std::hint::black_box(wire::decode(&encoded).unwrap());
        });
    }

    // FedAvg baseline to isolate compression overhead.
    if have_artifacts {
        let mut sim = Simulation::build(cfg(
            ModelKind::ResNetLite,
            DatasetKind::SynthCifar10,
            CompressorKind::None,
            true,
        ))
        .unwrap();
        let mut round = 0usize;
        sim.step(round).unwrap();
        round += 1;
        b.bench("resnetlite-fedavg-xla", || {
            let rec = sim.step(round).unwrap();
            round += 1;
            std::hint::black_box(rec.train_loss);
        });
    }
}
