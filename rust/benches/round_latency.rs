//! End-to-end round latency: one full FL round (local training via the
//! XLA artifacts when present, compression, decompression, aggregation,
//! evaluation skipped) per model — the §Perf L3 headline number — plus a
//! worker-count sweep (1/2/4/8) over a 20-client GradESTC round that
//! measures the round engine's parallel speedup, and encode/decode
//! throughput of the wire codec for GradESTC vs Raw payload sets.
//!
//! Run with `cargo bench --bench round_latency` (`make artifacts` first to
//! include the XLA cases; the native cases and the sweep always run).

use gradestc::compress::{build_pair, Compressor as _, Payload};
use gradestc::config::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams, ModelKind,
    NetConfig,
};
use gradestc::coordinator::Simulation;
use gradestc::model::meta::layer_table;
use gradestc::net::wire;
use gradestc::util::bench::Bencher;
use gradestc::util::rng::Pcg64;
use std::time::Duration;

fn cfg(model: ModelKind, dataset: DatasetKind, comp: CompressorKind, xla: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench-round".into(),
        dataset,
        model,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 1_000_000, // stepped manually
        local_epochs: 1,
        batch_size: if matches!(model, ModelKind::TinyTransformer) { 16 } else { 32 },
        lr: 0.03,
        samples_per_client: 32, // one batch per client: isolates step latency
        test_samples: 64,
        eval_every: usize::MAX,
        threshold_frac: 0.95,
        compressor: comp,
        seed: 7,
        use_xla: xla,
        artifacts_dir: "artifacts".into(),
        workers: 1,
        net: NetConfig::default(),
    }
}

/// One LeNet-5 update compressed by `kind`, as a ready-to-encode payload
/// set (GradESTC warmed for one round first so the bench measures the
/// steady-state coefficient payloads, not the init-round basis refresh).
fn payload_set(kind: &CompressorKind) -> Vec<Payload> {
    let meta = layer_table(ModelKind::LeNet5);
    let mut rng = Pcg64::seeded(0xBE7C);
    let (mut c, _d) = build_pair(kind, &meta, 9);
    let warm: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
    let _ = c.compress(&warm);
    let update: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
    let (payloads, _) = c.compress(&update);
    payloads
}

fn main() {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let mut b = Bencher::new("round").budget(
        Duration::from_millis(200),
        Duration::from_millis(3000),
        5,
    );
    let cases = [
        ("lenet5-gradestc", ModelKind::LeNet5, DatasetKind::SynthMnist),
        ("resnetlite-gradestc", ModelKind::ResNetLite, DatasetKind::SynthCifar10),
    ];
    for (name, model, dataset) in cases {
        for (backend, xla) in [("xla", true), ("native", false)] {
            if xla && !have_artifacts {
                eprintln!("skipping {name}/{backend}: no artifacts");
                continue;
            }
            let comp = CompressorKind::GradEstc(GradEstcParams {
                k: if matches!(model, ModelKind::LeNet5) { 8 } else { 32 },
                ..Default::default()
            });
            let mut sim = Simulation::build(cfg(model, dataset, comp, xla)).unwrap();
            let mut round = 0usize;
            // one warm round to compile executables / init bases
            sim.step(round).unwrap();
            round += 1;
            b.bench(&format!("{name}-{backend}"), || {
                let rec = sim.step(round).unwrap();
                round += 1;
                std::hint::black_box(rec.train_loss);
            });
        }
    }
    // Worker-count sweep: 20-client GradESTC round on the native backend.
    // The 1-worker case is the sequential baseline; the speedup at 2/4/8
    // workers is the round engine's headline number (results are
    // bit-identical across the sweep, only wallclock changes).
    for workers in [1usize, 2, 4, 8] {
        let comp = CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() });
        let mut c = cfg(ModelKind::LeNet5, DatasetKind::SynthMnist, comp, false);
        c.num_clients = 20;
        c.workers = workers;
        let mut sim = Simulation::build(c).unwrap();
        let mut round = 0usize;
        // one warm round to initialize the compressor bases
        sim.step(round).unwrap();
        round += 1;
        b.bench(&format!("lenet5-gradestc-20clients-w{workers}"), || {
            let rec = sim.step(round).unwrap();
            round += 1;
            std::hint::black_box(rec.train_loss);
        });
    }

    // Wire-codec throughput: encode/decode one client's payload set for
    // the paper's method vs the uncompressed baseline. The Raw set is ~25×
    // larger, so this isolates codec cost per byte on both regimes.
    let cases = [
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ),
        ("raw", CompressorKind::None),
    ];
    for (name, kind) in cases {
        let payloads = payload_set(&kind);
        let encoded = wire::encode(&payloads);
        let bytes = encoded.len() as f64;
        b.bench_with_throughput(&format!("wire-encode-{name}"), Some((bytes, "B")), || {
            std::hint::black_box(wire::encode(&payloads));
        });
        b.bench_with_throughput(&format!("wire-decode-{name}"), Some((bytes, "B")), || {
            std::hint::black_box(wire::decode(&encoded).unwrap());
        });
    }

    // FedAvg baseline to isolate compression overhead.
    if have_artifacts {
        let mut sim = Simulation::build(cfg(
            ModelKind::ResNetLite,
            DatasetKind::SynthCifar10,
            CompressorKind::None,
            true,
        ))
        .unwrap();
        let mut round = 0usize;
        sim.step(round).unwrap();
        round += 1;
        b.bench("resnetlite-fedavg-xla", || {
            let rec = sim.step(round).unwrap();
            round += 1;
            std::hint::black_box(rec.train_loss);
        });
    }
}
