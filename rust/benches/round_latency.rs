//! End-to-end round latency: one full FL round (local training via the
//! XLA artifacts when present, compression, decompression, aggregation,
//! evaluation skipped) per model — the §Perf L3 headline number — plus a
//! worker-count sweep (1/2/4/8) over a 20-client GradESTC round that
//! measures the round engine's parallel speedup.
//!
//! Run with `cargo bench --bench round_latency` (`make artifacts` first to
//! include the XLA cases; the native cases and the sweep always run).

use gradestc::config::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams, ModelKind,
};
use gradestc::coordinator::Simulation;
use gradestc::util::bench::Bencher;
use std::time::Duration;

fn cfg(model: ModelKind, dataset: DatasetKind, comp: CompressorKind, xla: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench-round".into(),
        dataset,
        model,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds: 1_000_000, // stepped manually
        local_epochs: 1,
        batch_size: if matches!(model, ModelKind::TinyTransformer) { 16 } else { 32 },
        lr: 0.03,
        samples_per_client: 32, // one batch per client: isolates step latency
        test_samples: 64,
        eval_every: usize::MAX,
        threshold_frac: 0.95,
        compressor: comp,
        seed: 7,
        use_xla: xla,
        artifacts_dir: "artifacts".into(),
        workers: 1,
    }
}

fn main() {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let mut b = Bencher::new("round").budget(
        Duration::from_millis(200),
        Duration::from_millis(3000),
        5,
    );
    let cases = [
        ("lenet5-gradestc", ModelKind::LeNet5, DatasetKind::SynthMnist),
        ("resnetlite-gradestc", ModelKind::ResNetLite, DatasetKind::SynthCifar10),
    ];
    for (name, model, dataset) in cases {
        for (backend, xla) in [("xla", true), ("native", false)] {
            if xla && !have_artifacts {
                eprintln!("skipping {name}/{backend}: no artifacts");
                continue;
            }
            let comp = CompressorKind::GradEstc(GradEstcParams {
                k: if matches!(model, ModelKind::LeNet5) { 8 } else { 32 },
                ..Default::default()
            });
            let mut sim = Simulation::build(cfg(model, dataset, comp, xla)).unwrap();
            let mut round = 0usize;
            // one warm round to compile executables / init bases
            sim.step(round).unwrap();
            round += 1;
            b.bench(&format!("{name}-{backend}"), || {
                let rec = sim.step(round).unwrap();
                round += 1;
                std::hint::black_box(rec.train_loss);
            });
        }
    }
    // Worker-count sweep: 20-client GradESTC round on the native backend.
    // The 1-worker case is the sequential baseline; the speedup at 2/4/8
    // workers is the round engine's headline number (results are
    // bit-identical across the sweep, only wallclock changes).
    for workers in [1usize, 2, 4, 8] {
        let comp = CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() });
        let mut c = cfg(ModelKind::LeNet5, DatasetKind::SynthMnist, comp, false);
        c.num_clients = 20;
        c.workers = workers;
        let mut sim = Simulation::build(c).unwrap();
        let mut round = 0usize;
        // one warm round to initialize the compressor bases
        sim.step(round).unwrap();
        round += 1;
        b.bench(&format!("lenet5-gradestc-20clients-w{workers}"), || {
            let rec = sim.step(round).unwrap();
            round += 1;
            std::hint::black_box(rec.train_loss);
        });
    }

    // FedAvg baseline to isolate compression overhead.
    if have_artifacts {
        let mut sim = Simulation::build(cfg(
            ModelKind::ResNetLite,
            DatasetKind::SynthCifar10,
            CompressorKind::None,
            true,
        ))
        .unwrap();
        let mut round = 0usize;
        sim.step(round).unwrap();
        round += 1;
        b.bench("resnetlite-fedavg-xla", || {
            let rec = sim.step(round).unwrap();
            round += 1;
            std::hint::black_box(rec.train_loss);
        });
    }
}
