//! Micro-benchmarks for the dense linear-algebra substrate — the
//! compression hot path's building blocks (§Perf L3) — plus the
//! scalar-vs-blocked backend sweep the compute-backend plane's speedup
//! claims rest on.
//!
//! The sweep runs the two kernels the round loop spends its time in —
//! the fused server fold `C += α·M·A` (`matmul_acc`) and the client
//! projection `A = MᵀG` (`matmul_at_b`) — on ResNetLite layer geometries
//! at rank `k ∈ {8, 32, 128}`, once per backend, so the
//! `BENCH_linalg.json` trajectory file carries the blocked/scalar ratio
//! per shape. Run with `cargo bench --bench linalg`
//! (`GRADESTC_BENCH_FAST=1` for a quick pass).

use gradestc::linalg::{
    householder_qr, matmul, matmul_at_b, randomized_svd, thin_svd, Backend, BlockedBackend, Mat,
    RsvdOptions, ScalarBackend,
};
use gradestc::util::bench::Bencher;
use gradestc::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new("linalg");
    let mut rng = Pcg64::seeded(1);

    // Layer geometries from the paper's §V-B setups.
    let geoms = [
        ("lenet-fc1", 256usize, 120usize, 8usize),
        ("resnet-stage2", 576, 64, 32),
        ("resnet-stage3", 1152, 128, 32),
        ("alexnet-fc1", 2048, 512, 48),
    ];

    for &(name, l, m, k) in &geoms {
        let basis = Mat::randn(l, k, &mut rng);
        let g = Mat::randn(l, m, &mut rng);
        let a = Mat::randn(k, m, &mut rng);
        let flops_proj = (2 * l * k * m) as f64;
        b.bench_with_throughput(
            &format!("project_{name}_{l}x{m}x{k}"),
            Some((2.0 * flops_proj, "FLOP")),
            || {
                let acoef = matmul_at_b(&basis, &g);
                let e = g.sub(&matmul(&basis, &acoef));
                std::hint::black_box(e);
            },
        );
        b.bench_with_throughput(
            &format!("reconstruct_{name}_{l}x{m}x{k}"),
            Some((flops_proj, "FLOP")),
            || {
                std::hint::black_box(matmul(&basis, &a));
            },
        );
    }

    // Backend sweep: the aggregation plane's fused fold and the
    // compressor's projection, scalar vs blocked, on the ResNetLite layer
    // geometries × rank. Names are `<kernel>/<geom>/k<rank>/<backend>` so
    // the gate/plot tooling can pair the two backends per shape.
    let backends: [(&str, &dyn Backend); 2] =
        [("scalar", &ScalarBackend), ("blocked", &BlockedBackend)];
    for &(geom, l, m) in &[("resnet-stage2", 576usize, 64usize), ("resnet-stage3", 1152, 128)] {
        for k in [8usize, 32, 128] {
            let basis = Mat::randn(l, k, &mut rng);
            let g = Mat::randn(l, m, &mut rng);
            let coeffs = Mat::randn(k, m, &mut rng);
            let flops = (2 * l * k * m) as f64;
            for (bname, bk) in backends {
                b.bench_with_throughput(
                    &format!("matmul_acc/{geom}/k{k}/{bname}"),
                    Some((flops, "FLOP")),
                    || {
                        let mut acc = Mat::zeros(l, m);
                        bk.matmul_acc(&mut acc, 0.5, &basis, &coeffs);
                        std::hint::black_box(acc);
                    },
                );
                b.bench_with_throughput(
                    &format!("matmul_at_b/{geom}/k{k}/{bname}"),
                    Some((flops, "FLOP")),
                    || {
                        std::hint::black_box(bk.matmul_at_b(&basis, &g));
                    },
                );
            }
        }
    }

    // Randomized SVD at the error-matrix geometry (d ≈ 8 typical).
    for &(name, l, m, d) in
        &[("resnet-stage3", 1152usize, 128usize, 8usize), ("alexnet-fc1", 2048, 512, 8)]
    {
        let e = Mat::randn(l, m, &mut rng);
        let mut seed = Pcg64::seeded(2);
        b.bench(&format!("rsvd_d8_{name}_{l}x{m}"), || {
            std::hint::black_box(randomized_svd(&e, d, RsvdOptions::default(), &mut seed));
        });
    }

    // QR + small SVD (rSVD internals).
    let tall = Mat::randn(1152, 14, &mut rng);
    b.bench("qr_1152x14", || std::hint::black_box(householder_qr(&tall)));
    let sketch = Mat::randn(14, 128, &mut rng);
    b.bench("thin_svd_14x128", || std::hint::black_box(thin_svd(&sketch, 8)));

    // Machine-readable trajectory file (the bench-linalg CI job uploads
    // it; scripts/bench_gate.py diffs it against the committed baseline).
    std::fs::write("BENCH_linalg.json", b.to_json("")).expect("writing BENCH_linalg.json");
    println!("wrote BENCH_linalg.json ({} benches)", b.results().len());
}
