//! Training backends.
//!
//! [`XlaTrainer`] executes the AOT-compiled L2 graphs (one PJRT call per
//! minibatch: `(params…, x, y, lr) → (loss, params…)`), keeping python off
//! the round loop. [`crate::nn::NativeTrainer`] is the from-scratch Rust
//! reference implementation used when artifacts are unavailable and as an
//! independent cross-check of the L2 graphs (both backends implement
//! identical semantics; `rust/tests/xla_runtime.rs` compares them).
//!
//! The trait is split in two for the round engine
//! ([`crate::coordinator::engine`]): [`Trainer`] is the minimal
//! coordinator-thread interface, and [`ParallelTrainer`] marks backends
//! that are additionally `Sync` and therefore shareable by `&self` across
//! worker threads. The native backend qualifies (it is stateless between
//! calls); the XLA backend does not — its PJRT handles are `Rc`-based — so
//! [`NativeOrXla::plan`] degrades it gracefully to sequential execution.

use anyhow::{anyhow, Context, Result};

use crate::config::{experiment::model_name, ExperimentConfig, ModelKind};
use crate::data::synth::Dataset;
use crate::model::meta::ModelMeta;
use crate::model::params::ParamStore;
use crate::nn::NativeTrainer;
use crate::runtime::{HostTensor, ModelEntry, Runtime};
use crate::util::rng::Pcg64;

/// A training backend (coordinator-thread interface).
///
/// Implementations need not be `Send`: the `xla` crate's PJRT handles are
/// `Rc`-based, so that backend lives on the coordinator thread (PJRT
/// parallelizes *within* an execute call instead). Backends that *are*
/// thread-shareable opt into the round engine's parallel per-client phase
/// through [`ParallelTrainer`].
pub trait Trainer {
    /// Run `epochs` of local SGD from `start`; returns (new params,
    /// mean minibatch loss).
    fn local_train(
        &self,
        start: &ParamStore,
        data: &Dataset,
        epochs: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg64,
    ) -> Result<(ParamStore, f64)>;

    /// Evaluate on a dataset; returns (mean loss, accuracy).
    fn evaluate(&self, params: &ParamStore, data: &Dataset) -> Result<(f64, f64)>;

    /// One-batch raw gradients (for instrumentation like the Fig. 1 probe).
    fn grads(
        &self,
        params: &ParamStore,
        data: &Dataset,
        batch: usize,
        rng: &mut Pcg64,
    ) -> Result<(Vec<Vec<f32>>, f64)>;
}

/// A trainer that is `Sync` and can be shared by `&self` across the round
/// engine's worker threads.
///
/// Blanket-implemented for every `Trainer + Sync` type, so a backend only
/// has to *be* thread-shareable to qualify — `NativeTrainer` is the
/// canonical instance (asserted in `crate::nn`'s tests).
pub trait ParallelTrainer: Trainer + Sync {
    /// View as a plain [`Trainer`] (explicit upcast; kept as a method so
    /// the engine does not rely on trait-object upcasting coercion).
    fn as_trainer(&self) -> &dyn Trainer;
}

impl<T: Trainer + Sync> ParallelTrainer for T {
    fn as_trainer(&self) -> &dyn Trainer {
        self
    }
}

/// Assemble one minibatch from dataset rows into trainer inputs.
///
/// Indices may repeat (cycling pads shards smaller than a batch).
pub fn make_batch(
    kind: ModelKind,
    meta: &ModelMeta,
    data: &Dataset,
    idx: &[usize],
) -> (HostTensor, HostTensor) {
    let b = idx.len();
    if matches!(kind, ModelKind::TinyTransformer) {
        let seq = data.features;
        let mut toks = Vec::with_capacity(b * seq);
        for &i in idx {
            toks.extend(data.sample(i).iter().map(|&t| t as i32));
        }
        let y = vec![0i32; b];
        (HostTensor::i32(toks, &[b, seq]), HostTensor::i32(y, &[b]))
    } else {
        let (h, w, c) = (
            meta.input_shape[0],
            meta.input_shape[1],
            meta.input_shape[2],
        );
        let mut x = Vec::with_capacity(b * h * w * c);
        let mut y = Vec::with_capacity(b);
        for &i in idx {
            x.extend_from_slice(data.sample(i));
            y.push(data.y[i] as i32);
        }
        (HostTensor::f32(x, &[b, h, w, c]), HostTensor::i32(y, &[b]))
    }
}

/// Batch index schedule for one epoch: shuffled, full batches only; shards
/// smaller than one batch are cycled to fill a single batch.
pub fn epoch_batches(n: usize, batch: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    if n == 0 {
        return Vec::new();
    }
    if n < batch {
        let mut idx = Vec::with_capacity(batch);
        while idx.len() < batch {
            idx.push(idx.len() % n);
        }
        let mut shuffled: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut shuffled);
        for v in idx.iter_mut() {
            *v = shuffled[*v % n];
        }
        return vec![idx];
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    order.chunks_exact(batch).map(|c| c.to_vec()).collect()
}

/// XLA-artifact trainer.
pub struct XlaTrainer {
    runtime: Runtime,
    entry: ModelEntry,
    kind: ModelKind,
    meta: ModelMeta,
}

impl XlaTrainer {
    /// Open artifacts and bind the model's step executables.
    pub fn new(artifacts_dir: &str, kind: ModelKind, meta: &ModelMeta) -> Result<Self> {
        let runtime = Runtime::open(artifacts_dir)?;
        let name = model_name(kind);
        let entry = runtime
            .manifest()
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in artifact manifest"))?
            .clone();
        // Contract check: the artifact layer table must match ours.
        if entry.layers.len() != meta.layers.len() {
            return Err(anyhow!(
                "artifact layer table for '{name}' has {} tensors, expected {}",
                entry.layers.len(),
                meta.layers.len()
            ));
        }
        for (a, b) in entry.layers.iter().zip(&meta.layers) {
            if a.name != b.name || a.shape != b.shape {
                return Err(anyhow!(
                    "layer mismatch: artifact {}{:?} vs rust {}{:?}",
                    a.name,
                    a.shape,
                    b.name,
                    b.shape
                ));
            }
        }
        Ok(XlaTrainer { runtime, entry, kind, meta: meta.clone() })
    }

    /// The artifact's baked-in train batch size.
    pub fn train_batch(&self) -> usize {
        self.entry.batch
    }

    fn params_to_tensors(&self, params: &ParamStore) -> Vec<HostTensor> {
        (0..params.len())
            .map(|i| {
                HostTensor::f32(params.tensor(i).to_vec(), &self.meta.layers[i].shape)
            })
            .collect()
    }
}

impl Trainer for XlaTrainer {
    fn local_train(
        &self,
        start: &ParamStore,
        data: &Dataset,
        epochs: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg64,
    ) -> Result<(ParamStore, f64)> {
        if batch != self.entry.batch {
            return Err(anyhow!(
                "config batch {batch} != artifact batch {} (shapes are baked at AOT time)",
                self.entry.batch
            ));
        }
        let exe = self.runtime.load(&self.entry.train_step.file)?;
        let mut params = self.params_to_tensors(start);
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        for _ in 0..epochs {
            for idx in epoch_batches(data.len(), batch, rng) {
                let (x, y) = make_batch(self.kind, &self.meta, data, &idx);
                let mut inputs = params.clone();
                inputs.push(x);
                inputs.push(y);
                inputs.push(HostTensor::scalar(lr));
                let mut out = self
                    .runtime
                    .call_exe(&exe, &inputs)
                    .context("train_step execution")?;
                loss_sum += out[0].scalar_f32()? as f64;
                params = out.split_off(1);
                steps += 1;
            }
        }
        let tensors: Vec<Vec<f32>> = params
            .into_iter()
            .map(|t| t.into_f32())
            .collect::<Result<_>>()?;
        Ok((
            ParamStore::from_tensors(&self.meta, tensors),
            loss_sum / steps.max(1) as f64,
        ))
    }

    fn evaluate(&self, params: &ParamStore, data: &Dataset) -> Result<(f64, f64)> {
        let exe = self.runtime.load(&self.entry.eval_step.file)?;
        let eb = self.entry.eval_batch;
        let ptensors = self.params_to_tensors(params);
        let nbatches = data.len() / eb;
        if nbatches == 0 {
            return Err(anyhow!("test set smaller than eval batch {eb}"));
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for bi in 0..nbatches {
            let idx: Vec<usize> = (bi * eb..(bi + 1) * eb).collect();
            let (x, y) = make_batch(self.kind, &self.meta, data, &idx);
            let mut inputs = ptensors.clone();
            inputs.push(x);
            inputs.push(y);
            let out = self.runtime.call_exe(&exe, &inputs)?;
            loss_sum += out[0].scalar_f32()? as f64;
            correct += out[1].scalar_f32()? as f64;
        }
        let denom = if matches!(self.kind, ModelKind::TinyTransformer) {
            (nbatches * eb * (data.features - 1)) as f64
        } else {
            (nbatches * eb) as f64
        };
        Ok((loss_sum / denom, correct / denom))
    }

    fn grads(
        &self,
        params: &ParamStore,
        data: &Dataset,
        _batch: usize,
        rng: &mut Pcg64,
    ) -> Result<(Vec<Vec<f32>>, f64)> {
        let exe = self.runtime.load(&self.entry.grad_step.file)?;
        let batch = self.entry.batch;
        let idx = epoch_batches(data.len(), batch, rng)
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("empty dataset"))?;
        let (x, y) = make_batch(self.kind, &self.meta, data, &idx);
        let mut inputs = self.params_to_tensors(params);
        inputs.push(x);
        inputs.push(y);
        let mut out = self.runtime.call_exe(&exe, &inputs)?;
        let loss = out[0].scalar_f32()? as f64;
        let grads: Vec<Vec<f32>> = out
            .split_off(1)
            .into_iter()
            .map(|t| t.into_f32())
            .collect::<Result<_>>()?;
        Ok((grads, loss))
    }
}

/// Backend selector.
pub enum NativeOrXla {
    /// AOT artifacts through PJRT.
    Xla(XlaTrainer),
    /// From-scratch Rust implementation.
    Native(NativeTrainer),
}

impl NativeOrXla {
    /// Choose per config.
    pub fn build(cfg: &ExperimentConfig, meta: &ModelMeta) -> Result<NativeOrXla> {
        if cfg.use_xla {
            Ok(NativeOrXla::Xla(XlaTrainer::new(&cfg.artifacts_dir, cfg.model, meta)?))
        } else {
            Ok(NativeOrXla::Native(NativeTrainer::new(cfg.model, meta)?))
        }
    }

    /// Scheduling plan for the round engine's per-client phase.
    ///
    /// The native backend is `Sync` and fans lanes across `workers`
    /// threads; the XLA backend degrades gracefully to coordinator-thread
    /// execution (its PJRT handles cannot cross threads — PJRT already
    /// parallelizes within each execute call). Results are bit-identical
    /// either way.
    pub fn plan(&self, workers: usize) -> super::engine::ExecPlan<'_> {
        use super::engine::ExecPlan;
        match self {
            NativeOrXla::Native(t) if workers > 1 => {
                ExecPlan::Parallel { trainer: t, workers }
            }
            NativeOrXla::Native(t) => ExecPlan::Sequential { trainer: t },
            NativeOrXla::Xla(t) => ExecPlan::Sequential { trainer: t },
        }
    }
}

impl Trainer for NativeOrXla {
    fn local_train(
        &self,
        start: &ParamStore,
        data: &Dataset,
        epochs: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg64,
    ) -> Result<(ParamStore, f64)> {
        match self {
            NativeOrXla::Xla(t) => t.local_train(start, data, epochs, batch, lr, rng),
            NativeOrXla::Native(t) => t.local_train(start, data, epochs, batch, lr, rng),
        }
    }

    fn evaluate(&self, params: &ParamStore, data: &Dataset) -> Result<(f64, f64)> {
        match self {
            NativeOrXla::Xla(t) => t.evaluate(params, data),
            NativeOrXla::Native(t) => t.evaluate(params, data),
        }
    }

    fn grads(
        &self,
        params: &ParamStore,
        data: &Dataset,
        batch: usize,
        rng: &mut Pcg64,
    ) -> Result<(Vec<Vec<f32>>, f64)> {
        match self {
            NativeOrXla::Xla(t) => t.grads(params, data, batch, rng),
            NativeOrXla::Native(t) => t.grads(params, data, batch, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_batches_cover_without_repeats() {
        let mut rng = Pcg64::seeded(1);
        let batches = epoch_batches(100, 32, &mut rng);
        assert_eq!(batches.len(), 3); // 96 samples, remainder dropped
        let mut all: Vec<usize> = batches.into_iter().flatten().collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate index within epoch");
    }

    #[test]
    fn small_shard_cycles_to_one_batch() {
        let mut rng = Pcg64::seeded(2);
        let batches = epoch_batches(5, 32, &mut rng);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 32);
        assert!(batches[0].iter().all(|&i| i < 5));
    }

    #[test]
    fn empty_shard_no_batches() {
        let mut rng = Pcg64::seeded(3);
        assert!(epoch_batches(0, 32, &mut rng).is_empty());
    }
}
