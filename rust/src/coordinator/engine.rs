//! The staged round engine: broadcast → parallel client phase → transport
//! → parallel server phase → fixed-order reduction → apply/eval.
//!
//! One FL round decomposes into stages with very different sharing shapes:
//!
//! 1. **Broadcast** — the coordinator encodes the global model once
//!    ([`wire::encode_params`](crate::net::wire::encode_params)) and ships
//!    the frame to every surviving participant through the
//!    [`Transport`](crate::net::Transport); the downlink is charged from
//!    the delivered frames' lengths. (Every client receives an identical
//!    frame, so the coordinator decodes one copy and shares it read-only
//!    across lanes.)
//! 2. **Client phase** — each participant's *lane* (its private shard,
//!    RNG, compressor, and the server's paired decompressor, all colocated
//!    in [`Client`]) runs local SGD from the decoded broadcast, compresses
//!    the pseudo-gradient, and **encodes it to wire bytes**
//!    ([`wire::encode`](crate::net::wire::encode)). Lanes touch only
//!    disjoint state plus `&`-shared inputs, so [`run_client_phase`] fans
//!    them across worker threads via
//!    [`parallel_map`](crate::util::pool::parallel_map) whenever the
//!    backend allows ([`ExecPlan::Parallel`]).
//! 3. **Transport** — the coordinator uploads each lane's frame in
//!    participant order, drains the fabric, charges the uplink from the
//!    drained buffer lengths, and applies the straggler deadline.
//! 4. **Server decode** — [`run_server_phase`] decodes *every* received
//!    frame (stragglers included — paired client/server state must evolve
//!    in lockstep) with the lane's paired decompressor into structured
//!    [`LayerUpdate`]s, fanned across workers (per-lane state only, so
//!    order-free). Nothing is densified here: low-rank layers stay as
//!    `(coeffs, basis)` factors, sparse and quantized layers keep their
//!    compact forms. A lane's basis state is a handle into the
//!    simulation-wide [`BasisPool`](crate::compress::BasisPool): a
//!    basis-changing payload copy-on-writes and re-interns (the only point
//!    the fanned lanes touch the shared pool — a brief lock per changed
//!    layer), while stable rounds never lock at all; interning decides
//!    allocation sharing only, never values, so worker-count determinism
//!    is untouched.
//! 5. **Aggregation** — the on-time updates are folded in participant
//!    order into the
//!    [`ServerAggregator`](crate::coordinator::ServerAggregator)'s
//!    per-layer accumulators, parallelized over *layers* (disjoint
//!    accumulator buffers), fusing low-rank reconstruction with the
//!    weighted FedAvg reduction in `O(model)` memory.
//!
//! # Determinism
//!
//! The engine is bit-deterministic in the worker count: every lane's state
//! evolves only from its own streams (client RNG, compressor/decompressor
//! state), frames and results are collected in participant order
//! regardless of completion order, dropout is a pure function of
//! `(seed, round, cid)`, and the reduction's chunk geometry is fixed.
//! `workers = 1` and `workers = N` therefore produce identical
//! [`RoundRecord`](crate::metrics::RoundRecord)s — including identical
//! surviving-client sets — the property that keeps temporally-correlated
//! compressor state (GradESTC basis evolution) reproducible at any
//! parallelism. `rust/tests/simulation.rs` asserts this end-to-end, with
//! and without dropout.

use anyhow::{Context, Result};

use super::trainer::{ParallelTrainer, Trainer};
use super::Client;
// The `as _` imports bring the lane traits into scope for the
// `client.compressor.compress(..)` / `client.decompressor.decode(..)`
// calls below without claiming their names.
use crate::compress::{CompressStats, Compressor as _, Decompressor as _, LayerUpdate};
use crate::model::params::ParamStore;
use crate::net::wire;
use crate::telemetry::{Phase, Telemetry};
use crate::util::pool::parallel_map;

/// Immutable inputs shared (`&`) by every client lane in a round.
#[derive(Clone, Copy)]
pub struct RoundInputs<'a> {
    /// Broadcast global parameters (decoded from the broadcast frame,
    /// read-only).
    pub global: &'a ParamStore,
    /// Local SGD epochs per round.
    pub local_epochs: usize,
    /// Minibatch size.
    pub batch_size: usize,
    /// SGD learning rate.
    pub lr: f32,
}

/// How the per-client phase executes.
pub enum ExecPlan<'a> {
    /// Fan lanes across `workers` threads; the trainer is `Sync` and shared
    /// by `&self` (the native backend).
    Parallel {
        /// Shared trainer.
        trainer: &'a dyn ParallelTrainer,
        /// Worker-thread count (> 1).
        workers: usize,
    },
    /// Run every lane on the coordinator thread — used when `workers <= 1`
    /// or the backend cannot cross threads (the XLA backend's PJRT handles
    /// are `Rc`-based).
    Sequential {
        /// Coordinator-thread trainer.
        trainer: &'a dyn Trainer,
    },
}

/// One client lane's uplink-side round output, in participant order: the
/// *encoded* update frame plus the client-local measurements.
pub struct ClientFrame {
    /// Client id.
    pub cid: usize,
    /// Mean minibatch loss over local training.
    pub mean_loss: f64,
    /// Wire-encoded compressed update (what gets uploaded; its length is
    /// the uplink charge).
    pub frame: Vec<u8>,
    /// Compression statistics (Σd proxy etc.).
    pub stats: CompressStats,
    /// FedAvg weight (shard size).
    pub weight: f64,
}

/// Run one client lane's uplink side: local SGD from the broadcast model,
/// compress the pseudo-gradient, encode it to wire bytes. Touches only the
/// lane's own state plus the shared read-only inputs.
fn run_lane(
    trainer: &dyn Trainer,
    inputs: &RoundInputs<'_>,
    cid: usize,
    client: &mut Client,
) -> Result<ClientFrame> {
    let (new_params, mean_loss) = trainer.local_train(
        inputs.global,
        &client.data,
        inputs.local_epochs,
        inputs.batch_size,
        inputs.lr,
        &mut client.rng,
    )?;
    // Pseudo-gradient: Δ = new − global. Hand its buffers to the
    // compressor directly — no per-tensor re-copy in the hot phase.
    let tensors = new_params.delta(inputs.global).into_tensors();
    let (payloads, stats) = client.compressor.compress(&tensors);
    let frame = wire::encode(&payloads);
    Ok(ClientFrame {
        cid,
        mean_loss,
        frame,
        stats,
        weight: client.data.len() as f64,
    })
}

/// Execute the client phase for every lane.
///
/// Frames are returned in `lanes` (participant) order regardless of
/// scheduling; the first error in that order wins, so failures are
/// deterministic too.
///
/// With telemetry enabled, each lane is timed as a `client_compress` host
/// span tagged with its client id (`round` is the sync round or the async
/// model version at dispatch). Recording appends to a tag-sharded buffer
/// and never feeds back into the lane, so traced runs stay bit-identical
/// to untraced ones at any worker count.
pub fn run_client_phase(
    plan: ExecPlan<'_>,
    inputs: RoundInputs<'_>,
    lanes: Vec<(usize, &mut Client)>,
    tel: Option<&Telemetry>,
    round: u64,
) -> Result<Vec<ClientFrame>> {
    match plan {
        ExecPlan::Parallel { trainer, workers } => {
            parallel_map(workers, lanes, |(cid, client)| {
                timed_lane(trainer.as_trainer(), &inputs, cid, client, tel, round)
            })
            .into_iter()
            .collect()
        }
        ExecPlan::Sequential { trainer } => lanes
            .into_iter()
            .map(|(cid, client)| timed_lane(trainer, &inputs, cid, client, tel, round))
            .collect(),
    }
}

/// [`run_lane`] wrapped in a `client_compress` host span when telemetry is
/// enabled (`tel = None` adds a single branch).
fn timed_lane(
    trainer: &dyn Trainer,
    inputs: &RoundInputs<'_>,
    cid: usize,
    client: &mut Client,
    tel: Option<&Telemetry>,
    round: u64,
) -> Result<ClientFrame> {
    let sp = Telemetry::timer(tel);
    let out = run_lane(trainer, inputs, cid, client);
    if let Some(sp) = sp {
        sp.end(Phase::ClientCompress, round, Some(cid as u32));
    }
    out
}

/// Execute the server decode phase: decode each uploaded frame into
/// structured [`LayerUpdate`]s with the lane's paired decompressor,
/// advancing its state (basis replacement, re-ortho).
///
/// `frames[i]` must be lane `lanes[i]`'s upload (the coordinator aligns
/// them by construction). Each unit touches only its own lane's
/// decompressor state, so the phase fans across `workers` threads with
/// bit-identical results at any count. Returns `(client_id, updates)` in
/// lane order. No densification happens here — the dense materialization
/// is the observer's opt-in path, and aggregation folds the structured
/// forms directly ([`super::ServerAggregator`]).
///
/// With telemetry enabled, each lane's decode is a `server_decode` host
/// span and its payloads are charged to the per-variant byte counters
/// (`bytes.basis`, `bytes.sparse`, ...) — commutative adds, so traced
/// results stay worker-count independent.
pub fn run_server_phase(
    workers: usize,
    lanes: Vec<(usize, &mut Client)>,
    frames: Vec<Vec<u8>>,
    tel: Option<&Telemetry>,
    round: u64,
) -> Result<Vec<(usize, Vec<LayerUpdate>)>> {
    assert_eq!(lanes.len(), frames.len(), "one frame per lane");
    let units: Vec<((usize, &mut Client), Vec<u8>)> =
        lanes.into_iter().zip(frames).collect();
    parallel_map(workers, units, |((cid, client), frame)| {
        let sp = Telemetry::timer(tel);
        let payloads = wire::decode(&frame)
            .with_context(|| format!("decoding client {cid}'s upload"))?;
        if let Some(t) = tel {
            t.count_payloads(&payloads);
        }
        let updates = client.decompressor.decode(payloads);
        if let Some(sp) = sp {
            sp.end(Phase::ServerDecode, round, Some(cid as u32));
        }
        Ok((cid, updates))
    })
    .into_iter()
    .collect()
}
