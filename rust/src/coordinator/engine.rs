//! The staged round engine: broadcast → parallel per-client phase →
//! fixed-order weighted reduction → apply/eval.
//!
//! One FL round decomposes into stages with very different sharing shapes:
//!
//! 1. **Broadcast** — the coordinator charges the downlink for every
//!    participant (pure accounting; the global model is shared read-only).
//! 2. **Per-client phase** — each participant's *lane* (its private shard,
//!    RNG, compressor, and the server's paired decompressor, all colocated
//!    in [`Client`]) runs local SGD from the broadcast model, compresses the
//!    pseudo-gradient, and reconstructs it server-side. Lanes touch only
//!    disjoint state plus `&`-shared inputs, so [`run_client_phase`] fans
//!    them across worker threads via
//!    [`parallel_map`](crate::util::pool::parallel_map) whenever the
//!    backend allows ([`ExecPlan::Parallel`]).
//! 3. **Reduction** — lane outcomes are consumed in participant order
//!    (uplink charges, loss averaging, hook dispatch) and the weighted
//!    FedAvg aggregate runs as a deterministic chunked reduction
//!    ([`ParamStore::weighted_sum`]).
//! 4. **Apply/eval** — the coordinator applies the aggregate and evaluates.
//!
//! # Determinism
//!
//! The engine is bit-deterministic in the worker count: every lane's state
//! evolves only from its own streams (client RNG, compressor/decompressor
//! state), results are collected in participant order regardless of
//! completion order, and the reduction's chunk geometry is fixed. `workers =
//! 1` and `workers = N` therefore produce identical
//! [`RoundRecord`](crate::metrics::RoundRecord)s — the property that keeps
//! temporally-correlated compressor state (GradESTC basis evolution)
//! reproducible at any parallelism. `rust/tests/simulation.rs` asserts this
//! end-to-end.

use anyhow::Result;

use super::trainer::{ParallelTrainer, Trainer};
use super::Client;
use crate::compress::CompressStats;
use crate::model::params::ParamStore;
use crate::util::pool::parallel_map;

/// Immutable inputs shared (`&`) by every client lane in a round.
#[derive(Clone, Copy)]
pub struct RoundInputs<'a> {
    /// Broadcast global parameters (read-only).
    pub global: &'a ParamStore,
    /// Local SGD epochs per round.
    pub local_epochs: usize,
    /// Minibatch size.
    pub batch_size: usize,
    /// SGD learning rate.
    pub lr: f32,
}

/// How the per-client phase executes.
pub enum ExecPlan<'a> {
    /// Fan lanes across `workers` threads; the trainer is `Sync` and shared
    /// by `&self` (the native backend).
    Parallel {
        /// Shared trainer.
        trainer: &'a dyn ParallelTrainer,
        /// Worker-thread count (> 1).
        workers: usize,
    },
    /// Run every lane on the coordinator thread — used when `workers <= 1`
    /// or the backend cannot cross threads (the XLA backend's PJRT handles
    /// are `Rc`-based).
    Sequential {
        /// Coordinator-thread trainer.
        trainer: &'a dyn Trainer,
    },
}

/// One client lane's round output, in participant order.
pub struct LaneOutcome {
    /// Client id.
    pub cid: usize,
    /// Mean minibatch loss over local training.
    pub mean_loss: f64,
    /// Exact wire bytes of the compressed update.
    pub uplink_bytes: u64,
    /// Server-side reconstruction of the update (tensor-aligned).
    pub update: Vec<Vec<f32>>,
    /// Compression statistics (Σd proxy etc.).
    pub stats: CompressStats,
    /// FedAvg weight (shard size).
    pub weight: f64,
}

/// Detach disjoint `&mut Client` lanes for the participant set, in `ids`
/// order.
///
/// Panics if `ids` repeats a client (the sampler returns distinct ids).
pub fn take_lanes<'a>(
    clients: &'a mut [Client],
    ids: &[usize],
) -> Vec<(usize, &'a mut Client)> {
    let mut slots: Vec<Option<&'a mut Client>> = clients.iter_mut().map(Some).collect();
    ids.iter()
        .map(|&cid| (cid, slots[cid].take().expect("duplicate participant id")))
        .collect()
}

/// Run one client lane: local SGD from the broadcast model, compress the
/// pseudo-gradient, reconstruct server-side. Touches only the lane's own
/// state plus the shared read-only inputs.
fn run_lane(
    trainer: &dyn Trainer,
    inputs: &RoundInputs<'_>,
    cid: usize,
    client: &mut Client,
) -> Result<LaneOutcome> {
    let (new_params, mean_loss) = trainer.local_train(
        inputs.global,
        &client.data,
        inputs.local_epochs,
        inputs.batch_size,
        inputs.lr,
        &mut client.rng,
    )?;
    // Pseudo-gradient: Δ = new − global. Hand its buffers to the
    // compressor directly — no per-tensor re-copy in the hot phase.
    let tensors = new_params.delta(inputs.global).into_tensors();
    let (payloads, stats) = client.compressor.compress(&tensors);
    let uplink_bytes: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
    // Server-side reconstruction by the lane's paired decompressor.
    let update = client.decompressor.decompress(&payloads);
    Ok(LaneOutcome {
        cid,
        mean_loss,
        uplink_bytes,
        update,
        stats,
        weight: client.data.len() as f64,
    })
}

/// Execute the per-client phase for every lane.
///
/// Outcomes are returned in `lanes` (participant) order regardless of
/// scheduling; the first error in that order wins, so failures are
/// deterministic too.
pub fn run_client_phase(
    plan: ExecPlan<'_>,
    inputs: RoundInputs<'_>,
    lanes: Vec<(usize, &mut Client)>,
) -> Result<Vec<LaneOutcome>> {
    match plan {
        ExecPlan::Parallel { trainer, workers } => {
            parallel_map(workers, lanes, |(cid, client)| {
                run_lane(trainer.as_trainer(), &inputs, cid, client)
            })
            .into_iter()
            .collect()
        }
        ExecPlan::Sequential { trainer } => lanes
            .into_iter()
            .map(|(cid, client)| run_lane(trainer, &inputs, cid, client))
            .collect(),
    }
}
