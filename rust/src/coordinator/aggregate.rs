//! The server aggregation plane: weighted FedAvg over *structured*
//! updates, folded in the compressed domain.
//!
//! The pre-refactor server phase inflated every survivor's payload back
//! into a dense model (`O(survivors × model)` memory) and then reduced.
//! [`ServerAggregator`] instead keeps **one accumulator per layer** —
//! `O(model)` total — and folds each survivor's [`LayerUpdate`]s into it
//! directly:
//!
//! * [`LayerUpdate::LowRank`] fuses reconstruction with aggregation:
//!   `Acc_G += α · M·A` via [`matmul_acc`], scaling the k-sized inner loop
//!   instead of an `l×m` dense gradient, with the accumulator held in
//!   segment (G) space and converted to the tensor's flat layout once per
//!   round — not once per client. The basis `M` arrives as an immutable
//!   `Arc<Mat>` snapshot of the lane's interned
//!   [`BasisPool`](crate::compress::BasisPool) entry: holding it here is
//!   what forces a lane's *next* basis update down the copy-on-write path
//!   instead of mutating state this fold still reads, and N lanes folding
//!   the same basis reference one allocation.
//! * [`LayerUpdate::Sparse`] scatter-adds `α·v` at the kept indices.
//! * [`LayerUpdate::QuantDense`] folds `α·(lo + q·step)` straight from the
//!   bit-packed codes.
//! * [`LayerUpdate::Dense`] is a plain [`axpy`].
//!
//! # Determinism
//!
//! Each layer's accumulator is folded over clients **in participant
//! order**, sequentially; [`ServerAggregator::fold_batch`] parallelizes
//! over *layers* (disjoint accumulators), never over clients, so
//! `workers = 1` and `workers = N` produce bit-identical aggregates. For
//! dense/sparse/quantized updates the per-element operation sequence is
//! exactly the old dense reduction's (`acc += scale · v` in client order
//! from a zero accumulator), so those paths are bit-identical to the
//! legacy `ParamStore::weighted_sum` pipeline; the fused low-rank path
//! reorders float products (α folded into the matmul) and agrees to
//! ~1e-7 relative — both equalities are locked in by
//! `rust/tests/aggregation.rs`.

use crate::compress::codec::dequant_values;
use crate::compress::{LayerUpdate, SegmentGeom};
use crate::linalg::{axpy, default_backend, Backend, Mat};
use crate::model::meta::ModelMeta;
use crate::model::params::ParamStore;
use crate::util::pool::parallel_map;

/// One layer's running aggregate.
enum LayerAcc {
    /// Nothing folded yet. Materialized lazily by the first fold so a
    /// low-rank layer never pays for (and then discards) a dense zero
    /// buffer, and so mixing dense and low-rank folds for one tensor is a
    /// hard error in every build, not a silent overwrite.
    Empty,
    /// Flat accumulator in the tensor's natural layout (dense / sparse /
    /// quantized folds).
    Flat(Vec<f32>),
    /// Segment-space accumulator for low-rank folds; converted to the flat
    /// layout once, in [`ServerAggregator::finish`].
    Seg { g: Mat, geom: SegmentGeom },
}

impl LayerAcc {
    /// Flat accumulator view, materializing `Empty` at `len` zeros. Panics
    /// if this layer already accumulates in segment space.
    fn flat(&mut self, len: usize, what: &str) -> &mut Vec<f32> {
        if let LayerAcc::Empty = self {
            *self = LayerAcc::Flat(vec![0.0; len]);
        }
        match self {
            LayerAcc::Flat(dst) => {
                assert_eq!(dst.len(), len, "{what} update length mismatch");
                dst
            }
            _ => panic!("{what} update folded into a segment-space accumulator"),
        }
    }
}

/// Streaming weighted-FedAvg accumulator over structured updates; see the
/// module docs. Peak memory is `O(model)` plus one client's compressed
/// updates — never `survivors × model`.
pub struct ServerAggregator {
    accs: Vec<LayerAcc>,
    backend: &'static dyn Backend,
}

impl ServerAggregator {
    /// Fresh zero aggregate for a model on the process-default compute
    /// backend. Accumulator buffers materialize lazily on first fold (flat
    /// or segment space, whichever the layer's updates call for).
    pub fn new(meta: &ModelMeta) -> Self {
        Self::with_backend(meta, default_backend())
    }

    /// [`Self::new`] pinned to an explicit compute backend — the fused
    /// low-rank fold (`Acc_G += α·M·A`) runs through its `matmul_acc`.
    pub fn with_backend(meta: &ModelMeta, backend: &'static dyn Backend) -> Self {
        ServerAggregator {
            accs: meta.layers.iter().map(|_| LayerAcc::Empty).collect(),
            backend,
        }
    }

    /// Fold one survivor's updates with FedAvg weight `scale`, layer by
    /// layer on the calling thread (the streaming path).
    pub fn fold(&mut self, scale: f32, updates: Vec<LayerUpdate>) {
        assert_eq!(updates.len(), self.accs.len(), "update tensor count mismatch");
        for (acc, update) in self.accs.iter_mut().zip(updates) {
            fold_one(self.backend, acc, scale, update);
        }
    }

    /// Fold a whole round's `(scale, updates)` batch — participant order —
    /// fanned across `workers` threads **by layer**: each worker owns a
    /// disjoint set of accumulators and folds every client into them in
    /// batch order, so the result is bit-identical to calling
    /// [`ServerAggregator::fold`] per client at any worker count.
    pub fn fold_batch(&mut self, workers: usize, batch: Vec<(f32, Vec<LayerUpdate>)>) {
        let ntensors = self.accs.len();
        // Transpose client-major into tensor-major ownership (pure moves).
        let mut per_tensor: Vec<Vec<(f32, LayerUpdate)>> =
            (0..ntensors).map(|_| Vec::with_capacity(batch.len())).collect();
        for (scale, updates) in batch {
            assert_eq!(updates.len(), ntensors, "update tensor count mismatch");
            for (t, update) in updates.into_iter().enumerate() {
                per_tensor[t].push((scale, update));
            }
        }
        let bk = self.backend;
        let units: Vec<(&mut LayerAcc, Vec<(f32, LayerUpdate)>)> =
            self.accs.iter_mut().zip(per_tensor).collect();
        parallel_map(workers, units, |(acc, folds)| {
            for (scale, update) in folds {
                fold_one(bk, acc, scale, update);
            }
        });
    }

    /// Finish the round: convert segment-space accumulators back to flat
    /// tensor layout (once per layer) and wrap the result. Layers no fold
    /// ever touched come out as zeros.
    pub fn finish(self, meta: &ModelMeta) -> ParamStore {
        let tensors: Vec<Vec<f32>> = self
            .accs
            .into_iter()
            .zip(&meta.layers)
            .map(|(acc, layer)| match acc {
                LayerAcc::Empty => vec![0.0; layer.size()],
                LayerAcc::Flat(v) => v,
                LayerAcc::Seg { g, geom } => geom.segments_to_flat(&g),
            })
            .collect();
        ParamStore::from_tensors(meta, tensors)
    }
}

fn fold_one(bk: &dyn Backend, acc: &mut LayerAcc, scale: f32, update: LayerUpdate) {
    match update {
        LayerUpdate::Dense(v) => {
            axpy(acc.flat(v.len(), "dense"), scale, &v);
        }
        LayerUpdate::Sparse { indices, values, len } => {
            // Strictly-increasing indices (the producer contract, enforced
            // by wire::decode) make this scatter-add exactly equivalent to
            // densify-then-add: no index is touched twice.
            debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
            let dst = acc.flat(len, "sparse");
            for (&i, &v) in indices.iter().zip(&values) {
                dst[i as usize] += scale * v;
            }
        }
        LayerUpdate::QuantDense { lo, hi, bits, packed, len } => {
            let dst = acc.flat(len, "quantized");
            // Stream dequantized values straight into the accumulator (the
            // shared `dequant_values` formula keeps this path and
            // `to_dense` in exact agreement); the only transient buffer is
            // this layer's code vector, freed before the next fold.
            for (d, v) in dst.iter_mut().zip(dequant_values(lo, hi, bits, &packed, len)) {
                *d += scale * v;
            }
        }
        LayerUpdate::LowRank { coeffs, basis, geom } => {
            // First low-rank fold materializes this layer's accumulator in
            // segment space (all lanes share one compressor config, so a
            // tensor is low-rank for everyone or no one — mixing is a hard
            // error in every build, never a silent overwrite).
            if let LayerAcc::Empty = acc {
                *acc = LayerAcc::Seg { g: Mat::zeros(geom.l, geom.m), geom };
            }
            let LayerAcc::Seg { g, geom: acc_geom } = acc else {
                panic!("low-rank update folded into a dense accumulator")
            };
            assert_eq!(*acc_geom, geom, "segment geometry changed mid-round");
            // The fusion: Acc_G += scale · M·A, never materializing Ĝ.
            bk.matmul_acc(g, scale, &basis, &coeffs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::model::meta::layer_table;
    use crate::util::rng::Pcg64;
    use std::sync::Arc;

    fn dense_batch(
        meta: &ModelMeta,
        n: usize,
        rng: &mut Pcg64,
    ) -> Vec<(f32, Vec<LayerUpdate>)> {
        (0..n)
            .map(|i| {
                let updates = meta
                    .layers
                    .iter()
                    .map(|l| LayerUpdate::Dense(rng.normal_vec(l.size())))
                    .collect();
                (0.1 + 0.07 * i as f32, updates)
            })
            .collect()
    }

    #[test]
    fn dense_fold_matches_weighted_sum_bitwise() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(17);
        let batch = dense_batch(&meta, 5, &mut rng);

        let dense: Vec<Vec<Vec<f32>>> = batch
            .iter()
            .map(|(_, us)| us.iter().map(LayerUpdate::to_dense).collect())
            .collect();
        let scales: Vec<f32> = batch.iter().map(|(s, _)| *s).collect();
        let terms: Vec<&[Vec<f32>]> = dense.iter().map(|u| u.as_slice()).collect();
        let reference = ParamStore::weighted_sum(&meta, &terms, &scales, 1);

        for workers in [1usize, 2, 8] {
            let mut agg = ServerAggregator::new(&meta);
            agg.fold_batch(workers, batch.clone());
            let got = agg.finish(&meta);
            for t in 0..reference.len() {
                let same = reference
                    .tensor(t)
                    .iter()
                    .zip(got.tensor(t))
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "tensor {t} differs at workers={workers}");
            }
        }
    }

    #[test]
    fn streaming_fold_equals_batched_fold() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(23);
        let batch = dense_batch(&meta, 4, &mut rng);

        let mut streamed = ServerAggregator::new(&meta);
        for (scale, updates) in batch.clone() {
            streamed.fold(scale, updates);
        }
        let streamed = streamed.finish(&meta);

        let mut batched = ServerAggregator::new(&meta);
        batched.fold_batch(8, batch);
        let batched = batched.finish(&meta);
        for t in 0..streamed.len() {
            let same = streamed
                .tensor(t)
                .iter()
                .zip(batched.tensor(t))
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "tensor {t}");
        }
    }

    #[test]
    #[should_panic(expected = "dense accumulator")]
    fn mixed_dense_then_lowrank_folds_panic() {
        let mut rng = Pcg64::seeded(5);
        let geom = SegmentGeom { l: 4, m: 4, conv: None };
        let mut acc = LayerAcc::Empty;
        fold_one(default_backend(), &mut acc, 1.0, LayerUpdate::Dense(vec![1.0; 16]));
        fold_one(
            default_backend(),
            &mut acc,
            1.0,
            LayerUpdate::LowRank {
                coeffs: Mat::randn(2, 4, &mut rng),
                basis: Arc::new(Mat::randn(4, 2, &mut rng)),
                geom,
            },
        );
    }

    #[test]
    fn lowrank_fold_fuses_reconstruction() {
        // One 8x6 layer, two clients with different bases/coefficients:
        // the fused fold must match densify-then-weighted-add closely.
        let mut rng = Pcg64::seeded(31);
        let geom = SegmentGeom { l: 8, m: 6, conv: None };
        let mk = |rng: &mut Pcg64| LayerUpdate::LowRank {
            coeffs: Mat::randn(3, 6, rng),
            basis: Arc::new(Mat::randn(8, 3, rng)),
            geom,
        };
        let (u1, u2) = (mk(&mut rng), mk(&mut rng));
        let (s1, s2) = (0.3f32, 0.7f32);

        let mut expect = vec![0.0f32; 48];
        for (s, u) in [(s1, &u1), (s2, &u2)] {
            for (e, v) in expect.iter_mut().zip(u.to_dense()) {
                *e += s * v;
            }
        }

        let mut acc = LayerAcc::Empty;
        fold_one(default_backend(), &mut acc, s1, u1);
        fold_one(default_backend(), &mut acc, s2, u2);
        let LayerAcc::Seg { g, geom } = acc else { panic!("accumulator not in G space") };
        let got = geom.segments_to_flat(&g);
        for (a, b) in expect.iter().zip(&got) {
            assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0), "{a} vs {b}");
        }
    }
}
