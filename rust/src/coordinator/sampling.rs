//! Client participation sampling (paper §V-B: 10 clients full
//! participation; Fig. 7: 50 clients at 20%).

use crate::util::rng::Pcg64;

/// Samples the participant set for each round.
pub struct ParticipationSampler {
    num_clients: usize,
    fraction: f64,
    rng: Pcg64,
}

impl ParticipationSampler {
    /// `fraction` ∈ (0, 1]; at least one client always participates.
    pub fn new(num_clients: usize, fraction: f64, rng: Pcg64) -> Self {
        assert!(num_clients > 0);
        assert!(fraction > 0.0 && fraction <= 1.0, "participation must be in (0,1]");
        ParticipationSampler { num_clients, fraction, rng }
    }

    /// Participant ids for `round` (sorted, distinct).
    pub fn sample(&mut self, _round: usize) -> Vec<usize> {
        let k = ((self.num_clients as f64 * self.fraction).round() as usize)
            .clamp(1, self.num_clients);
        if k == self.num_clients {
            return (0..self.num_clients).collect();
        }
        let mut ids = self.rng.sample_indices(self.num_clients, k);
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_is_everyone() {
        let mut s = ParticipationSampler::new(10, 1.0, Pcg64::seeded(1));
        assert_eq!(s.sample(0), (0..10).collect::<Vec<_>>());
        assert_eq!(s.sample(1), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn partial_participation_sizes() {
        let mut s = ParticipationSampler::new(50, 0.2, Pcg64::seeded(2));
        for r in 0..20 {
            let ids = s.sample(r);
            assert_eq!(ids.len(), 10);
            assert!(ids.windows(2).all(|w| w[0] < w[1]));
            assert!(ids.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn coverage_over_rounds() {
        // Every client should participate eventually.
        let mut s = ParticipationSampler::new(20, 0.25, Pcg64::seeded(3));
        let mut seen = vec![false; 20];
        for r in 0..60 {
            for i in s.sample(r) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "some client never sampled");
    }

    #[test]
    fn at_least_one_participant() {
        let mut s = ParticipationSampler::new(3, 0.01, Pcg64::seeded(4));
        assert_eq!(s.sample(0).len(), 1);
    }
}
