//! Virtual client lanes: lazy materialization, parallel first-touch, and
//! LRU residency bounding for million-client populations.
//!
//! A [`LanePool`] owns one slot per client in the population, but a slot
//! holds an actual [`Client`] lane only while that lane is *resident*.
//! Every lane is a pure function of `(seed, cid)` — shard, RNG stream, and
//! compressor pair are derived by [`LaneFactory::materialize`] with no
//! sequential dependency on other clients — so:
//!
//! * a sampled-never client costs ~0 bytes (an empty slot);
//! * first-touch batches materialize in parallel through
//!   [`crate::util::pool::parallel_map`] in deterministic cid order;
//! * an evicted lane re-materializes on demand bit-identically, its basis
//!   re-interned through the shared [`BasisPool`].
//!
//! **Seed-derivation contract** (frozen — tests in `tests/lanes.rs` pin
//! lazy ≡ eager bit-identity on top of it): with `root = Pcg64::new(seed,
//! 0x51)` and `Pcg64::fork` non-mutating,
//!
//! * synth shard labels: one [`ShardPlan`] from `root.fork(0x2_0000_0000)`
//!   (label draw) + `root.fork(0x2_0000_0001)` (partition);
//! * synth shard pixels: `root.fork(0x1_0000_0000 + cid)`;
//! * corpus shard: `root.fork(1000 + cid)` (identical to the pre-plan
//!   keying, which was already per-client);
//! * lane RNG: `root.fork(7000 + cid)`;
//! * compressor pair seed: `seed ^ (cid << 8)`.
//!
//! **Residency bound**: `max_resident > 0` caps resident lanes; the
//! least-recently-touched unpinned lane is evicted past the cap. Lanes
//! with an upload in flight are *pinned* — their paired compressor/
//! decompressor state has advanced at dispatch, and a re-materialized
//! (reset) decompressor would misdecode the in-flight frame — so the
//! bound is enforced net of pins, and net of the cohort currently being
//! ensured (a cap below one round's cohort degrades to holding exactly
//! that cohort rather than breaking dispatch).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::compress::{build_pair_with, BasisPool};
use crate::config::CompressorKind;
use crate::data::corpus::CorpusGenerator;
use crate::data::synth::{Dataset, SynthGenerator};
use crate::data::ShardPlan;
use crate::linalg::Backend;
use crate::model::meta::ModelMeta;
use crate::telemetry::{Phase, Telemetry};
use crate::util::pool::parallel_map;
use crate::util::rng::Pcg64;

use super::Client;

/// Where a materialized lane's shard comes from. Generators are shared
/// (`Arc`) across worker threads during parallel first-touch.
pub(crate) enum ShardSource {
    /// Class-conditional image data: the per-client label slice comes from
    /// the population-wide [`ShardPlan`], the pixels from the per-client
    /// stream `root.fork(0x1_0000_0000 + cid)`.
    Synth {
        gen: Arc<SynthGenerator>,
        plan: Arc<ShardPlan>,
    },
    /// Token sequences from the per-client stream `root.fork(1000 + cid)`
    /// — the same keying the eager corpus path always used.
    Corpus {
        gen: Arc<CorpusGenerator>,
        samples: usize,
        seq: usize,
    },
}

/// Derives a full [`Client`] lane from `(seed, cid)` alone. Everything it
/// holds is `Sync`, so [`LanePool::ensure_resident`] can fan `materialize`
/// across workers.
pub(crate) struct LaneFactory {
    /// The build-time root stream (`Pcg64::new(seed, 0x51)`); never
    /// advanced, only forked, so materialization order cannot matter.
    pub(crate) root: Pcg64,
    /// `cfg.seed`, for the compressor-pair derivation.
    pub(crate) seed: u64,
    pub(crate) compressor: CompressorKind,
    pub(crate) meta: ModelMeta,
    /// The population-shared basis pool; a re-materialized lane's initial
    /// basis re-interns here (deduping against any live copy).
    pub(crate) pool: BasisPool,
    pub(crate) backend: &'static dyn Backend,
    pub(crate) source: ShardSource,
}

impl LaneFactory {
    /// Materialize lane `cid`: shard + RNG stream + paired compressor/
    /// decompressor, derived purely from `(seed, cid)`.
    pub(crate) fn materialize(&self, cid: usize) -> Client {
        let data = match &self.source {
            ShardSource::Synth { gen, plan } => {
                let mut r = self.root.fork(0x1_0000_0000 + cid as u64);
                gen.generate_with_labels(plan.labels_of(cid), &mut r)
            }
            ShardSource::Corpus { gen, samples, seq } => {
                let mut r = self.root.fork(1000 + cid as u64);
                let corpus = gen.generate(*samples, *seq, &mut r);
                Dataset {
                    x: corpus.tokens.iter().map(|&t| t as f32).collect(),
                    y: vec![0; corpus.len()],
                    features: *seq,
                    classes: 256,
                }
            }
        };
        let (compressor, decompressor) = build_pair_with(
            &self.pool,
            &self.compressor,
            &self.meta,
            self.seed ^ ((cid as u64) << 8),
            self.backend,
        );
        Client {
            id: cid,
            data,
            compressor,
            decompressor,
            rng: self.root.fork(7000 + cid as u64),
        }
    }
}

/// The population's lane slots: resident lanes, LRU bookkeeping, and the
/// factory that (re-)materializes missing ones. Replaces the former
/// `Vec<Client>` on [`super::Simulation`].
pub struct LanePool {
    /// One slot per client id; `None` = not resident (never materialized,
    /// evicted, or currently loaned out via [`LanePool::take`]).
    slots: Vec<Option<Box<Client>>>,
    /// In-flight pin *count* per lane, exempting it from eviction while
    /// positive (see module docs). A count, not a flag: with per-client
    /// concurrency > 1 the async scheduler can have several uploads of the
    /// same lane in flight, and the lane must stay pinned until the last
    /// one is decoded.
    pinned: Vec<u32>,
    /// Last touch tick per lane, for invalidating stale heap entries.
    last_touch: Vec<u64>,
    /// Monotonic touch counter.
    clock: u64,
    /// Min-heap of `(touch tick, cid)`; entries whose tick no longer
    /// matches `last_touch[cid]` are stale and skipped on pop.
    lru: BinaryHeap<Reverse<(u64, usize)>>,
    /// Residency cap; `0` = unbounded.
    max_resident: usize,
    /// Current resident-lane count (loaned lanes still count).
    resident: usize,
    /// Lifetime materializations (first-touch + re-materializations).
    materialized: u64,
    /// Lifetime evictions.
    evictions: u64,
    /// Lifetime availability-fault discards (see [`LanePool::discard`]).
    discards: u64,
    /// `None` for a fixed (pre-built) pool, where every lane is resident
    /// forever — the frozen legacy-shards path.
    factory: Option<LaneFactory>,
}

impl LanePool {
    /// A fully-materialized pool with no factory: every lane resident for
    /// the run's lifetime, no eviction. Used by the frozen `legacy_shards`
    /// reference path.
    pub(crate) fn fixed(clients: Vec<Client>) -> LanePool {
        let n = clients.len();
        LanePool {
            slots: clients.into_iter().map(|c| Some(Box::new(c))).collect(),
            pinned: vec![0; n],
            last_touch: vec![0; n],
            clock: 0,
            lru: BinaryHeap::new(),
            max_resident: 0,
            resident: n,
            materialized: n as u64,
            evictions: 0,
            discards: 0,
            factory: None,
        }
    }

    /// An all-empty pool of `n` virtual lanes backed by `factory`.
    pub(crate) fn virtual_lanes(n: usize, factory: LaneFactory, max_resident: usize) -> LanePool {
        LanePool {
            slots: (0..n).map(|_| None).collect(),
            pinned: vec![0; n],
            last_touch: vec![0; n],
            clock: 0,
            lru: BinaryHeap::new(),
            max_resident,
            resident: 0,
            materialized: 0,
            evictions: 0,
            discards: 0,
            factory: Some(factory),
        }
    }

    /// Population size (resident or not).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True for an empty population.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Currently resident lanes (including loaned-out ones).
    pub fn resident(&self) -> usize {
        self.resident
    }

    /// Lifetime lane materializations.
    pub fn materializations(&self) -> u64 {
        self.materialized
    }

    /// Lifetime lane evictions.
    pub fn eviction_count(&self) -> u64 {
        self.evictions
    }

    /// Lifetime availability-fault discards.
    pub fn discard_count(&self) -> u64 {
        self.discards
    }

    fn touch(&mut self, cid: usize) {
        self.clock += 1;
        self.last_touch[cid] = self.clock;
        self.lru.push(Reverse((self.clock, cid)));
    }

    /// Make every lane in `cids` resident, then enforce the residency cap.
    /// Missing lanes materialize through `parallel_map` in ascending-cid
    /// order, so the result — and every RNG/compressor state inside —
    /// is identical at any worker count. Touches all of `cids` (in sorted
    /// order, again for worker-count independence of the LRU order).
    pub(crate) fn ensure_resident(
        &mut self,
        cids: &[usize],
        workers: usize,
        tel: Option<&Telemetry>,
        round: u64,
    ) {
        let mut missing: Vec<usize> = cids
            .iter()
            .copied()
            .filter(|&c| self.slots[c].is_none())
            .collect();
        missing.sort_unstable();
        missing.dedup();
        if !missing.is_empty() {
            let factory = self
                .factory
                .as_ref()
                .expect("non-resident lane in a fixed lane pool");
            let built = parallel_map(workers, missing.clone(), |cid| {
                let sp = Telemetry::timer(tel);
                let lane = Box::new(factory.materialize(cid));
                if let Some(sp) = sp {
                    sp.end(Phase::LaneMaterialize, round, Some(cid as u32));
                }
                lane
            });
            for (cid, lane) in missing.into_iter().zip(built) {
                self.slots[cid] = Some(lane);
                self.resident += 1;
                self.materialized += 1;
            }
        }
        let mut touched: Vec<usize> = cids.to_vec();
        touched.sort_unstable();
        touched.dedup();
        for &cid in &touched {
            self.touch(cid);
        }
        // The requested working set is about to be dispatched: exempt it
        // from this enforcement pass (so a cap below one cohort's size can
        // never evict a lane that [`LanePool::take`] is about to loan) —
        // the cap is a floor with respect to the active cohort, like pins.
        let guard: Vec<usize> =
            touched.iter().copied().filter(|&c| self.pinned[c] == 0).collect();
        for &c in &guard {
            self.pinned[c] += 1;
        }
        self.evict_to_cap();
        for &c in &guard {
            self.pinned[c] -= 1;
        }
    }

    /// Evict least-recently-touched unpinned lanes until the cap holds.
    /// Pinned lanes are skipped (and requeued), so the cap is a floor with
    /// respect to pins: with more in-flight lanes than `max_resident`, the
    /// pool holds exactly the pinned set. After any eviction the shared
    /// basis pool is swept, or dead weak refs would accumulate O(lifetime
    /// materializations) between telemetry's per-round sweeps.
    fn evict_to_cap(&mut self) {
        if self.max_resident == 0 {
            return;
        }
        let mut skipped: Vec<Reverse<(u64, usize)>> = Vec::new();
        let mut evicted = false;
        while self.resident > self.max_resident {
            let Some(Reverse((t, cid))) = self.lru.pop() else {
                break;
            };
            if self.last_touch[cid] != t || self.slots[cid].is_none() {
                continue; // stale entry (re-touched, loaned, or already gone)
            }
            if self.pinned[cid] > 0 {
                skipped.push(Reverse((t, cid)));
                continue;
            }
            self.slots[cid] = None;
            self.resident -= 1;
            self.evictions += 1;
            evicted = true;
        }
        self.lru.extend(skipped);
        if evicted {
            if let Some(f) = &self.factory {
                f.pool.sweep();
            }
        }
    }

    /// Pin `cid` against eviction (an upload is in flight on it). Pins
    /// nest: every dispatch pins and every decoded (or faulted) arrival
    /// unpins, so under concurrency > 1 the lane stays pinned until its
    /// last in-flight frame resolves.
    pub(crate) fn pin(&mut self, cid: usize) {
        self.pinned[cid] += 1;
    }

    /// Drop one pin and re-enforce the cap (the pin may have been the only
    /// thing holding the pool above it).
    pub(crate) fn unpin(&mut self, cid: usize) {
        self.pinned[cid] = self.pinned[cid].saturating_sub(1);
        self.evict_to_cap();
    }

    /// Drop lane `cid` entirely — the availability-fault path. The
    /// client departed with an upload in flight: its client-side
    /// compressor advanced at dispatch with no decode to match, so the
    /// paired state is unrecoverable and the lane must not stay resident
    /// (or pinned). A later [`LanePool::lane_mut`]/
    /// [`LanePool::ensure_resident`] re-materializes the lane bit-exactly
    /// from `(seed, cid)` via the factory, re-interning its basis through
    /// the shared [`BasisPool`] — which is precisely how a
    /// departed-then-returning client re-enters fingerprint lockstep.
    /// Requires a factory (the fixed legacy-shards pool cannot rebuild a
    /// dropped lane; `Simulation::build` rejects that combination).
    pub(crate) fn discard(&mut self, cid: usize) {
        debug_assert!(self.factory.is_some(), "discarding a lane from a fixed pool");
        self.pinned[cid] = 0;
        if self.slots[cid].take().is_some() {
            self.resident -= 1;
            self.discards += 1;
            if let Some(f) = &self.factory {
                f.pool.sweep();
            }
        }
    }

    /// Mutable access to one lane, materializing it on the spot if needed
    /// (single-lane path — arrival decodes; no span, callers on the batch
    /// path use [`LanePool::ensure_resident`]).
    pub(crate) fn lane_mut(&mut self, cid: usize) -> &mut Client {
        if self.slots[cid].is_none() {
            let factory = self
                .factory
                .as_ref()
                .expect("non-resident lane in a fixed lane pool");
            self.slots[cid] = Some(Box::new(factory.materialize(cid)));
            self.resident += 1;
            self.materialized += 1;
        }
        self.touch(cid);
        self.slots[cid].as_deref_mut().unwrap()
    }

    /// Loan out the lanes for `ids` (must be distinct and resident — call
    /// [`LanePool::ensure_resident`] first). The slots go empty but the
    /// lanes still count as resident; pair with [`LanePool::restore`].
    /// O(k) in the number of ids, independent of population size.
    pub(crate) fn take(&mut self, ids: &[usize]) -> Vec<(usize, Box<Client>)> {
        debug_assert!(
            {
                let mut sorted = ids.to_vec();
                sorted.sort_unstable();
                sorted.windows(2).all(|w| w[0] != w[1])
            },
            "take() ids must be distinct"
        );
        ids.iter()
            .map(|&cid| {
                let lane = self.slots[cid].take().expect("taking a non-resident lane");
                (cid, lane)
            })
            .collect()
    }

    /// Return lanes loaned out by [`LanePool::take`].
    pub(crate) fn restore(&mut self, lanes: Vec<(usize, Box<Client>)>) {
        for (cid, lane) in lanes {
            debug_assert!(self.slots[cid].is_none(), "restoring into an occupied slot");
            self.slots[cid] = Some(lane);
        }
    }

    /// `(client compressor, server decompressor)` state fingerprints per
    /// lane, id order; non-resident lanes report `(0, 0)` (same as a
    /// stateless compressor).
    pub fn fingerprints(&self) -> Vec<(u64, u64)> {
        self.slots
            .iter()
            .map(|slot| match slot {
                Some(c) => (
                    c.compressor.state_fingerprint(),
                    c.decompressor.state_fingerprint(),
                ),
                None => (0, 0),
            })
            .collect()
    }
}
