//! FL coordinator: the Layer-3 runtime that drives federated training.
//!
//! One [`Simulation`] owns the global model, the synthetic federated
//! dataset, one client *lane* per client (private shard + RNG + compressor
//! + the server's paired decompressor), a [`Trainer`] backend (XLA
//! artifacts or the native reference), and the communication ledger.
//! `run()` executes the FedAvg round loop of paper §V, staged by the round
//! engine ([`engine`]):
//!
//! ```text
//! for round r:
//!   sample participants                     (participation fraction)
//!   stage 1  broadcast global params        → downlink charge
//!   stage 2  per-client phase, one lane per participant, fanned across
//!            `cfg.workers` threads when the backend is Sync:
//!              local SGD (E epochs) → Δᵢ → compress → decompress Δ̂ᵢ
//!   stage 3  fixed-order accounting (uplink, loss, Σd, hook) + weighted
//!            FedAvg aggregate via a deterministic chunked reduction
//!   stage 4  apply aggregate, evaluate on held-out data, record round
//! ```
//!
//! The engine is bit-deterministic in the worker count (see [`engine`]'s
//! module docs): `workers = 1` and `workers = N` produce identical
//! [`RoundRecord`]s for the same seed.

pub mod engine;
pub mod sampling;
pub mod trainer;

pub use engine::{ExecPlan, LaneOutcome, RoundInputs};
pub use sampling::ParticipationSampler;
pub use trainer::{NativeOrXla, ParallelTrainer, Trainer, XlaTrainer};

use anyhow::{anyhow, Context, Result};

use crate::compress::{build_pair, Compressor, Decompressor};
use crate::config::{DatasetKind, ExperimentConfig, ModelKind};
use crate::data::corpus::CorpusGenerator;
use crate::data::synth::{Dataset, SynthGenerator, SynthSpec};
use crate::data::{partition_indices, Partition};
use crate::metrics::{CommLedger, NetworkModel, RoundRecord, RunRecorder, RunReport};
use crate::model::meta::{layer_table, ModelMeta};
use crate::model::params::ParamStore;
use crate::util::rng::Pcg64;

/// One simulated client *lane*: everything a round's per-client phase
/// touches, colocated so the engine can move it into a worker task as one
/// disjoint unit — the client's private shard, RNG and compressor, plus the
/// server's paired decompressor. Client and server compressor state must
/// evolve in lockstep (the temporal-correlation contract), which pairing
/// them in one lane makes structural.
pub struct Client {
    /// Client id.
    pub id: usize,
    /// This client's private shard.
    pub data: Dataset,
    pub(crate) compressor: Box<dyn Compressor>,
    /// Server-side decompression state paired with this client's compressor.
    pub(crate) decompressor: Box<dyn Decompressor>,
    pub(crate) rng: Pcg64,
}

/// A fully-built federated simulation.
pub struct Simulation {
    /// The configuration this simulation was built from.
    pub cfg: ExperimentConfig,
    /// Architecture metadata.
    pub meta: ModelMeta,
    /// Global model parameters.
    pub global: ParamStore,
    /// Client lanes in id order.
    pub clients: Vec<Client>,
    /// Held-out evaluation data.
    pub test_data: Dataset,
    trainer: NativeOrXla,
    sampler: ParticipationSampler,
    ledger: CommLedger,
    network: NetworkModel,
    /// Per-round records.
    pub recorder: RunRecorder,
    /// Optional per-round callback hook (gradient probes, logging).
    round_hook: Option<Box<dyn FnMut(usize, &Simulation2Hook)>>,
}

/// Read-only view passed to round hooks.
pub struct Simulation2Hook<'a> {
    /// Round's decompressed updates per participant `(client_id, tensors)`.
    pub updates: &'a [(usize, Vec<Vec<f32>>)],
    /// Model metadata.
    pub meta: &'a ModelMeta,
}

/// Build the federated dataset for a config: per-client shards + test set.
pub fn build_datasets(
    cfg: &ExperimentConfig,
    rng: &mut Pcg64,
) -> (Vec<Dataset>, Dataset) {
    match cfg.dataset {
        DatasetKind::TinyCorpus => {
            // Token sequences: features hold the tokens as f32 (the trainer
            // casts to i32); labels unused.
            let gen = CorpusGenerator::new(256, 4, cfg.seed ^ 0xC0);
            let seq = 64;
            let shards = (0..cfg.num_clients)
                .map(|c| {
                    let mut r = rng.fork(1000 + c as u64);
                    let corpus = gen.generate(cfg.samples_per_client, seq, &mut r);
                    Dataset {
                        x: corpus.tokens.iter().map(|&t| t as f32).collect(),
                        y: vec![0; corpus.len()],
                        features: seq,
                        classes: 256,
                    }
                })
                .collect();
            let mut r = rng.fork(999);
            let test_corpus = gen.generate(cfg.test_samples, seq, &mut r);
            let test = Dataset {
                x: test_corpus.tokens.iter().map(|&t| t as f32).collect(),
                y: vec![0; test_corpus.len()],
                features: seq,
                classes: 256,
            };
            (shards, test)
        }
        kind => {
            let spec = SynthSpec::for_kind(kind);
            let gen = SynthGenerator::new(spec, cfg.seed ^ 0xDA7A);
            let total = cfg.num_clients * cfg.samples_per_client;
            let mut rdata = rng.fork(501);
            let train = gen.generate(total, &mut rdata);
            let part: Partition = partition_indices(
                &train.y,
                spec.classes,
                cfg.num_clients,
                cfg.distribution,
                &mut rng.fork(502),
            );
            let shards = part
                .assignments
                .iter()
                .map(|idx| train.subset(idx))
                .collect();
            let mut rtest = rng.fork(503);
            let test = gen.generate(cfg.test_samples, &mut rtest);
            (shards, test)
        }
    }
}

impl Simulation {
    /// Build everything from a config. Fails if `use_xla` is set but the
    /// artifacts are missing or don't cover the model.
    pub fn build(cfg: ExperimentConfig) -> Result<Simulation> {
        let meta = layer_table(cfg.model);
        let mut root = Pcg64::new(cfg.seed, 0x51);

        let (shards, test_data) = build_datasets(&cfg, &mut root);

        let trainer = NativeOrXla::build(&cfg, &meta)
            .with_context(|| "building trainer backend")?;

        let mut clients = Vec::with_capacity(cfg.num_clients);
        for (id, data) in shards.into_iter().enumerate() {
            let (compressor, decompressor) =
                build_pair(&cfg.compressor, &meta, cfg.seed ^ (id as u64) << 8);
            clients.push(Client {
                id,
                data,
                compressor,
                decompressor,
                rng: root.fork(7000 + id as u64),
            });
        }

        let global = ParamStore::init(&meta, &Pcg64::new(cfg.seed, 0x6000));
        let sampler = ParticipationSampler::new(
            cfg.num_clients,
            cfg.participation,
            root.fork(42),
        );
        Ok(Simulation {
            cfg,
            meta,
            global,
            clients,
            test_data,
            trainer,
            sampler,
            ledger: CommLedger::new(),
            network: NetworkModel::edge_default(),
            recorder: RunRecorder::new(),
            round_hook: None,
        })
    }

    /// Install a per-round hook (used by the Fig. 1 similarity probe).
    pub fn set_round_hook(
        &mut self,
        hook: Box<dyn FnMut(usize, &Simulation2Hook)>,
    ) {
        self.round_hook = Some(hook);
    }

    /// Total uplink bytes charged so far.
    pub fn total_uplink(&self) -> u64 {
        self.ledger.total_uplink()
    }

    /// Execute one round through the staged engine; returns the round
    /// record. Bit-identical for every `cfg.workers` value (see [`engine`]).
    pub fn step(&mut self, round: usize) -> Result<RoundRecord> {
        let participants = self.sampler.sample(round);
        let broadcast_bytes = 4 * self.global.numel() as u64;
        let workers = self.cfg.resolved_workers();

        // Stage 1: broadcast — every participant downloads the global model.
        for _ in &participants {
            self.ledger.charge_downlink(broadcast_bytes);
        }

        // Stage 2: per-client phase (local SGD → compress → decompress),
        // one lane per participant, fanned across workers when the backend
        // allows.
        let inputs = engine::RoundInputs {
            global: &self.global,
            local_epochs: self.cfg.local_epochs,
            batch_size: self.cfg.batch_size,
            lr: self.cfg.lr,
        };
        let lanes = engine::take_lanes(&mut self.clients, &participants);
        let outcomes = engine::run_client_phase(self.trainer.plan(workers), inputs, lanes)?;

        // Stage 3: fixed-order accounting over lane outcomes (participant
        // order, independent of completion order) …
        let mut per_client_up: Vec<u64> = Vec::with_capacity(outcomes.len());
        let mut updates: Vec<(usize, Vec<Vec<f32>>)> = Vec::with_capacity(outcomes.len());
        let mut weights: Vec<f64> = Vec::with_capacity(outcomes.len());
        let mut loss_sum = 0.0f64;
        let mut sum_d = 0u64;
        for outcome in outcomes {
            self.ledger.charge_uplink(outcome.uplink_bytes);
            per_client_up.push(outcome.uplink_bytes);
            loss_sum += outcome.mean_loss;
            sum_d += outcome.stats.sum_d;
            weights.push(outcome.weight);
            updates.push((outcome.cid, outcome.update));
        }

        if let Some(hook) = self.round_hook.as_mut() {
            hook(round, &Simulation2Hook { updates: &updates, meta: &self.meta });
        }

        // … followed by the weighted FedAvg aggregate as a deterministic
        // chunked reduction (shard-size weights).
        let wtotal: f64 = weights.iter().sum();
        let scales: Vec<f32> = weights.iter().map(|w| (w / wtotal) as f32).collect();
        let terms: Vec<&[Vec<f32>]> = updates.iter().map(|(_, u)| u.as_slice()).collect();
        let agg = ParamStore::weighted_sum(&self.meta, &terms, &scales, workers);

        // Stage 4: apply, evaluate, record.
        self.global.axpy(1.0, &agg);

        let (test_loss, test_acc) = if round % self.cfg.eval_every == 0
            || round + 1 == self.cfg.rounds
        {
            self.trainer.evaluate(&self.global, &self.test_data)?
        } else {
            (f64::NAN, f64::NAN)
        };

        let (up, down) = self.ledger.end_round();
        let record = RoundRecord {
            round,
            train_loss: loss_sum / participants.len().max(1) as f64,
            test_accuracy: test_acc,
            test_loss,
            uplink_bytes: up,
            downlink_bytes: down,
            sim_time_s: self.network.round_time(&per_client_up, broadcast_bytes),
            sum_d,
        };
        self.recorder.push(record.clone());
        Ok(record)
    }

    /// Run all configured rounds and produce the summary report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with_progress(|_, _| {})
    }

    /// Like [`Simulation::run`] but invokes `progress(round, record)` after
    /// each round (CLI progress lines).
    pub fn run_with_progress(
        &mut self,
        mut progress: impl FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        for round in 0..self.cfg.rounds {
            let rec = self.step(round)?;
            progress(round, &rec);
        }
        let threshold = self.cfg.threshold_frac * self.recorder.best_accuracy();
        Ok(self.recorder.report(threshold))
    }
}

/// Verify a model kind is covered by the artifacts dir without building a
/// full simulation (used by the CLI for friendly errors).
pub fn check_artifacts(cfg: &ExperimentConfig) -> Result<()> {
    if !cfg.use_xla {
        return Ok(());
    }
    let rt = crate::runtime::Runtime::open(&cfg.artifacts_dir)?;
    let name = crate::config::experiment::model_name(cfg.model);
    if !rt.manifest().models.contains_key(name) {
        return Err(anyhow!(
            "artifacts at '{}' do not cover model '{name}' — run `make artifacts`",
            cfg.artifacts_dir
        ));
    }
    Ok(())
}

/// Convenience: model kinds whose datasets are vision-shaped.
pub fn is_vision(model: ModelKind) -> bool {
    !matches!(model, ModelKind::TinyTransformer)
}
