//! FL coordinator: the Layer-3 runtime that drives federated training.
//!
//! One [`Simulation`] owns the global model, the synthetic federated
//! dataset, one (compressor, decompressor) pair per client, a [`Trainer`]
//! backend (XLA artifacts or the native reference), and the communication
//! ledger. `run()` executes the FedAvg round loop of paper §V:
//!
//! ```text
//! for round r:
//!   sample participants                    (participation fraction)
//!   broadcast global params  → downlink charge
//!   per client: local SGD (E epochs) → update Δᵢ → compress → uplink charge
//!   server: decompress Δ̂ᵢ → weighted FedAvg aggregate → apply
//!   evaluate on held-out data, record round
//! ```

pub mod sampling;
pub mod trainer;

pub use sampling::ParticipationSampler;
pub use trainer::{NativeOrXla, Trainer, XlaTrainer};

use anyhow::{anyhow, Context, Result};

use crate::compress::{build_pair, Compressor, Decompressor};
use crate::config::{DatasetKind, ExperimentConfig, ModelKind};
use crate::data::corpus::CorpusGenerator;
use crate::data::synth::{Dataset, SynthGenerator, SynthSpec};
use crate::data::{partition_indices, Partition};
use crate::metrics::{CommLedger, NetworkModel, RoundRecord, RunRecorder, RunReport};
use crate::model::meta::{layer_table, ModelMeta};
use crate::model::params::ParamStore;
use crate::util::rng::Pcg64;

/// One simulated client.
pub struct Client {
    /// Client id.
    pub id: usize,
    /// This client's private shard.
    pub data: Dataset,
    compressor: Box<dyn Compressor>,
    rng: Pcg64,
}

/// Server-side per-client decompression state.
struct ServerSide {
    decompressor: Box<dyn Decompressor>,
}

/// A fully-built federated simulation.
pub struct Simulation {
    /// The configuration this simulation was built from.
    pub cfg: ExperimentConfig,
    /// Architecture metadata.
    pub meta: ModelMeta,
    /// Global model parameters.
    pub global: ParamStore,
    /// Clients in id order.
    pub clients: Vec<Client>,
    server_sides: Vec<ServerSide>,
    /// Held-out evaluation data.
    pub test_data: Dataset,
    trainer: NativeOrXla,
    sampler: ParticipationSampler,
    ledger: CommLedger,
    network: NetworkModel,
    /// Per-round records.
    pub recorder: RunRecorder,
    /// Optional per-round callback hook (gradient probes, logging).
    round_hook: Option<Box<dyn FnMut(usize, &Simulation2Hook)>>,
}

/// Read-only view passed to round hooks.
pub struct Simulation2Hook<'a> {
    /// Round's decompressed updates per participant `(client_id, tensors)`.
    pub updates: &'a [(usize, Vec<Vec<f32>>)],
    /// Model metadata.
    pub meta: &'a ModelMeta,
}

/// Build the federated dataset for a config: per-client shards + test set.
pub fn build_datasets(
    cfg: &ExperimentConfig,
    rng: &mut Pcg64,
) -> (Vec<Dataset>, Dataset) {
    match cfg.dataset {
        DatasetKind::TinyCorpus => {
            // Token sequences: features hold the tokens as f32 (the trainer
            // casts to i32); labels unused.
            let gen = CorpusGenerator::new(256, 4, cfg.seed ^ 0xC0);
            let seq = 64;
            let shards = (0..cfg.num_clients)
                .map(|c| {
                    let mut r = rng.fork(1000 + c as u64);
                    let corpus = gen.generate(cfg.samples_per_client, seq, &mut r);
                    Dataset {
                        x: corpus.tokens.iter().map(|&t| t as f32).collect(),
                        y: vec![0; corpus.len()],
                        features: seq,
                        classes: 256,
                    }
                })
                .collect();
            let mut r = rng.fork(999);
            let test_corpus = gen.generate(cfg.test_samples, seq, &mut r);
            let test = Dataset {
                x: test_corpus.tokens.iter().map(|&t| t as f32).collect(),
                y: vec![0; test_corpus.len()],
                features: seq,
                classes: 256,
            };
            (shards, test)
        }
        kind => {
            let spec = SynthSpec::for_kind(kind);
            let gen = SynthGenerator::new(spec, cfg.seed ^ 0xDA7A);
            let total = cfg.num_clients * cfg.samples_per_client;
            let mut rdata = rng.fork(501);
            let train = gen.generate(total, &mut rdata);
            let part: Partition = partition_indices(
                &train.y,
                spec.classes,
                cfg.num_clients,
                cfg.distribution,
                &mut rng.fork(502),
            );
            let shards = part
                .assignments
                .iter()
                .map(|idx| train.subset(idx))
                .collect();
            let mut rtest = rng.fork(503);
            let test = gen.generate(cfg.test_samples, &mut rtest);
            (shards, test)
        }
    }
}

impl Simulation {
    /// Build everything from a config. Fails if `use_xla` is set but the
    /// artifacts are missing or don't cover the model.
    pub fn build(cfg: ExperimentConfig) -> Result<Simulation> {
        let meta = layer_table(cfg.model);
        let mut root = Pcg64::new(cfg.seed, 0x51);

        let (shards, test_data) = build_datasets(&cfg, &mut root);

        let trainer = NativeOrXla::build(&cfg, &meta)
            .with_context(|| "building trainer backend")?;

        let mut clients = Vec::with_capacity(cfg.num_clients);
        let mut server_sides = Vec::with_capacity(cfg.num_clients);
        for (id, data) in shards.into_iter().enumerate() {
            let (compressor, decompressor) =
                build_pair(&cfg.compressor, &meta, cfg.seed ^ (id as u64) << 8);
            clients.push(Client {
                id,
                data,
                compressor,
                rng: root.fork(7000 + id as u64),
            });
            server_sides.push(ServerSide { decompressor });
        }

        let global = ParamStore::init(&meta, &Pcg64::new(cfg.seed, 0x6000));
        let sampler = ParticipationSampler::new(
            cfg.num_clients,
            cfg.participation,
            root.fork(42),
        );
        Ok(Simulation {
            cfg,
            meta,
            global,
            clients,
            server_sides,
            test_data,
            trainer,
            sampler,
            ledger: CommLedger::new(),
            network: NetworkModel::edge_default(),
            recorder: RunRecorder::new(),
            round_hook: None,
        })
    }

    /// Install a per-round hook (used by the Fig. 1 similarity probe).
    pub fn set_round_hook(
        &mut self,
        hook: Box<dyn FnMut(usize, &Simulation2Hook)>,
    ) {
        self.round_hook = Some(hook);
    }

    /// Total uplink bytes charged so far.
    pub fn total_uplink(&self) -> u64 {
        self.ledger.total_uplink()
    }

    /// Execute one round; returns the round record.
    pub fn step(&mut self, round: usize) -> Result<RoundRecord> {
        let participants = self.sampler.sample(round);
        let broadcast_bytes = 4 * self.global.numel() as u64;

        let mut per_client_up: Vec<u64> = Vec::with_capacity(participants.len());
        let mut updates: Vec<(usize, Vec<Vec<f32>>)> =
            Vec::with_capacity(participants.len());
        let mut weights: Vec<f64> = Vec::with_capacity(participants.len());
        let mut loss_sum = 0.0f64;
        let mut sum_d = 0u64;

        for &cid in &participants {
            self.ledger.charge_downlink(broadcast_bytes);
            let client = &mut self.clients[cid];
            // Local training from the broadcast global model.
            let (new_params, mean_loss) = self.trainer.local_train(
                &self.global,
                &client.data,
                self.cfg.local_epochs,
                self.cfg.batch_size,
                self.cfg.lr,
                &mut client.rng,
            )?;
            loss_sum += mean_loss;
            // Pseudo-gradient: Δ = new − global.
            let delta = new_params.delta(&self.global);
            let tensors: Vec<Vec<f32>> =
                (0..delta.len()).map(|i| delta.tensor(i).to_vec()).collect();
            let (payloads, stats) = client.compressor.compress(&tensors);
            sum_d += stats.sum_d;
            let up: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
            self.ledger.charge_uplink(up);
            per_client_up.push(up);
            // Server-side reconstruction.
            let rec = self.server_sides[cid].decompressor.decompress(&payloads);
            updates.push((cid, rec));
            weights.push(client.data.len() as f64);
        }

        if let Some(mut hook) = self.round_hook.take() {
            hook(round, &Simulation2Hook { updates: &updates, meta: &self.meta });
            self.round_hook = Some(hook);
        }

        // FedAvg aggregation, weighted by shard size.
        let wtotal: f64 = weights.iter().sum();
        let mut agg = ParamStore::zeros_like(&self.meta);
        for ((_, upd), w) in updates.iter().zip(&weights) {
            let scale = (w / wtotal) as f32;
            for (i, t) in upd.iter().enumerate() {
                let dst = agg.tensor_mut(i);
                for (d, &v) in dst.iter_mut().zip(t) {
                    *d += scale * v;
                }
            }
        }
        self.global.axpy(1.0, &agg);

        // Evaluation.
        let (test_loss, test_acc) = if round % self.cfg.eval_every == 0
            || round + 1 == self.cfg.rounds
        {
            self.trainer.evaluate(&self.global, &self.test_data)?
        } else {
            (f64::NAN, f64::NAN)
        };

        let (up, down) = self.ledger.end_round();
        let record = RoundRecord {
            round,
            train_loss: loss_sum / participants.len().max(1) as f64,
            test_accuracy: test_acc,
            test_loss,
            uplink_bytes: up,
            downlink_bytes: down,
            sim_time_s: self.network.round_time(&per_client_up, broadcast_bytes),
            sum_d,
        };
        self.recorder.push(record.clone());
        Ok(record)
    }

    /// Run all configured rounds and produce the summary report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with_progress(|_, _| {})
    }

    /// Like [`Simulation::run`] but invokes `progress(round, record)` after
    /// each round (CLI progress lines).
    pub fn run_with_progress(
        &mut self,
        mut progress: impl FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        for round in 0..self.cfg.rounds {
            let rec = self.step(round)?;
            progress(round, &rec);
        }
        let threshold = self.cfg.threshold_frac * self.recorder.best_accuracy();
        Ok(self.recorder.report(threshold))
    }
}

/// Verify a model kind is covered by the artifacts dir without building a
/// full simulation (used by the CLI for friendly errors).
pub fn check_artifacts(cfg: &ExperimentConfig) -> Result<()> {
    if !cfg.use_xla {
        return Ok(());
    }
    let rt = crate::runtime::Runtime::open(&cfg.artifacts_dir)?;
    let name = crate::config::experiment::model_name(cfg.model);
    if !rt.manifest().models.contains_key(name) {
        return Err(anyhow!(
            "artifacts at '{}' do not cover model '{name}' — run `make artifacts`",
            cfg.artifacts_dir
        ));
    }
    Ok(())
}

/// Convenience: model kinds whose datasets are vision-shaped.
pub fn is_vision(model: ModelKind) -> bool {
    !matches!(model, ModelKind::TinyTransformer)
}
