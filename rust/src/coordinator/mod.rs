//! FL coordinator: the Layer-3 runtime that drives federated training.
//!
//! One [`Simulation`] owns the global model, the synthetic federated
//! dataset, one client *lane* per client (private shard + RNG + compressor
//! + the server's paired decompressor), a [`Trainer`] backend (XLA
//! artifacts or the native reference), the [`Transport`] fabric every byte
//! crosses, the per-client link model, the communication ledger, and the
//! population-wide [`BasisPool`] in which every lane's decompressor
//! interns its basis state (per-client server memory is a handle, not a
//! matrix — see [`crate::compress::intern`]).
//! `run()` executes the FedAvg round loop of paper §V, staged by the round
//! engine ([`engine`]):
//!
//! ```text
//! for round r:
//!   sample participants, apply dropout      (participation · survival)
//!   stage 1  encode global params → Transport → downlink charged from
//!            the delivered frames → decode client-side
//!   stage 2  client phase, one lane per survivor, fanned across
//!            `cfg.workers` threads when the backend is Sync:
//!              local SGD (E epochs) → Δᵢ → compress → encode to bytes
//!   stage 3  upload frames through the Transport (participant order),
//!            uplink charged from the drained buffers, straggler deadline
//!   stage 4  server decode: frame → structured LayerUpdates per lane
//!            (parallel over lanes; stragglers decoded too — lockstep —
//!            but folded with weight 0, i.e. skipped by the aggregate)
//!   stage 5  streaming compressed-domain aggregation: on-time updates
//!            folded in participant order into per-layer accumulators
//!            (parallel over layers, [`ServerAggregator`]), fusing
//!            low-rank reconstruction with the weighted FedAvg reduction
//!            in O(model) memory; dense per-client updates materialize
//!            only when a round hook asks to observe them
//!   stage 6  apply aggregate, evaluate on held-out data, record round
//! ```
//!
//! Late (straggler) uploads are still decoded — the paired compressor/
//! decompressor state must evolve in lockstep — but are excluded from the
//! round's aggregate, mirroring a synchronous server that processes late
//! arrivals off the critical path.
//!
//! The engine is bit-deterministic in the worker count (see [`engine`]'s
//! module docs): `workers = 1` and `workers = N` produce identical
//! [`RoundRecord`]s — including identical surviving-client sets under
//! dropout — for the same seed.

pub mod aggregate;
pub mod engine;
pub mod lanes;
pub mod sampling;
pub mod trainer;

pub use aggregate::ServerAggregator;
pub use engine::{ClientFrame, ExecPlan, RoundInputs};
pub use lanes::LanePool;
pub use sampling::ParticipationSampler;
pub use trainer::{NativeOrXla, ParallelTrainer, Trainer, XlaTrainer};

use anyhow::{anyhow, Context, Result};

use crate::compress::{build_pair_with, BasisPool, Compressor, Decompressor, LayerUpdate, PoolStats};
use crate::config::{DatasetKind, ExperimentConfig, ModelKind};
use crate::linalg::Backend;
use crate::data::corpus::CorpusGenerator;
use crate::data::synth::{Dataset, SynthGenerator, SynthSpec};
use crate::data::{partition_indices, plan_shards, Partition};
use crate::metrics::{CommLedger, NetworkModel, RoundRecord, RunRecorder, RunReport};
use crate::model::meta::{layer_table, ModelMeta};
use crate::model::params::ParamStore;
use crate::net::transport::Instrumented;
use crate::net::{wire, BroadcastCache, DropoutModel, Loopback, Transport};
use crate::telemetry::{ApplyEvent, ArrivalEvent, DispatchEvent, Observer, Phase, Telemetry};
use crate::util::rng::Pcg64;

use std::sync::Arc;

/// One simulated client *lane*: everything a round's per-client phase
/// touches, colocated so the engine can move it into a worker task as one
/// disjoint unit — the client's private shard, RNG and compressor, plus the
/// server's paired decompressor. Client and server compressor state must
/// evolve in lockstep (the temporal-correlation contract), which pairing
/// them in one lane makes structural.
pub struct Client {
    /// Client id.
    pub id: usize,
    /// This client's private shard.
    pub data: Dataset,
    pub(crate) compressor: Box<dyn Compressor>,
    /// Server-side decompression state paired with this client's compressor.
    pub(crate) decompressor: Box<dyn Decompressor>,
    pub(crate) rng: Pcg64,
}

/// A fully-built federated simulation.
pub struct Simulation {
    /// The configuration this simulation was built from.
    pub cfg: ExperimentConfig,
    /// Architecture metadata.
    pub meta: ModelMeta,
    /// Global model parameters.
    pub global: ParamStore,
    /// The population's lane slots: resident client lanes plus the factory
    /// that (re-)materializes missing ones from `(seed, cid)` — see
    /// [`lanes`].
    pub lanes: LanePool,
    /// Held-out evaluation data. Shared (`Arc`) so experiment grids whose
    /// cells differ only in shards reuse one test set instead of cloning
    /// it per cell.
    pub test_data: Arc<Dataset>,
    // Crate-visible so the scheduler plane (`crate::sched`) can drive the
    // same stages the legacy loop does — broadcast/upload through the
    // transport, ledger charges from drained frames, per-lane decode —
    // without a parallel accessor API.
    pub(crate) trainer: NativeOrXla,
    pub(crate) sampler: ParticipationSampler,
    pub(crate) ledger: CommLedger,
    pub(crate) network: NetworkModel,
    pub(crate) transport: Box<dyn Transport>,
    pub(crate) dropout: DropoutModel,
    /// The basis-interning pool every lane's decompressor shares: one
    /// allocation per *distinct* server-side basis across the whole
    /// population ([`crate::compress::intern`]), the memory lever that
    /// lets the scheduler plane's 10⁴+-client populations fit.
    pub(crate) basis_pool: BasisPool,
    /// Virtual simulation clock, seconds: cumulative `sim_time_s` for the
    /// sync loop, scheduler-managed for semi-sync/async. Recorded per round
    /// as [`RoundRecord::sim_clock_s`].
    pub(crate) vclock: f64,
    /// Global-model version: bumped once per scheduler apply. Keys the
    /// broadcast-encode cache.
    pub(crate) model_version: u64,
    /// Model-version-keyed broadcast-encode memo shared by all schedulers
    /// ([`crate::net::broadcast`]); consult via
    /// [`Simulation::broadcast_frame`].
    pub(crate) broadcast_cache: BroadcastCache,
    /// Compute backend resolved from `cfg.backend`: every compressor lane
    /// and server aggregator in this simulation runs on it.
    pub(crate) backend: &'static dyn Backend,
    /// Per-round records.
    pub recorder: RunRecorder,
    /// Telemetry plane, `None` when disabled — no span buffer, registry,
    /// or transport wrapper is allocated, and every recording site is one
    /// pointer test (see [`crate::telemetry`]).
    pub(crate) telemetry: Option<Arc<Telemetry>>,
    /// Streaming run probe ([`crate::telemetry::Observer`]), called from
    /// every scheduler; installed via [`Simulation::set_observer`] or the
    /// legacy [`Simulation::set_round_hook`] adapter.
    pub(crate) observer: Option<Box<dyn Observer>>,
}

/// Read-only view passed to round hooks.
///
/// Installing a hook is the *only* thing that makes the server phase
/// materialize dense per-client updates (the Fig. 1 similarity probe needs
/// them); without one, updates stay in their structured compressed form
/// end to end.
pub struct RoundHookView<'a> {
    /// Round's densified updates per participant `(client_id, tensors)`.
    pub updates: &'a [(usize, Vec<Vec<f32>>)],
    /// Model metadata.
    pub meta: &'a ModelMeta,
}

/// Replays streaming [`Observer`] arrivals as the legacy per-round dense
/// batch: buffers each arrival densified, hands the batch to the wrapped
/// hook when the record lands, and clears. This is what makes
/// `set_round_hook` probes (the Fig. 1 similarity heatmap) work unchanged
/// under semisync and async, where "round" is whatever the scheduler
/// records (async: one apply per record).
struct RoundHookAdapter {
    hook: Box<dyn FnMut(usize, &RoundHookView)>,
    meta: ModelMeta,
    pending: Vec<(usize, Vec<Vec<f32>>)>,
}

impl Observer for RoundHookAdapter {
    fn on_arrival(&mut self, ev: &ArrivalEvent) {
        self.pending.push((ev.cid, ev.dense()));
    }

    fn on_round(&mut self, round: usize, _rec: &RoundRecord) {
        let view = RoundHookView { updates: &self.pending, meta: &self.meta };
        (self.hook)(round, &view);
        self.pending.clear();
    }
}

/// Build the federated dataset for a config: per-client shards + test set.
///
/// This is the **frozen legacy keying** (`cfg.lanes.legacy_shards`): one
/// sequential root-RNG walk generates the whole training pool, then an
/// index partition slices it into shards. The virtual-lane path instead
/// derives each shard independently from `(seed, cid)` (see [`lanes`]);
/// the two produce different shard *values* by construction, so this path
/// is kept runnable as the regression reference. Test sets are identical
/// across both paths (same `fork(999)`/`fork(503)` streams).
pub fn build_datasets(
    cfg: &ExperimentConfig,
    rng: &mut Pcg64,
) -> (Vec<Dataset>, Dataset) {
    match cfg.dataset {
        DatasetKind::TinyCorpus => {
            // Token sequences: features hold the tokens as f32 (the trainer
            // casts to i32); labels unused.
            let gen = CorpusGenerator::new(256, 4, cfg.seed ^ 0xC0);
            let seq = 64;
            let shards = (0..cfg.num_clients)
                .map(|c| {
                    let mut r = rng.fork(1000 + c as u64);
                    let corpus = gen.generate(cfg.samples_per_client, seq, &mut r);
                    Dataset {
                        x: corpus.tokens.iter().map(|&t| t as f32).collect(),
                        y: vec![0; corpus.len()],
                        features: seq,
                        classes: 256,
                    }
                })
                .collect();
            let mut r = rng.fork(999);
            let test_corpus = gen.generate(cfg.test_samples, seq, &mut r);
            let test = Dataset {
                x: test_corpus.tokens.iter().map(|&t| t as f32).collect(),
                y: vec![0; test_corpus.len()],
                features: seq,
                classes: 256,
            };
            (shards, test)
        }
        kind => {
            let spec = SynthSpec::for_kind(kind);
            let gen = SynthGenerator::new(spec, cfg.seed ^ 0xDA7A);
            let total = cfg.num_clients * cfg.samples_per_client;
            let mut rdata = rng.fork(501);
            let train = gen.generate(total, &mut rdata);
            let part: Partition = partition_indices(
                &train.y,
                spec.classes,
                cfg.num_clients,
                cfg.distribution,
                &mut rng.fork(502),
            );
            let shards = part
                .assignments
                .iter()
                .map(|idx| train.subset(idx))
                .collect();
            let mut rtest = rng.fork(503);
            let test = gen.generate(cfg.test_samples, &mut rtest);
            (shards, test)
        }
    }
}

impl Simulation {
    /// Build everything from a config. Fails if `use_xla` is set but the
    /// artifacts are missing or don't cover the model.
    pub fn build(cfg: ExperimentConfig) -> Result<Simulation> {
        Simulation::build_with_test_data(cfg, None)
    }

    /// Like [`Simulation::build`] but reusing a pre-built test set.
    /// Experiment grids whose cells differ only in shard assignment (same
    /// dataset kind, `test_samples`, and seed) pass the previous cell's
    /// [`Simulation::test_data`] here instead of regenerating and cloning
    /// the full evaluation set per cell. `None` generates it as usual.
    pub fn build_with_test_data(
        cfg: ExperimentConfig,
        shared_test: Option<Arc<Dataset>>,
    ) -> Result<Simulation> {
        cfg.net.validate().map_err(|e| anyhow!("invalid network config: {e}"))?;
        cfg.sched.validate().map_err(|e| anyhow!("invalid scheduler config: {e}"))?;
        cfg.lanes.validate().map_err(|e| anyhow!("invalid lane config: {e}"))?;
        // Cross-plane coherence: a fault discards the departed client's
        // lane, and only the virtual-lane factory can re-materialize it
        // from (seed, cid) — the fixed legacy-shards pool cannot.
        if cfg.sched.avail.armed() && cfg.lanes.legacy_shards {
            return Err(anyhow!(
                "availability/churn (--avail < 1 or --churn > 0) is incompatible with \
                 --legacy-shards: a faulted client's lane is discarded and must be \
                 re-materialized from (seed, cid), which the fixed legacy pool cannot do"
            ));
        }
        let meta = layer_table(cfg.model);
        let mut root = Pcg64::new(cfg.seed, 0x51);

        let trainer = NativeOrXla::build(&cfg, &meta)
            .with_context(|| "building trainer backend")?;

        // One basis pool for the whole population: every lane's
        // decompressor interns its basis state here, so per-client server
        // memory is a handle, not a matrix, and identical bases dedupe.
        let basis_pool = BasisPool::new();
        let backend = cfg.backend.resolve();

        let (lanes, test_data) = if cfg.lanes.legacy_shards {
            // Frozen reference: the pre-virtual-lane sequential root-RNG
            // walk, materialized eagerly into a fixed pool.
            let (shards, test) = build_datasets(&cfg, &mut root);
            let mut clients = Vec::with_capacity(cfg.num_clients);
            for (id, data) in shards.into_iter().enumerate() {
                let (compressor, decompressor) = build_pair_with(
                    &basis_pool,
                    &cfg.compressor,
                    &meta,
                    cfg.seed ^ ((id as u64) << 8),
                    backend,
                );
                clients.push(Client {
                    id,
                    data,
                    compressor,
                    decompressor,
                    rng: root.fork(7000 + id as u64),
                });
            }
            let test = shared_test.unwrap_or_else(|| Arc::new(test));
            (LanePool::fixed(clients), test)
        } else {
            // Virtual lanes: every lane derives from (seed, cid) through
            // the factory — see `lanes` for the seed-derivation contract.
            let source = match cfg.dataset {
                DatasetKind::TinyCorpus => lanes::ShardSource::Corpus {
                    gen: Arc::new(CorpusGenerator::new(256, 4, cfg.seed ^ 0xC0)),
                    samples: cfg.samples_per_client,
                    seq: 64,
                },
                kind => {
                    let spec = SynthSpec::for_kind(kind);
                    let total = cfg.num_clients * cfg.samples_per_client;
                    // The population-wide shard plan draws labels and runs
                    // the partition from dedicated root forks: O(total)
                    // u32 labels, not O(total) pixels.
                    let plan = plan_shards(
                        total,
                        spec.classes,
                        cfg.num_clients,
                        cfg.distribution,
                        &mut root.fork(0x2_0000_0000),
                        &mut root.fork(0x2_0000_0001),
                    );
                    lanes::ShardSource::Synth {
                        gen: Arc::new(SynthGenerator::new(spec, cfg.seed ^ 0xDA7A)),
                        plan: Arc::new(plan),
                    }
                }
            };
            let test = match shared_test {
                Some(t) => t,
                // Same streams the legacy path uses (fork(999)/fork(503)),
                // so test sets are identical across legacy/plan keying.
                None => Arc::new(match &source {
                    lanes::ShardSource::Corpus { gen, seq, .. } => {
                        let corpus =
                            gen.generate(cfg.test_samples, *seq, &mut root.fork(999));
                        Dataset {
                            x: corpus.tokens.iter().map(|&t| t as f32).collect(),
                            y: vec![0; corpus.len()],
                            features: *seq,
                            classes: 256,
                        }
                    }
                    lanes::ShardSource::Synth { gen, .. } => {
                        gen.generate(cfg.test_samples, &mut root.fork(503))
                    }
                }),
            };
            let factory = lanes::LaneFactory {
                root: root.clone(),
                seed: cfg.seed,
                compressor: cfg.compressor.clone(),
                meta: meta.clone(),
                pool: basis_pool.clone(),
                backend,
                source,
            };
            let mut pool =
                LanePool::virtual_lanes(cfg.num_clients, factory, cfg.lanes.max_resident);
            if !cfg.lanes.lazy {
                // Eager mode: materialize the whole population now, fanned
                // across workers in deterministic cid order (telemetry is
                // enabled post-build, so no spans to record here).
                let all: Vec<usize> = (0..cfg.num_clients).collect();
                pool.ensure_resident(&all, cfg.resolved_workers(), None, 0);
            }
            (pool, test)
        };

        let global = ParamStore::init(&meta, &Pcg64::new(cfg.seed, 0x6000));
        let sampler = ParticipationSampler::new(
            cfg.num_clients,
            cfg.participation,
            root.fork(42),
        );
        // Per-client links and the dropout model draw from their own seed
        // streams, so enabling them never perturbs data/model/sampler RNG.
        let network =
            NetworkModel::from_profiles(cfg.net.sample_links(cfg.num_clients, cfg.seed));
        let dropout = DropoutModel::new(cfg.net.dropout, cfg.seed ^ 0xD20);
        Ok(Simulation {
            cfg,
            meta,
            global,
            lanes,
            test_data,
            trainer,
            sampler,
            ledger: CommLedger::new(),
            network,
            transport: Box::new(Loopback::new()),
            dropout,
            basis_pool,
            vclock: 0.0,
            model_version: 0,
            broadcast_cache: BroadcastCache::new(),
            backend,
            recorder: RunRecorder::new(),
            telemetry: None,
            observer: None,
        })
    }

    /// The per-client link model in effect.
    pub fn network(&self) -> &NetworkModel {
        &self.network
    }

    /// Swap the transport fabric (e.g. a future distributed backend). The
    /// replacement must honor [`Transport`]'s FIFO drain contract.
    pub fn set_transport(&mut self, transport: Box<dyn Transport>) {
        self.transport = transport;
    }

    /// Install a per-round hook (used by the Fig. 1 similarity probe).
    /// This opts the server phase into densifying every arrival's update
    /// for the hook's [`RoundHookView`]; leave it uninstalled to keep the
    /// round loop in the compressed domain. Implemented as an adapter over
    /// [`Simulation::set_observer`], so hooks now fire under every
    /// scheduler, not just sync.
    pub fn set_round_hook(
        &mut self,
        hook: Box<dyn FnMut(usize, &RoundHookView)>,
    ) {
        self.observer = Some(Box::new(RoundHookAdapter {
            hook,
            meta: self.meta.clone(),
            pending: Vec::new(),
        }));
    }

    /// Install a streaming run probe, called from all three schedulers —
    /// see [`crate::telemetry::Observer`] for the per-scheduler lifecycle.
    /// Latest installation wins ([`Simulation::set_round_hook`] is an
    /// adapter over this).
    pub fn set_observer(&mut self, observer: Box<dyn Observer>) {
        self.observer = Some(observer);
    }

    /// Switch telemetry on for this run: allocates the span/metrics store
    /// and wraps the transport in a counting
    /// [`crate::net::transport::Instrumented`]. Idempotent; returns the
    /// live handle (also reachable via [`Simulation::telemetry`]). Without
    /// this call, the plane's cost is one pointer test per site.
    pub fn enable_telemetry(&mut self) -> Arc<Telemetry> {
        if let Some(tel) = &self.telemetry {
            return Arc::clone(tel);
        }
        let tel = Arc::new(Telemetry::new(self.backend.name(), self.cfg.sched.kind.name()));
        let inner = std::mem::replace(&mut self.transport, Box::new(Loopback::new()));
        self.transport = Box::new(Instrumented::new(inner, tel.transport_counters()));
        self.telemetry = Some(Arc::clone(&tel));
        tel
    }

    /// The run's telemetry, if [`Simulation::enable_telemetry`] was called.
    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.telemetry.as_ref()
    }

    /// Round-end telemetry: drive the basis-pool sweep (`stats()` *is* the
    /// dead-weak-ref sweep, so the gauges below can never report freed
    /// bases), gauge pool occupancy, and freeze this round's metrics into
    /// `record.ext`. No-op when telemetry is disabled.
    pub(crate) fn telemetry_round_end(&mut self, record: &mut RoundRecord) {
        if let Some(tel) = &self.telemetry {
            let pool = self.basis_pool.stats();
            tel.gauge("pool.entries", pool.entries as f64);
            tel.gauge("pool.bytes", pool.bytes() as f64);
            tel.gauge("lanes.resident", self.lanes.resident() as f64);
            tel.gauge("lanes.materialized", self.lanes.materializations() as f64);
            tel.gauge("lanes.evictions", self.lanes.eviction_count() as f64);
            tel.gauge("lanes.discarded", self.lanes.discard_count() as f64);
            tel.count("sum_d", record.sum_d);
            record.ext = Some(tel.snapshot_round(record.round as u64));
        }
    }

    /// The encoded broadcast frame for model `version`, memoized in the
    /// shared [`BroadcastCache`]: all three schedulers encode each version
    /// at most once. A `BroadcastEncode` span (tagged `span_round`; async
    /// passes the version) is recorded only when the encode actually runs.
    pub(crate) fn broadcast_frame(&mut self, version: u64, span_round: u64) -> Arc<[u8]> {
        if let Some(frame) = self.broadcast_cache.get(version) {
            return frame;
        }
        let tel = self.telemetry.clone();
        let sp = Telemetry::timer(tel.as_deref());
        let frame: Arc<[u8]> = wire::encode_params(&self.global).into();
        if let Some(sp) = sp {
            sp.end(Phase::BroadcastEncode, span_round, None);
        }
        self.broadcast_cache.put(version, Arc::clone(&frame));
        frame
    }

    /// `(client compressor, server decompressor)` state fingerprints per
    /// client lane, id order. The two halves must be equal whenever the
    /// paired states are in lockstep — the invariant the straggler-decode
    /// tests assert from outside the crate. Stateless compressors and
    /// non-resident (never-materialized or evicted) lanes report `(0, 0)`.
    pub fn lane_fingerprints(&self) -> Vec<(u64, u64)> {
        self.lanes.fingerprints()
    }

    /// Total uplink bytes charged so far.
    pub fn total_uplink(&self) -> u64 {
        self.ledger.total_uplink()
    }

    /// The shared basis-interning pool (all lanes' server-side basis
    /// state lives here; see [`crate::compress::intern`]).
    pub fn basis_pool(&self) -> &BasisPool {
        &self.basis_pool
    }

    /// Live interned-basis count and resident floats across the whole
    /// population — the number the scale experiment/bench/tests compare
    /// against the naive `clients × basis` baseline.
    pub fn basis_pool_stats(&self) -> PoolStats {
        self.basis_pool.stats()
    }

    /// Execute one round through the staged engine; returns the round
    /// record. Bit-identical for every `cfg.workers` value (see [`engine`]).
    pub fn step(&mut self, round: usize) -> Result<RoundRecord> {
        let participants = self.sampler.sample(round);
        // Dropout: a dropped client never hears the broadcast and never
        // uploads. Pure per-(seed, round, cid) decision, so the surviving
        // set is identical at any worker count.
        let survivors = self.dropout.filter(round, &participants);
        let workers = self.cfg.resolved_workers();
        let tel = self.telemetry.clone();
        let t_round_start = self.vclock;
        if let Some(t) = tel.as_deref() {
            t.count("dispatches", survivors.len() as u64);
            t.count("dropouts", (participants.len() - survivors.len()) as u64);
        }
        if let Some(obs) = self.observer.as_mut() {
            obs.on_dispatch(&DispatchEvent {
                round,
                cids: &survivors,
                vtime: t_round_start,
                model_version: self.model_version,
            });
        }

        // Stage 1: broadcast — fetch the encoded global model (cached per
        // model version; encoded at most once across rounds that don't
        // apply), ship the frame (one shared allocation) to every survivor
        // through the transport, and charge the downlink from the buffers
        // that actually crossed it.
        let broadcast = self.broadcast_frame(self.model_version, round as u64);
        let broadcast_bytes = broadcast.len() as u64;
        for &cid in &survivors {
            self.transport.broadcast(cid, &broadcast)?;
        }
        let delivered = self.transport.drain_broadcasts();
        for (_, frame) in &delivered {
            self.ledger.charge_downlink(frame.len() as u64);
        }
        // Client side: every client received an identical frame, so decode
        // one copy and share it read-only across lanes (the f32 ↔ LE-bytes
        // round trip is bit-exact).
        let global_rx = match delivered.first() {
            Some((_, frame)) => wire::decode_params(&self.meta, frame)
                .context("decoding the model broadcast")?,
            None => self.global.clone(),
        };

        // Stage 2: client phase (local SGD → compress → encode), one lane
        // per survivor, fanned across workers when the backend allows.
        let inputs = engine::RoundInputs {
            global: &global_rx,
            local_epochs: self.cfg.local_epochs,
            batch_size: self.cfg.batch_size,
            lr: self.cfg.lr,
        };
        // Materialize any first-touch lanes (parallel, deterministic cid
        // order), then loan the survivors' lanes out to the engine. No
        // pinning needed here: the lockstep loop decodes every upload
        // within this same step, so nothing can evict a lane between its
        // dispatch and its decode.
        self.lanes
            .ensure_resident(&survivors, workers, tel.as_deref(), round as u64);
        let mut taken = self.lanes.take(&survivors);
        let outcomes = {
            let lane_refs: Vec<(usize, &mut Client)> =
                taken.iter_mut().map(|(cid, b)| (*cid, &mut **b)).collect();
            engine::run_client_phase(
                self.trainer.plan(workers),
                inputs,
                lane_refs,
                tel.as_deref(),
                round as u64,
            )
        };
        self.lanes.restore(taken);
        let outcomes = outcomes?;

        // Stage 3: upload every frame through the transport in participant
        // order; the uplink charge is whatever the server drains. Weights
        // are keyed by client id, not position, so a transport that ever
        // reorders frames cannot silently mis-weight the aggregate.
        let mut loss_sum = 0.0f64;
        let mut sum_d = 0u64;
        let mut weight_of: Vec<f64> = vec![0.0; self.lanes.len()];
        for outcome in outcomes {
            loss_sum += outcome.mean_loss;
            sum_d += outcome.stats.sum_d;
            weight_of[outcome.cid] = outcome.weight;
            self.transport.upload(outcome.cid, outcome.frame)?;
        }
        let uploads = self.transport.drain_uploads();
        debug_assert_eq!(
            uploads.iter().map(|(cid, _)| *cid).collect::<Vec<_>>(),
            survivors,
            "transport violated the FIFO drain contract"
        );
        let mut per_client_up: Vec<(usize, u64)> = Vec::with_capacity(uploads.len());
        for (cid, frame) in &uploads {
            self.ledger.charge_uplink(frame.len() as u64);
            per_client_up.push((*cid, frame.len() as u64));
        }
        // Straggler deadline: a client whose broadcast+upload transfer on
        // its own link exceeds the deadline arrives too late to enter the
        // aggregate. Its bytes are still charged (they crossed the wire)
        // and its frame is still decoded below (paired compressor state
        // must stay in lockstep) — it just doesn't contribute to FedAvg.
        let deadline = self.cfg.net.deadline();
        let on_time: Vec<bool> = per_client_up
            .iter()
            .map(|&(cid, up)| match deadline {
                Some(d) => self.network.link(cid).round_trip_time(broadcast_bytes, up) <= d,
                None => true,
            })
            .collect();
        // The round's virtual duration (used again at stage 6): the
        // slowest on-time transfer, capped at the deadline.
        let sim_time_s = self.network.round_time(&per_client_up, broadcast_bytes, deadline);
        if let Some(t) = tel.as_deref() {
            t.count("stragglers", on_time.iter().filter(|ot| !**ot).count() as u64);
            // Each survivor's transfer on the virtual clock, from the
            // round's dispatch instant to its individual arrival — capped
            // at the round close, because the lockstep loop re-dispatches
            // every client at the close regardless (no busy model): an
            // uncapped straggler transit would partially overlap the same
            // client's next-round span and break per-track nesting.
            // Semisync/async show the full transfer instead; they never
            // re-dispatch a client mid-flight.
            for &(cid, up) in &per_client_up {
                let rtt = self.network.link(cid).round_trip_time(broadcast_bytes, up);
                t.virt_span(
                    Phase::UplinkTransit,
                    round as u64,
                    Some(cid as u32),
                    t_round_start,
                    t_round_start + rtt.min(sim_time_s),
                );
            }
        }

        // Stage 4: server decode — every received frame (stragglers too:
        // paired compressor/decompressor state must advance in lockstep)
        // becomes structured LayerUpdates, fanned across workers per lane.
        let ids: Vec<usize> = uploads.iter().map(|(cid, _)| *cid).collect();
        let frames: Vec<Vec<u8>> = uploads.into_iter().map(|(_, f)| f).collect();
        let mut taken = self.lanes.take(&ids);
        let decoded = {
            let lane_refs: Vec<(usize, &mut Client)> =
                taken.iter_mut().map(|(cid, b)| (*cid, &mut **b)).collect();
            engine::run_server_phase(workers, lane_refs, frames, tel.as_deref(), round as u64)
        };
        self.lanes.restore(taken);
        let decoded = decoded?;

        // Streaming probes: every decoded upload (stragglers too, flagged
        // off-time with weight 0) reaches the observer before the fold —
        // the legacy dense round-hook adapter sees exactly the batch the
        // old hook did. Deliberate trade-off: a densifying observer makes
        // low-rank layers reconstruct twice (once in its view, once fused
        // into the fold) so the aggregate stays bit-identical whether or
        // not anything is observing the round.
        if let Some(obs) = self.observer.as_mut() {
            for ((cid, updates), ot) in decoded.iter().zip(&on_time) {
                obs.on_arrival(&ArrivalEvent {
                    round,
                    cid: *cid,
                    updates,
                    meta: &self.meta,
                    weight: if *ot { weight_of[*cid] } else { 0.0 },
                    staleness: 0,
                    vtime: t_round_start,
                    on_time: *ot,
                });
            }
        }

        // Stage 5: streaming compressed-domain aggregation — fold the
        // on-time clients' structured updates (participant order,
        // shard-size weights) into per-layer accumulators, parallel over
        // layers. Stragglers were decoded above but carry weight 0: they
        // simply don't enter the fold.
        let wtotal: f64 = decoded
            .iter()
            .zip(&on_time)
            .filter(|(_, ot)| **ot)
            .map(|((cid, _), _)| weight_of[*cid])
            .sum();

        // Stage 6: apply, evaluate, record. A round with no usable weight
        // (every survivor missed the deadline, or all on-time shards are
        // empty) skips the apply entirely instead of normalizing by 0 —
        // the old dense path would have produced NaN scales there and
        // poisoned the global model.
        let mut folded = 0usize;
        if wtotal > 0.0 {
            let folds: Vec<(f32, Vec<LayerUpdate>)> = decoded
                .into_iter()
                .zip(&on_time)
                .filter(|(_, ot)| **ot)
                .map(|((cid, updates), _)| ((weight_of[cid] / wtotal) as f32, updates))
                .collect();
            folded = folds.len();
            let sp = Telemetry::timer(tel.as_deref());
            let mut agg = ServerAggregator::with_backend(&self.meta, self.backend);
            agg.fold_batch(workers, folds);
            if let Some(sp) = sp {
                sp.end(Phase::Fold, round as u64, None);
            }
            let sp = Telemetry::timer(tel.as_deref());
            self.global.axpy(1.0, &agg.finish(&self.meta));
            if let Some(sp) = sp {
                sp.end(Phase::Apply, round as u64, None);
            }
            // The model changed: invalidate the broadcast memo's key.
            self.model_version += 1;
        }

        let sp = Telemetry::timer(tel.as_deref());
        let (test_loss, test_acc) = if round % self.cfg.eval_every == 0
            || round + 1 == self.cfg.rounds
        {
            self.trainer.evaluate(&self.global, &self.test_data)?
        } else {
            (f64::NAN, f64::NAN)
        };
        if let Some(sp) = sp {
            sp.end(Phase::Eval, round as u64, None);
        }

        let (up, down) = self.ledger.end_round();
        self.vclock += sim_time_s;
        if folded > 0 {
            if let Some(t) = tel.as_deref() {
                t.count("folds", folded as u64);
                t.count("applies", 1);
            }
            if let Some(obs) = self.observer.as_mut() {
                obs.on_apply(&ApplyEvent { round, vtime: self.vclock, folded, wtotal });
            }
        }
        let mut record = RoundRecord {
            round,
            train_loss: loss_sum / survivors.len().max(1) as f64,
            test_accuracy: test_acc,
            test_loss,
            uplink_bytes: up,
            downlink_bytes: down,
            sim_time_s,
            sim_clock_s: self.vclock,
            sum_d,
            survivors,
            ext: None,
        };
        self.telemetry_round_end(&mut record);
        self.recorder.push(record.clone());
        if let Some(obs) = self.observer.as_mut() {
            obs.on_round(round, &record);
        }
        Ok(record)
    }

    /// Run all configured rounds through the **legacy synchronous loop**
    /// and produce the summary report. Ignores `cfg.sched` — this is the
    /// reference the `SyncScheduler` equivalence tests compare against;
    /// use [`Simulation::run_scheduled`] to honor the configured
    /// scheduler.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with_progress(|_, _| {})
    }

    /// Like [`Simulation::run`] but invokes `progress(round, record)` after
    /// each round (CLI progress lines).
    pub fn run_with_progress(
        &mut self,
        mut progress: impl FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        for round in 0..self.cfg.rounds {
            let rec = self.step(round)?;
            progress(round, &rec);
        }
        Ok(self.finish_report())
    }

    /// Run under the scheduler configured in `cfg.sched`
    /// ([`crate::sched`]): sync reproduces [`Simulation::run`]
    /// bit-identically; semi-sync and async drive the same transport,
    /// lanes, and aggregation plane on their own virtual-clock control
    /// flow. Observers (and round hooks, via the adapter) fire under every
    /// scheduler — see [`crate::telemetry::Observer`] for the lifecycle.
    pub fn run_scheduled(&mut self) -> Result<RunReport> {
        self.run_scheduled_with_progress(|_, _| {})
    }

    /// Like [`Simulation::run_scheduled`] with a per-record progress
    /// callback.
    pub fn run_scheduled_with_progress(
        &mut self,
        mut progress: impl FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        let sched_cfg = self.cfg.sched.clone();
        let mut sched = crate::sched::build_scheduler(&sched_cfg);
        sched.run(self, &mut progress)
    }

    /// End-of-run summary at the configured threshold fraction (shared by
    /// every scheduler so reports are comparable across control flows).
    pub(crate) fn finish_report(&self) -> RunReport {
        let threshold = self.cfg.threshold_frac * self.recorder.best_accuracy();
        self.recorder.report(threshold)
    }
}

/// Verify a model kind is covered by the artifacts dir without building a
/// full simulation (used by the CLI for friendly errors).
pub fn check_artifacts(cfg: &ExperimentConfig) -> Result<()> {
    if !cfg.use_xla {
        return Ok(());
    }
    let rt = crate::runtime::Runtime::open(&cfg.artifacts_dir)?;
    let name = crate::config::experiment::model_name(cfg.model);
    if !rt.manifest().models.contains_key(name) {
        return Err(anyhow!(
            "artifacts at '{}' do not cover model '{name}' — run `make artifacts`",
            cfg.artifacts_dir
        ));
    }
    Ok(())
}

/// Convenience: model kinds whose datasets are vision-shaped.
pub fn is_vision(model: ModelKind) -> bool {
    !matches!(model, ModelKind::TinyTransformer)
}
