//! Row-major dense `f32` matrix.

use crate::util::rng::Pcg64;

/// Dense row-major matrix of `f32`.
///
/// Deliberately minimal: a shape-checked `Vec<f32>` with the accessors the
/// rest of the crate needs. Heavy operations live in sibling modules
/// ([`super::matmul`], [`super::qr`], [`super::svd`], [`super::rsvd`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec: {}x{} != {}", rows, cols, data.len());
        Mat { rows, cols, data }
    }

    /// Matrix with i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Backing slice (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable backing slice (row-major).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f32> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Overwrite column `j`.
    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Squared L2 norm of row `i`.
    pub fn row_norm_sq(&self, i: usize) -> f32 {
        self.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() as f32
    }

    /// `self - other`, shape-checked.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self -= other`.
    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// Keep the first `k` columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Keep the first `k` rows.
    pub fn take_rows(&self, k: usize) -> Mat {
        assert!(k <= self.rows);
        Mat::from_vec(k, self.cols, self.data[..k * self.cols].to_vec())
    }

    /// Max |entry| of `self - other` (for tests).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        m[(2, 3)] = 7.5;
        assert_eq!(m[(2, 3)], 7.5);
        assert_eq!(m.row(2)[3], 7.5);
        assert_eq!(m.col(3)[2], 7.5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(5);
        let m = Mat::randn(17, 43, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_values() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(0, 1)], 4.0);
        assert_eq!(t[(2, 0)], 3.0);
    }

    #[test]
    fn fro_norm_known() {
        let m = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn take_cols_rows() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let c = m.take_cols(2);
        assert_eq!(c.as_slice(), &[1., 2., 4., 5.]);
        let r = m.take_rows(1);
        assert_eq!(r.as_slice(), &[1., 2., 3.]);
    }

    #[test]
    fn sub_works() {
        let a = Mat::from_vec(1, 3, vec![5., 5., 5.]);
        let b = Mat::from_vec(1, 3, vec![1., 2., 3.]);
        assert_eq!(a.sub(&b).as_slice(), &[4., 3., 2.]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        let _ = Mat::from_vec(2, 2, vec![1.0; 3]);
    }
}
