//! Thin QR factorization and re-orthonormalization.
//!
//! * [`householder_qr`] — numerically robust thin QR via Householder
//!   reflections; used by the randomized-SVD range finder.
//! * [`mgs_orthonormalize`] — modified Gram–Schmidt pass used to repair
//!   float drift in the long-lived GradESTC basis matrix (DESIGN.md §5).
//!
//! Both run their panel primitives — reflector/projection dots and the
//! `dst += a·x` updates — through [`Backend::dot_f64`] and
//! [`Backend::axpy`] on a transposed working copy, so columns are
//! contiguous rows and the inner loops autovectorize. On the scalar
//! backend the per-element arithmetic sequence is identical to the
//! original strided loops (sequential f64 dots; `x - d·v ≡ x + (-d)·v`
//! exactly in IEEE), so results are bit-for-bit unchanged; the `_in`
//! variants take an explicit backend, the plain names use the process
//! default.

use super::{default_backend, matmul, Backend, Mat};

/// Thin QR on the process-default backend; see [`householder_qr_in`].
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    householder_qr_in(default_backend(), a)
}

/// Thin QR: returns `(Q, R)` with `Q: m×n` orthonormal columns and
/// `R: n×n` upper-triangular, for `A: m×n`, `m >= n`.
pub fn householder_qr_in(bk: &dyn Backend, a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "householder_qr expects tall matrix, got {m}x{n}");
    // Work on the transpose so each column of A is a contiguous row: the
    // reflector dot and update become flat slice kernels.
    let mut rt = a.transpose();
    // Householder vectors, stored per step.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);

    for j in 0..n {
        // v = R[j:, j]; compute the Householder reflector for this column.
        let mut v: Vec<f32> = rt.row(j)[j..].to_vec();
        let norm_x = bk.dot_f64(&v, &v).sqrt() as f32;
        if norm_x == 0.0 {
            // Zero column: skip (reflector = identity). Keep a unit vector
            // so Q stays well-defined.
            let mut e = vec![0.0; m - j];
            e[0] = 1.0;
            vs.push(e);
            continue;
        }
        let alpha = if v[0] >= 0.0 { -norm_x } else { norm_x };
        v[0] -= alpha;
        let vnorm = bk.dot_f64(&v, &v).sqrt() as f32;
        if vnorm > 0.0 {
            v.iter_mut().for_each(|x| *x /= vnorm);
        } else {
            v[0] = 1.0;
        }
        // Apply H = I - 2 v vᵀ to R[j:, j:], column by contiguous column.
        for col in j..n {
            let row = &mut rt.row_mut(col)[j..];
            let dot = 2.0 * bk.dot_f64(&v, row) as f32;
            bk.axpy(row, -dot, &v);
        }
        vs.push(v);
    }

    // Build thin Q by applying reflectors (in reverse) to the first n
    // columns of the identity — also column-contiguous via the transpose.
    let mut qt = Mat::zeros(n, m);
    for j in 0..n {
        qt[(j, j)] = 1.0;
    }
    for j in (0..n).rev() {
        let v = &vs[j];
        for col in 0..n {
            let row = &mut qt.row_mut(col)[j..];
            let dot = 2.0 * bk.dot_f64(v, row) as f32;
            bk.axpy(row, -dot, v);
        }
    }
    let q = qt.transpose();

    // Zero R's strictly-lower part and truncate to n×n.
    let mut r_out = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_out[(i, j)] = rt[(j, i)];
        }
    }
    (q, r_out)
}

/// MGS on the process-default backend; see [`mgs_orthonormalize_in`].
pub fn mgs_orthonormalize(a: &mut Mat, eps: f32) -> Vec<usize> {
    mgs_orthonormalize_in(default_backend(), a, eps)
}

/// Modified Gram–Schmidt: orthonormalize the columns of `a` in place.
///
/// Columns that become numerically zero (below `eps`) are replaced with
/// zeros and reported in the returned list — callers decide how to refill
/// them. Two MGS passes are performed ("twice is enough", Kahan/Parlett)
/// for stability.
pub fn mgs_orthonormalize_in(bk: &dyn Backend, a: &mut Mat, eps: f32) -> Vec<usize> {
    let n = a.cols();
    let mut degenerate = Vec::new();
    for _pass in 0..2 {
        for j in 0..n {
            let mut col_j = a.col(j);
            // Remove projections on previous columns.
            for p in 0..j {
                let col_p = a.col(p);
                let dot = bk.dot_f64(&col_p, &col_j) as f32;
                bk.axpy(&mut col_j, -dot, &col_p);
            }
            let norm = bk.dot_f64(&col_j, &col_j).sqrt() as f32;
            if norm < eps {
                col_j.iter_mut().for_each(|x| *x = 0.0);
                if _pass == 1 {
                    degenerate.push(j);
                }
            } else {
                col_j.iter_mut().for_each(|x| *x /= norm);
            }
            a.set_col(j, &col_j);
        }
    }
    degenerate
}

/// ‖QᵀQ − I‖∞ — orthonormality defect, used in tests and debug assertions.
pub fn ortho_defect(q: &Mat) -> f32 {
    let g = matmul(&q.transpose(), q);
    let n = g.rows();
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g[(i, j)] - target).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{BlockedBackend, ScalarBackend};
    use crate::util::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::seeded(1);
        for &(m, n) in &[(8, 8), (50, 10), (129, 31), (4, 1)] {
            let a = Mat::randn(m, n, &mut rng);
            let (q, r) = householder_qr(&a);
            let qr = matmul(&q, &r);
            assert!(qr.max_abs_diff(&a) < 1e-3, "({m},{n}) diff {}", qr.max_abs_diff(&a));
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(200, 40, &mut rng);
        let (q, _) = householder_qr(&a);
        assert!(ortho_defect(&q) < 1e-4);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::seeded(3);
        let a = Mat::randn(30, 12, &mut rng);
        let (_, r) = householder_qr(&a);
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // Two identical columns: QR must not produce NaNs.
        let mut rng = Pcg64::seeded(4);
        let mut a = Mat::randn(20, 3, &mut rng);
        let c0 = a.col(0);
        a.set_col(1, &c0);
        let (q, r) = householder_qr(&a);
        assert!(q.as_slice().iter().all(|x| x.is_finite()));
        assert!(r.as_slice().iter().all(|x| x.is_finite()));
        assert!(matmul(&q, &r).max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn qr_agrees_across_backends() {
        let mut rng = Pcg64::seeded(7);
        let a = Mat::randn(96, 24, &mut rng);
        let (qs, rs) = householder_qr_in(&ScalarBackend, &a);
        let (qb, rb) = householder_qr_in(&BlockedBackend, &a);
        assert!(qs.max_abs_diff(&qb) < 1e-4);
        assert!(rs.max_abs_diff(&rb) < 1e-3);
    }

    #[test]
    fn mgs_orthonormalizes() {
        let mut rng = Pcg64::seeded(5);
        let mut a = Mat::randn(64, 16, &mut rng);
        let degen = mgs_orthonormalize(&mut a, 1e-6);
        assert!(degen.is_empty());
        assert!(ortho_defect(&a) < 1e-4);
    }

    #[test]
    fn mgs_reports_degenerate_columns() {
        let mut rng = Pcg64::seeded(6);
        let mut a = Mat::randn(32, 4, &mut rng);
        let c0 = a.col(0);
        a.set_col(2, &c0); // duplicate -> degenerate after projection
        let degen = mgs_orthonormalize(&mut a, 1e-5);
        assert_eq!(degen, vec![2]);
    }
}
