//! Thin SVD via the Gram-matrix + symmetric Jacobi eigensolver route.
//!
//! The compressor only ever needs SVDs of *small* matrices (the `d×m`
//! projected sketch inside randomized SVD, `d ≤ k ≪ l,m`), so a dense
//! one-sided approach through the Gram matrix is both simple and fast:
//!
//! for `B: p×q` with `p <= q`:  `B Bᵀ = W Λ Wᵀ` (Jacobi), `σᵢ = √λᵢ`,
//! `U = W`, `Vᵀ = Σ⁻¹ Uᵀ B` (zero-σ rows replaced by zeros).
//!
//! Accuracy for the tiny Gram systems involved is well within the f32
//! tolerance the compressor needs (validated against the jnp oracle through
//! `python/tests/test_kernel.py` on identical inputs).

use super::{default_backend, matmul, Backend, Mat};

/// Thin SVD result: `a ≈ u · diag(s) · vt`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// Left singular vectors, `p×r` (columns orthonormal).
    pub u: Mat,
    /// Singular values, descending, length `r`.
    pub s: Vec<f32>,
    /// Right singular vectors transposed, `r×q` (rows orthonormal).
    pub vt: Mat,
}

/// Symmetric eigendecomposition by the cyclic Jacobi method.
///
/// `a` must be symmetric `n×n`. Returns `(eigenvalues, eigenvectors)` with
/// eigenvalues descending and eigenvectors as *columns* of the returned
/// matrix.
pub fn jacobi_eigh_symmetric(a: &Mat, max_sweeps: usize) -> (Vec<f32>, Mat) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "jacobi_eigh: matrix must be square");
    // Work in f64 for the iteration: Gram matrices square the condition
    // number, f32 sweeps stall before convergence.
    let mut m: Vec<f64> = a.as_slice().iter().map(|&x| x as f64).collect();
    let mut v: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |i: usize, j: usize| i * n + j;

    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[idx(i, j)] * m[idx(i, j)];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p,q of m.
                for i in 0..n {
                    let aip = m[idx(i, p)];
                    let aiq = m[idx(i, q)];
                    m[idx(i, p)] = c * aip - s * aiq;
                    m[idx(i, q)] = s * aip + c * aiq;
                }
                for j in 0..n {
                    let apj = m[idx(p, j)];
                    let aqj = m[idx(q, j)];
                    m[idx(p, j)] = c * apj - s * aqj;
                    m[idx(q, j)] = s * apj + c * aqj;
                }
                // Accumulate eigenvectors.
                for i in 0..n {
                    let vip = v[idx(i, p)];
                    let viq = v[idx(i, q)];
                    v[idx(i, p)] = c * vip - s * viq;
                    v[idx(i, q)] = s * vip + c * viq;
                }
            }
        }
    }

    // Extract, sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[idx(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let vals: Vec<f32> = pairs.iter().map(|&(l, _)| l as f32).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_j, &(_, old_j)) in pairs.iter().enumerate() {
        for i in 0..n {
            vecs[(i, new_j)] = v[idx(i, old_j)] as f32;
        }
    }
    (vals, vecs)
}

/// Thin SVD of an arbitrary `p×q` matrix, keeping at most `rank` components
/// (all if `rank == 0`). Intended for small/sketched matrices.
pub fn thin_svd(a: &Mat, rank: usize) -> Svd {
    thin_svd_in(default_backend(), a, rank)
}

/// [`thin_svd`] on an explicit [`Backend`]; the Gram product and the
/// `Σ⁻¹UᵀA` projection run through `bk` (the Jacobi sweeps are scalar f64
/// on every backend — they dominate neither flops nor tolerance).
pub fn thin_svd_in(bk: &dyn Backend, a: &Mat, rank: usize) -> Svd {
    let (p, q) = (a.rows(), a.cols());
    let r_full = p.min(q);
    let keep = if rank == 0 { r_full } else { rank.min(r_full) };

    if p <= q {
        // Gram on the small side: B Bᵀ (p×p).
        let g = bk.matmul_a_bt(a, a);
        let (vals, w) = jacobi_eigh_symmetric(&g, 30);
        let s: Vec<f32> = vals.iter().take(keep).map(|&l| l.max(0.0).sqrt()).collect();
        let u = w.take_cols(keep);
        // Vᵀ = Σ⁻¹ Uᵀ A, guarding σ≈0.
        let ut_a = bk.matmul_at_b(&u, a);
        let mut vt = ut_a;
        for (i, &si) in s.iter().enumerate() {
            let inv = if si > 1e-12 { 1.0 / si } else { 0.0 };
            for x in vt.row_mut(i) {
                *x *= inv;
            }
        }
        Svd { u, s, vt }
    } else {
        // Tall matrix: decompose the transpose and swap factors.
        let svd_t = thin_svd_in(bk, &a.transpose(), keep);
        Svd { u: svd_t.vt.transpose(), s: svd_t.s, vt: svd_t.u.transpose() }
    }
}

/// Principal angles between the column spans of two orthonormal bases
/// `a: l×k₁` and `b: l×k₂` (same `l`), ascending, in radians.
///
/// Standard Björck–Golub small-`k` route: the singular values of
/// `C = AᵀB` (a `k₁×k₂` product through `bk`) are the cosines of the
/// principal angles, clamped into `[0, 1]` before `acos` so f32
/// round-off near a shared direction cannot produce NaN. The angle
/// vector has `min(k₁, k₂)` entries in `[0, π/2]`: identical spans give
/// all-zero angles, orthogonal spans give all-`π/2`. Cost is one small
/// matmul plus a `k×k` Jacobi SVD — this is the diagnostics plane's
/// subspace-drift primitive, not a hot-path kernel.
pub fn principal_angles_in(bk: &dyn Backend, a: &Mat, b: &Mat) -> Vec<f64> {
    assert_eq!(a.rows(), b.rows(), "principal_angles: bases live in different spaces");
    if a.cols() == 0 || b.cols() == 0 {
        return Vec::new();
    }
    let c = bk.matmul_at_b(a, b);
    let svd = thin_svd_in(bk, &c, 0);
    svd.s.iter().map(|&s| (s as f64).clamp(0.0, 1.0).acos()).collect()
}

/// Chordal (projection-Frobenius) distance from a principal-angle vector:
/// `sqrt(Σ sin²θᵢ)` — 0 for identical spans, `sqrt(k)` for orthogonal
/// `k`-dimensional ones.
pub fn chordal_distance(angles: &[f64]) -> f64 {
    angles.iter().map(|t| t.sin() * t.sin()).sum::<f64>().sqrt()
}

impl Svd {
    /// Reconstruct `u · diag(s) · vt`.
    pub fn reconstruct(&self) -> Mat {
        let mut us = self.u.clone();
        for j in 0..self.s.len() {
            for i in 0..us.rows() {
                us[(i, j)] *= self.s[j];
            }
        }
        matmul(&us, &self.vt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_a_bt;
    use crate::linalg::qr::ortho_defect;
    use crate::util::rng::Pcg64;

    #[test]
    fn eigh_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 5.0;
        a[(2, 2)] = 3.0;
        let (vals, _) = jacobi_eigh_symmetric(&a, 20);
        assert!((vals[0] - 5.0).abs() < 1e-5);
        assert!((vals[1] - 3.0).abs() < 1e-5);
        assert!((vals[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Pcg64::seeded(1);
        let b = Mat::randn(10, 10, &mut rng);
        let a = matmul_a_bt(&b, &b); // symmetric PSD
        let (vals, w) = jacobi_eigh_symmetric(&a, 30);
        // A = W Λ Wᵀ
        let mut wl = w.clone();
        for j in 0..10 {
            for i in 0..10 {
                wl[(i, j)] *= vals[j];
            }
        }
        let rec = matmul(&wl, &w.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-2 * a.fro_norm());
    }

    #[test]
    fn svd_reconstructs_full_rank() {
        let mut rng = Pcg64::seeded(2);
        for &(p, q) in &[(6, 9), (9, 6), (12, 12), (1, 5), (5, 1)] {
            let a = Mat::randn(p, q, &mut rng);
            let svd = thin_svd(&a, 0);
            let rec = svd.reconstruct();
            assert!(
                rec.max_abs_diff(&a) < 1e-2,
                "({p},{q}): diff {}",
                rec.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn svd_factors_orthonormal() {
        let mut rng = Pcg64::seeded(3);
        let a = Mat::randn(8, 20, &mut rng);
        let svd = thin_svd(&a, 0);
        assert!(ortho_defect(&svd.u) < 1e-3);
        assert!(ortho_defect(&svd.vt.transpose()) < 1e-3);
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Pcg64::seeded(4);
        let a = Mat::randn(16, 10, &mut rng);
        let svd = thin_svd(&a, 0);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn truncated_svd_is_best_low_rank() {
        // Build a matrix with known rank-2 dominant structure; rank-2 SVD
        // must capture almost all its energy.
        let mut rng = Pcg64::seeded(5);
        let u = Mat::randn(30, 2, &mut rng);
        let v = Mat::randn(2, 40, &mut rng);
        let mut a = matmul(&u, &v);
        let noise = Mat::randn(30, 40, &mut rng);
        for (x, n) in a.as_mut_slice().iter_mut().zip(noise.as_slice()) {
            *x += 0.01 * n;
        }
        let svd = thin_svd(&a, 2);
        let rec = svd.reconstruct();
        let err = rec.sub(&a).fro_norm() / a.fro_norm();
        assert!(err < 0.05, "relative err {err}");
    }

    #[test]
    fn svd_of_zero_matrix() {
        let a = Mat::zeros(5, 7);
        let svd = thin_svd(&a, 3);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(svd.reconstruct().fro_norm() == 0.0);
    }

    #[test]
    fn principal_angles_identical_basis_are_zero() {
        let mut rng = Pcg64::seeded(6);
        let a = Mat::randn(24, 4, &mut rng);
        let q = crate::linalg::mgs_orthonormalize(&a);
        let angles = principal_angles_in(default_backend(), &q, &q);
        assert_eq!(angles.len(), 4);
        for t in &angles {
            assert!(t.abs() < 1e-3, "identical basis angle {t}");
        }
        assert!(chordal_distance(&angles) < 1e-3);
    }

    #[test]
    fn principal_angles_orthogonal_bases_are_right_angles() {
        // Disjoint coordinate subspaces: span{e0,e1} vs span{e2,e3}.
        let mut a = Mat::zeros(8, 2);
        let mut b = Mat::zeros(8, 2);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        b[(2, 0)] = 1.0;
        b[(3, 1)] = 1.0;
        let angles = principal_angles_in(default_backend(), &a, &b);
        assert_eq!(angles.len(), 2);
        let half_pi = std::f64::consts::FRAC_PI_2;
        for t in &angles {
            assert!((t - half_pi).abs() < 1e-5, "orthogonal basis angle {t}");
        }
        assert!((chordal_distance(&angles) - 2f64.sqrt()).abs() < 1e-5);
    }

    #[test]
    fn principal_angles_are_bounded_and_rotation_invariant() {
        let mut rng = Pcg64::seeded(7);
        let q1 = crate::linalg::mgs_orthonormalize(&Mat::randn(30, 5, &mut rng));
        let q2 = crate::linalg::mgs_orthonormalize(&Mat::randn(30, 5, &mut rng));
        let angles = principal_angles_in(default_backend(), &q1, &q2);
        assert_eq!(angles.len(), 5);
        let half_pi = std::f64::consts::FRAC_PI_2;
        for t in &angles {
            assert!(*t >= 0.0 && *t <= half_pi + 1e-9, "angle out of range: {t}");
        }
        // Angles measure the spans, not the particular orthonormal
        // representatives: a column permutation leaves them unchanged.
        let mut perm = Mat::zeros(30, 5);
        for j in 0..5 {
            for i in 0..30 {
                perm[(i, j)] = q2[(i, (j + 2) % 5)];
            }
        }
        let angles_p = principal_angles_in(default_backend(), &q1, &perm);
        for (x, y) in angles.iter().zip(&angles_p) {
            assert!((x - y).abs() < 1e-4, "permutation moved angle {x} -> {y}");
        }
    }
}
