//! The pluggable compute-backend plane.
//!
//! Every hot linalg kernel the compressor and the server aggregation
//! plane touch — the projection `A = MᵀG` ([`Backend::matmul_at_b`]), the
//! fused reconstruct-and-fold `C += α·M·A` ([`Backend::matmul_acc`]), the
//! rSVD/QR panel primitives — dispatches through the [`Backend`] trait so
//! a new compute substrate (GPU, Vulkan, PJRT) is a new `impl`, not a new
//! plumbing pass. Two CPU implementations ship today:
//!
//! * [`ScalarBackend`] — exactly the original loops in
//!   `linalg/matmul.rs`, frozen as the bit-identity reference. Its
//!   `matmul_at_b` keeps the historical k-chunked parallel reduction
//!   whose chunk count comes from the process-wide worker default — a
//!   reduction order that is constant *within* a process but not a pure
//!   function of problem shape.
//! * [`BlockedBackend`] — cache-blocked, SIMD-friendly register-tiled
//!   micro-kernels (`MR`×`NR` output tiles, autovectorizable chunked
//!   inner loops, no `unsafe`, no intrinsics). The default.
//!
//! # Determinism contract
//!
//! A backend's reduction order must be a **pure function of problem
//! shape** — never of worker count, thread identity, or scheduling. The
//! blocked kernels honor this by parallelizing only over disjoint output
//! rows: each output element is accumulated by exactly one thread in a
//! fixed ascending-`k` order, so any row partition produces bit-identical
//! results and the engine-wide w1-vs-wN determinism tests hold on every
//! backend. (`ScalarBackend::matmul_at_b` predates the contract; its
//! chunk-order reduction is process-constant, which is all those tests
//! need, and it is kept verbatim as the frozen reference.)
//!
//! Where the blocked kernels preserve the scalar per-element operation
//! sequence (`matmul_acc`, and `matmul` up to the scalar zero-skip
//! branch) results are bit-identical across backends; elsewhere
//! (`matmul_a_bt`, `dot*`: fixed-lane partial sums) they agree to ≤1e-5
//! relative error — `rust/tests/backend.rs` locks both regimes in over
//! ragged shapes.
//!
//! # Selection
//!
//! [`BackendKind`] rides in `ExperimentConfig::backend` (JSON `"backend"`,
//! absent ⇒ `auto`) and on the CLI as
//! `gradestc train --backend auto|scalar|blocked`. `auto` resolves to the
//! `GRADESTC_BACKEND` environment variable if set, else
//! [`BlockedBackend`]; the resolved handle is a `&'static dyn Backend`
//! threaded through the compressors, `randomized_svd`/QR, and the
//! [`ServerAggregator`](crate::coordinator::ServerAggregator). The free
//! functions `linalg::{matmul, matmul_acc, matmul_at_b, matmul_a_bt}`
//! dispatch through [`default_backend`] so callers outside the threaded
//! planes (the native trainer's conv/dense ops) get the fast kernels too.
//!
//! # Adding a backend
//!
//! Implement [`Backend`] (the four matmul variants plus the `axpy`/`dot`
//! panel hooks), keep the reduction-order contract above, add a
//! [`BackendKind`] variant + `parse`/`name` arm, and extend the
//! scalar-vs-new tolerance sweep in `rust/tests/backend.rs`. The XLA
//! runtime stub (`crate::runtime`, `--features xla`) is subsumed behind
//! the same seam: [`XlaBackend`] exists under the feature flag and
//! currently delegates kernels to the blocked CPU path until device
//! buffers are wired through PJRT.

use std::sync::OnceLock;

use super::matmul::{axpy, parallel_rows, scalar_matmul, scalar_matmul_a_bt, scalar_matmul_acc,
    scalar_matmul_at_b};
use super::Mat;

/// One compute substrate for the dense-linalg hot path. All methods must
/// keep the reduction-order determinism contract (module docs): results
/// may depend on the problem, never on the worker count.
pub trait Backend: Send + Sync {
    /// Stable short name (matches [`BackendKind::name`]).
    fn name(&self) -> &'static str;

    /// `C = A·B` (shapes `(m,k)·(k,n) -> (m,n)`).
    fn matmul(&self, a: &Mat, b: &Mat) -> Mat;

    /// `C += α·A·B` in place — the fused low-rank reconstruct-and-fold
    /// kernel of the server aggregation plane (paper Eq. 14 shapes).
    /// Single-threaded by contract: callers parallelize over disjoint
    /// per-layer accumulators.
    fn matmul_acc(&self, c: &mut Mat, alpha: f32, a: &Mat, b: &Mat);

    /// `C = Aᵀ·B` (shapes `(k,m)ᵀ·(k,n) -> (m,n)`) — the compressor's
    /// projection `A = MᵀG`.
    fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat;

    /// `C = A·Bᵀ` (shapes `(m,k)·(n,k)ᵀ -> (m,n)`) — Gram matrices for
    /// the small eigensolve.
    fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat;

    /// `dst += a·x`, the panel update primitive (QR reflector and MGS
    /// projection removal). Element-wise, so every backend shares the one
    /// implementation and results are bit-identical across backends.
    fn axpy(&self, dst: &mut [f32], a: f32, x: &[f32]) {
        axpy(dst, a, x);
    }

    /// Single-precision dot product.
    fn dot(&self, x: &[f32], y: &[f32]) -> f32;

    /// Double-precision-accumulated dot product — the panel hook QR and
    /// MGS use for reflector norms and projection coefficients.
    fn dot_f64(&self, x: &[f32], y: &[f32]) -> f64;
}

// ---------------------------------------------------------------------------
// ScalarBackend — the frozen reference
// ---------------------------------------------------------------------------

/// The original scalar kernels, verbatim (`linalg/matmul.rs`): the frozen
/// bit-identity reference every other backend is tested against.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarBackend;

impl Backend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn matmul(&self, a: &Mat, b: &Mat) -> Mat {
        scalar_matmul(a, b)
    }

    fn matmul_acc(&self, c: &mut Mat, alpha: f32, a: &Mat, b: &Mat) {
        scalar_matmul_acc(c, alpha, a, b);
    }

    fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat {
        scalar_matmul_at_b(a, b)
    }

    fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat {
        scalar_matmul_a_bt(a, b)
    }

    fn dot(&self, x: &[f32], y: &[f32]) -> f32 {
        let mut s = 0.0f32;
        for (&xv, &yv) in x.iter().zip(y) {
            s += xv * yv;
        }
        s
    }

    fn dot_f64(&self, x: &[f32], y: &[f32]) -> f64 {
        // Sequential f64 accumulation — exactly the historical QR/MGS
        // inner loops, so the scalar backend reproduces their results
        // bit-for-bit.
        let mut s = 0.0f64;
        for (&xv, &yv) in x.iter().zip(y) {
            s += xv as f64 * yv as f64;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// BlockedBackend — register-tiled CPU kernels
// ---------------------------------------------------------------------------

/// Output-tile height of the register micro-kernel.
const MR: usize = 4;
/// Output-tile width of the register micro-kernel (one f32 cache line).
const NR: usize = 16;

/// Accumulate `c_panel[r0..r1 rows] += α·A·B` with an `MR`×`NR` register
/// tile: each output tile is loaded once, accumulated over the *entire*
/// ascending-`k` range, and stored once — versus the scalar axpy kernel's
/// full C-row traffic per `k`. Each element's operation sequence
/// (`acc += (α·a[i,k])·b[k,j]`, `k` ascending, one rounding per step) is
/// identical to the scalar `matmul_acc` path, so this kernel is bit-exact
/// against it at any row partition.
fn blocked_panel(a: &Mat, b: &Mat, alpha: f32, r0: usize, r1: usize, c_panel: &mut [f32]) {
    let n = b.cols();
    let kk = a.cols();
    let bs = b.as_slice();
    let mut i = r0;
    while i < r1 {
        let i1 = (i + MR).min(r1);
        let h = i1 - i;
        let mut arows: [&[f32]; MR] = [&[]; MR];
        for (r, row) in arows.iter_mut().enumerate().take(h) {
            *row = a.row(i + r);
        }
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + NR).min(n);
            let w = j1 - j0;
            let mut acc = [[0.0f32; NR]; MR];
            for r in 0..h {
                let off = (i + r - r0) * n + j0;
                acc[r][..w].copy_from_slice(&c_panel[off..off + w]);
            }
            for k in 0..kk {
                let brow = &bs[k * n + j0..k * n + j1];
                for r in 0..h {
                    let s = alpha * arows[r][k];
                    for (av, &bv) in acc[r][..w].iter_mut().zip(brow) {
                        *av += s * bv;
                    }
                }
            }
            for r in 0..h {
                let off = (i + r - r0) * n + j0;
                c_panel[off..off + w].copy_from_slice(&acc[r][..w]);
            }
            j0 = j1;
        }
        i = i1;
    }
}

/// 8-lane f32 dot product with a fixed-shape lane-combine tree. The
/// partial-sum split depends only on the vector length, never on any
/// worker count, so results are deterministic per shape.
fn dot8(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let head = n / 8 * 8;
    let mut lanes = [0.0f32; 8];
    for (x8, y8) in x[..head].chunks_exact(8).zip(y[..head].chunks_exact(8)) {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x8[l] * y8[l];
        }
    }
    let mut s = ((lanes[0] + lanes[4]) + (lanes[2] + lanes[6]))
        + ((lanes[1] + lanes[5]) + (lanes[3] + lanes[7]));
    for (&xv, &yv) in x[head..].iter().zip(&y[head..]) {
        s += xv * yv;
    }
    s
}

/// 4-lane f64-accumulated dot product, fixed combine order (shape-pure).
fn dot4_f64(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let head = n / 4 * 4;
    let mut lanes = [0.0f64; 4];
    for (x4, y4) in x[..head].chunks_exact(4).zip(y[..head].chunks_exact(4)) {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x4[l] as f64 * y4[l] as f64;
        }
    }
    let mut s = (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]);
    for (&xv, &yv) in x[head..].iter().zip(&y[head..]) {
        s += xv as f64 * yv as f64;
    }
    s
}

/// Cache-blocked, register-tiled CPU backend: the default. See the module
/// docs for the determinism contract and the numerics relationship to
/// [`ScalarBackend`].
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockedBackend;

impl BlockedBackend {
    /// Shared `C = α·A·B` driver: row-parallel over register-tiled
    /// panels. Values are independent of the row partition (each output
    /// element is produced entirely by one thread in fixed `k` order).
    fn mm(&self, a: &Mat, b: &Mat) -> Mat {
        let (m, n) = (a.rows(), b.cols());
        let flops = 2 * m * n * a.cols();
        let out =
            parallel_rows(m, flops, |r0, r1, panel| blocked_panel(a, b, 1.0, r0, r1, panel), n);
        Mat::from_vec(m, n, out)
    }
}

impl Backend for BlockedBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn matmul(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(
            a.cols(),
            b.rows(),
            "matmul: {}x{} · {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        );
        self.mm(a, b)
    }

    fn matmul_acc(&self, c: &mut Mat, alpha: f32, a: &Mat, b: &Mat) {
        assert_eq!(
            a.cols(),
            b.rows(),
            "matmul_acc: {}x{} · {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        );
        assert_eq!(
            (c.rows(), c.cols()),
            (a.rows(), b.cols()),
            "matmul_acc: accumulator is {}x{}, product is {}x{}",
            c.rows(),
            c.cols(),
            a.rows(),
            b.cols()
        );
        let m = a.rows();
        blocked_panel(a, b, alpha, 0, m, c.as_mut_slice());
    }

    fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(
            a.rows(),
            b.rows(),
            "matmul_at_b: {}x{} ᵀ· {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        );
        // Materialize Aᵀ (32-blocked transpose, cheap next to the product)
        // and reuse the row-parallel tiled kernel: the reduction is then a
        // pure ascending-k per-element order regardless of worker count —
        // unlike the scalar path's k-chunked partial accumulators.
        let at = a.transpose();
        self.mm(&at, b)
    }

    fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(
            a.cols(),
            b.cols(),
            "matmul_a_bt: {}x{} · {}x{}ᵀ",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        );
        let (m, n) = (a.rows(), b.rows());
        let flops = 2 * m * n * a.cols();
        let out = parallel_rows(
            m,
            flops,
            |r0, r1, panel| {
                for (pi, i) in (r0..r1).enumerate() {
                    let arow = a.row(i);
                    for j in 0..n {
                        panel[pi * n + j] = dot8(arow, b.row(j));
                    }
                }
            },
            n,
        );
        Mat::from_vec(m, n, out)
    }

    fn dot(&self, x: &[f32], y: &[f32]) -> f32 {
        dot8(x, y)
    }

    fn dot_f64(&self, x: &[f32], y: &[f32]) -> f64 {
        dot4_f64(x, y)
    }
}

// ---------------------------------------------------------------------------
// XlaBackend — feature-gated device seam
// ---------------------------------------------------------------------------

/// Device-backend seam for the `xla` feature: the PJRT runtime
/// (`crate::runtime`) owns training executables, and this impl is where
/// its buffers will plug into the linalg plane. Until device transfers
/// are wired, kernels delegate to the blocked CPU path so an `xla` build
/// is functional end to end.
#[cfg(feature = "xla")]
#[derive(Clone, Copy, Debug, Default)]
pub struct XlaBackend;

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn matmul(&self, a: &Mat, b: &Mat) -> Mat {
        BlockedBackend.matmul(a, b)
    }

    fn matmul_acc(&self, c: &mut Mat, alpha: f32, a: &Mat, b: &Mat) {
        BlockedBackend.matmul_acc(c, alpha, a, b);
    }

    fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat {
        BlockedBackend.matmul_at_b(a, b)
    }

    fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat {
        BlockedBackend.matmul_a_bt(a, b)
    }

    fn dot(&self, x: &[f32], y: &[f32]) -> f32 {
        BlockedBackend.dot(x, y)
    }

    fn dot_f64(&self, x: &[f32], y: &[f32]) -> f64 {
        BlockedBackend.dot_f64(x, y)
    }
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

static SCALAR: ScalarBackend = ScalarBackend;
static BLOCKED: BlockedBackend = BlockedBackend;
#[cfg(feature = "xla")]
static XLA: XlaBackend = XlaBackend;

/// Experiment-facing backend selector (`ExperimentConfig::backend`, the
/// `"backend"` JSON string, and the `--backend` CLI flag).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// `GRADESTC_BACKEND` if set, else [`BlockedBackend`]. The default,
    /// and what an absent JSON field parses as.
    #[default]
    Auto,
    /// The frozen scalar reference.
    Scalar,
    /// The register-tiled CPU kernels.
    Blocked,
    /// The feature-gated device seam (delegates to blocked on the host).
    #[cfg(feature = "xla")]
    Xla,
}

impl BackendKind {
    /// Parse a CLI/JSON spec: `auto`, `scalar`, `blocked` (and `xla`
    /// under the feature flag).
    pub fn parse(spec: &str) -> std::result::Result<BackendKind, String> {
        match spec {
            "auto" => Ok(BackendKind::Auto),
            "scalar" => Ok(BackendKind::Scalar),
            "blocked" => Ok(BackendKind::Blocked),
            #[cfg(feature = "xla")]
            "xla" => Ok(BackendKind::Xla),
            #[cfg(not(feature = "xla"))]
            "xla" => Err(
                "backend 'xla' requires building with --features xla \
                 (see rust/Cargo.toml); use auto | scalar | blocked"
                    .into(),
            ),
            other => Err(format!("unknown backend '{other}' (auto | scalar | blocked)")),
        }
    }

    /// Stable short name for logs/JSON round trips.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Scalar => "scalar",
            BackendKind::Blocked => "blocked",
            #[cfg(feature = "xla")]
            BackendKind::Xla => "xla",
        }
    }

    /// Resolve to a backend handle. `Auto` defers to [`default_backend`].
    pub fn resolve(&self) -> &'static dyn Backend {
        match self {
            BackendKind::Auto => default_backend(),
            BackendKind::Scalar => &SCALAR,
            BackendKind::Blocked => &BLOCKED,
            #[cfg(feature = "xla")]
            BackendKind::Xla => &XLA,
        }
    }
}

/// The process-wide default backend: `GRADESTC_BACKEND` (`scalar` |
/// `blocked`, panicking on garbage — a typo must not silently change an
/// experiment's numerics) if set, else [`BlockedBackend`]. Resolved once
/// and cached; the free `linalg::matmul*` functions and every
/// `*_in`-less constructor dispatch through it.
pub fn default_backend() -> &'static dyn Backend {
    static DEFAULT: OnceLock<&'static dyn Backend> = OnceLock::new();
    *DEFAULT.get_or_init(|| match std::env::var("GRADESTC_BACKEND") {
        Ok(spec) => match BackendKind::parse(&spec) {
            Ok(BackendKind::Auto) => &BLOCKED,
            Ok(kind) => kind.resolve(),
            Err(e) => panic!("GRADESTC_BACKEND: {e}"),
        },
        Err(_) => &BLOCKED,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rel_close(a: &Mat, b: &Mat, tol: f32) -> bool {
        let scale = b.fro_norm().max(1.0);
        a.max_abs_diff(b) <= tol * scale
    }

    #[test]
    fn kind_parses_and_names_roundtrip() {
        for kind in [BackendKind::Auto, BackendKind::Scalar, BackendKind::Blocked] {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(BackendKind::parse("simd").is_err());
        #[cfg(not(feature = "xla"))]
        assert!(BackendKind::parse("xla").unwrap_err().contains("features xla"));
    }

    #[test]
    fn resolve_names_match() {
        assert_eq!(BackendKind::Scalar.resolve().name(), "scalar");
        assert_eq!(BackendKind::Blocked.resolve().name(), "blocked");
    }

    #[test]
    fn blocked_matmul_acc_is_bit_exact_vs_scalar() {
        // Same per-element operation sequence ⇒ bitwise equality, the
        // strong half of the cross-backend contract.
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[(13, 7, 19), (64, 32, 48), (5, 1, 3), (33, 17, 31)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let mut cs = Mat::randn(m, n, &mut rng);
            let mut cb = cs.clone();
            ScalarBackend.matmul_acc(&mut cs, 0.37, &a, &b);
            BlockedBackend.matmul_acc(&mut cb, 0.37, &a, &b);
            assert_eq!(cs.as_slice(), cb.as_slice(), "({m},{k},{n})");
        }
    }

    #[test]
    fn blocked_matmul_agrees_with_scalar() {
        let mut rng = Pcg64::seeded(12);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 23, 9), (70, 40, 50)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let cs = ScalarBackend.matmul(&a, &b);
            let cb = BlockedBackend.matmul(&a, &b);
            assert!(rel_close(&cb, &cs, 1e-5), "({m},{k},{n})");
        }
    }

    #[test]
    fn blocked_at_b_and_a_bt_agree_with_scalar() {
        let mut rng = Pcg64::seeded(13);
        let a = Mat::randn(96, 24, &mut rng);
        let b = Mat::randn(96, 40, &mut rng);
        assert!(rel_close(
            &BlockedBackend.matmul_at_b(&a, &b),
            &ScalarBackend.matmul_at_b(&a, &b),
            1e-5
        ));
        let c = Mat::randn(20, 64, &mut rng);
        let d = Mat::randn(30, 64, &mut rng);
        assert!(rel_close(
            &BlockedBackend.matmul_a_bt(&c, &d),
            &ScalarBackend.matmul_a_bt(&c, &d),
            1e-5
        ));
    }

    #[test]
    fn dots_agree_across_backends() {
        let mut rng = Pcg64::seeded(14);
        for n in [0usize, 1, 3, 8, 9, 31, 257] {
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let ds = ScalarBackend.dot_f64(&x, &y);
            let db = BlockedBackend.dot_f64(&x, &y);
            assert!((ds - db).abs() <= 1e-6 * ds.abs().max(1.0), "n={n}");
            let fs = ScalarBackend.dot(&x, &y);
            let fb = BlockedBackend.dot(&x, &y);
            assert!((fs - fb).abs() <= 1e-4 * fs.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn default_backend_is_blocked_unless_env_overrides() {
        // The test process may legitimately run with GRADESTC_BACKEND
        // set; assert consistency with the environment either way.
        let expect = match std::env::var("GRADESTC_BACKEND") {
            Ok(s) if s != "auto" => s,
            _ => "blocked".to_string(),
        };
        assert_eq!(default_backend().name(), expect);
    }
}
