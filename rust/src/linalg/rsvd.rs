//! Randomized SVD (Halko, Martinsson & Tropp 2011, Algorithms 4.4 + 5.1).
//!
//! The paper relies on randomized SVD to find the top-`d` directions of the
//! fitting-error matrix cheaply (§III-B(c), complexity discussion §III-C).
//! Pipeline: Gaussian sketch `Y = A Ω`, optional power iterations with QR
//! re-orthonormalization, thin QR range `Q`, project `B = Qᵀ A`, small SVD
//! of `B`, then `U = Q·U_B`.
//!
//! The sketch/projection matmuls are exactly the Pallas `rangefinder`
//! kernels at L1; this Rust implementation is the request-path twin and is
//! cross-checked against the jnp oracle in integration tests.

use super::{default_backend, householder_qr_in, thin_svd_in, Backend, Mat, Svd};
use crate::util::rng::Pcg64;

/// Options for [`randomized_svd`].
#[derive(Clone, Copy, Debug)]
pub struct RsvdOptions {
    /// Oversampling columns added to the sketch (Halko recommends 5–10).
    pub oversample: usize,
    /// Power iterations (0–2; each sharpens the spectrum at one extra pass
    /// over the data).
    pub power_iters: usize,
}

impl Default for RsvdOptions {
    fn default() -> Self {
        RsvdOptions { oversample: 6, power_iters: 1 }
    }
}

/// Rank-`rank` randomized SVD of `a`.
///
/// Returns factors truncated to `rank` (or `min(p,q)` if smaller). The RNG
/// drives the Gaussian test matrix, making results deterministic per seed.
pub fn randomized_svd(a: &Mat, rank: usize, opts: RsvdOptions, rng: &mut Pcg64) -> Svd {
    randomized_svd_in(default_backend(), a, rank, opts, rng)
}

/// [`randomized_svd`] on an explicit [`Backend`]; all matmuls, QR panels
/// and the small SVD run through `bk`.
pub fn randomized_svd_in(
    bk: &dyn Backend,
    a: &Mat,
    rank: usize,
    opts: RsvdOptions,
    rng: &mut Pcg64,
) -> Svd {
    let (p, q) = (a.rows(), a.cols());
    let r_full = p.min(q);
    let rank = rank.min(r_full).max(1);
    let sketch = (rank + opts.oversample).min(r_full);

    if sketch >= r_full || r_full <= 8 {
        // Sketching can't beat the exact small SVD here.
        return truncate(thin_svd_in(bk, a, rank), rank);
    }

    // Y = A Ω, Ω: q×sketch Gaussian.
    let omega = Mat::randn(q, sketch, rng);
    let mut y = bk.matmul(a, &omega);

    // Power iterations with QR stabilization: Y <- A (Aᵀ Y_q).
    let at = a.transpose();
    for _ in 0..opts.power_iters {
        let (qy, _) = householder_qr_in(bk, &y);
        let z = bk.matmul(&at, &qy);
        let (qz, _) = householder_qr_in(bk, &z);
        y = bk.matmul(a, &qz);
    }

    let (q_range, _) = householder_qr_in(bk, &y);
    // B = Qᵀ A (sketch×q), small.
    let b = bk.matmul(&q_range.transpose(), a);
    let svd_b = thin_svd_in(bk, &b, rank);
    let u = bk.matmul(&q_range, &svd_b.u);
    truncate(Svd { u, s: svd_b.s, vt: svd_b.vt }, rank)
}

fn truncate(svd: Svd, rank: usize) -> Svd {
    if svd.s.len() <= rank {
        return svd;
    }
    Svd {
        u: svd.u.take_cols(rank),
        s: svd.s[..rank].to_vec(),
        vt: svd.vt.take_rows(rank),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::linalg::{matmul, thin_svd};

    /// Low-rank + noise test matrix.
    fn low_rank(p: usize, q: usize, r: usize, noise: f32, rng: &mut Pcg64) -> Mat {
        let u = Mat::randn(p, r, rng);
        let v = Mat::randn(r, q, rng);
        let mut a = matmul(&u, &v);
        let n = Mat::randn(p, q, rng);
        for (x, nv) in a.as_mut_slice().iter_mut().zip(n.as_slice()) {
            *x += noise * nv;
        }
        a
    }

    #[test]
    fn recovers_low_rank_structure() {
        let mut rng = Pcg64::seeded(1);
        let a = low_rank(120, 80, 5, 0.01, &mut rng);
        let svd = randomized_svd(&a, 5, RsvdOptions::default(), &mut rng);
        let rec = svd.reconstruct();
        let rel = rec.sub(&a).fro_norm() / a.fro_norm();
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn factors_orthonormal() {
        let mut rng = Pcg64::seeded(2);
        let a = low_rank(100, 60, 8, 0.05, &mut rng);
        let svd = randomized_svd(&a, 8, RsvdOptions::default(), &mut rng);
        assert!(ortho_defect(&svd.u) < 1e-3);
        assert_eq!(svd.u.cols(), 8);
        assert_eq!(svd.s.len(), 8);
        assert_eq!(svd.vt.rows(), 8);
    }

    #[test]
    fn matches_exact_svd_energy() {
        // Captured energy of rank-k rSVD should be close to exact rank-k SVD.
        let mut rng = Pcg64::seeded(3);
        let a = low_rank(90, 70, 10, 0.1, &mut rng);
        let k = 10;
        let exact = thin_svd(&a, k);
        let approx = randomized_svd(&a, k, RsvdOptions { oversample: 8, power_iters: 2 }, &mut rng);
        let e_exact: f32 = exact.s.iter().map(|s| s * s).sum();
        let e_approx: f32 = approx.s.iter().map(|s| s * s).sum();
        assert!(e_approx > 0.97 * e_exact, "exact {e_exact} approx {e_approx}");
    }

    #[test]
    fn small_matrix_fallback() {
        let mut rng = Pcg64::seeded(4);
        let a = Mat::randn(6, 5, &mut rng);
        let svd = randomized_svd(&a, 3, RsvdOptions::default(), &mut rng);
        assert_eq!(svd.s.len(), 3);
        assert!(svd.u.as_slice().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut r1 = Pcg64::seeded(9);
        let mut r2 = Pcg64::seeded(9);
        let a = low_rank(64, 48, 4, 0.02, &mut Pcg64::seeded(5));
        let s1 = randomized_svd(&a, 4, RsvdOptions::default(), &mut r1);
        let s2 = randomized_svd(&a, 4, RsvdOptions::default(), &mut r2);
        assert_eq!(s1.u, s2.u);
        assert_eq!(s1.s, s2.s);
    }

    #[test]
    fn rank_larger_than_dims_clamped() {
        let mut rng = Pcg64::seeded(6);
        let a = Mat::randn(10, 4, &mut rng);
        let svd = randomized_svd(&a, 99, RsvdOptions::default(), &mut rng);
        assert!(svd.s.len() <= 4);
    }
}
