//! Dense linear-algebra substrate.
//!
//! GradESTC's per-round math is built from a handful of dense primitives
//! over `f32` matrices: blocked matmul, thin QR, symmetric Jacobi eigen,
//! thin SVD and randomized SVD (Halko–Martinsson–Tropp). No external BLAS
//! is available offline, so this module implements them with cache-blocked,
//! thread-parallel kernels; `benches/linalg.rs` tracks their throughput and
//! EXPERIMENTS.md §Perf records the optimization history.
//!
//! Matrices are row-major [`Mat`] with explicit dimensions; all routines are
//! deterministic given the caller-provided RNG.

mod mat;
mod matmul;
mod qr;
mod rsvd;
mod svd;

pub use mat::Mat;
pub use matmul::{axpy, matmul, matmul_acc, matmul_at_b, matmul_a_bt};
pub use qr::{householder_qr, mgs_orthonormalize, ortho_defect};
pub use rsvd::{randomized_svd, RsvdOptions};
pub use svd::{jacobi_eigh_symmetric, thin_svd, Svd};
