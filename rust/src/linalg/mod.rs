//! Dense linear-algebra substrate.
//!
//! GradESTC's per-round math is built from a handful of dense primitives
//! over `f32` matrices: blocked matmul, thin QR, symmetric Jacobi eigen,
//! thin SVD and randomized SVD (Halko–Martinsson–Tropp). No external BLAS
//! is available offline, so this module implements them with cache-blocked,
//! thread-parallel kernels; `benches/linalg.rs` tracks their throughput and
//! EXPERIMENTS.md §Perf records the optimization history.
//!
//! Matrices are row-major [`Mat`] with explicit dimensions; all routines are
//! deterministic given the caller-provided RNG.
//!
//! Kernels dispatch through the pluggable [`Backend`] trait
//! (`linalg/backend.rs`): register-tiled blocked CPU kernels by default,
//! the frozen scalar reference on request, selected per experiment via
//! `ExperimentConfig::backend` / `--backend` / `GRADESTC_BACKEND`. The
//! `*_in` variants (`randomized_svd_in`, `householder_qr_in`,
//! `mgs_orthonormalize_in`, `thin_svd_in`) take an explicit backend
//! handle; the plain names use the process default.

mod backend;
mod mat;
mod matmul;
mod qr;
mod rsvd;
mod svd;

pub use backend::{default_backend, Backend, BackendKind, BlockedBackend, ScalarBackend};
#[cfg(feature = "xla")]
pub use backend::XlaBackend;
pub use mat::Mat;
pub use matmul::{axpy, matmul, matmul_acc, matmul_at_b, matmul_a_bt};
pub use qr::{householder_qr, householder_qr_in, mgs_orthonormalize, mgs_orthonormalize_in,
    ortho_defect};
pub use rsvd::{randomized_svd, randomized_svd_in, RsvdOptions};
pub use svd::{chordal_distance, jacobi_eigh_symmetric, principal_angles_in, thin_svd,
    thin_svd_in, Svd};
