//! Matrix-multiplication entry points and the scalar reference kernels.
//!
//! Four entry points cover every product the compressor and the server
//! aggregation plane need without materializing transposes:
//!
//! * [`matmul`]       — `C = A·B`
//! * [`matmul_acc`]   — `C += α·A·B` (fused low-rank reconstruct+fold)
//! * [`matmul_at_b`]  — `C = Aᵀ·B`   (projection `A = MᵀG`)
//! * [`matmul_a_bt`]  — `C = A·Bᵀ`   (Gram matrices for the small eigsolve)
//!
//! plus the scaled-accumulate primitive [`axpy`] they are built from.
//! Each entry point dispatches through the process-default
//! [`Backend`](super::Backend) (see `linalg/backend.rs` — register-tiled
//! blocked kernels unless `GRADESTC_BACKEND` overrides); the `scalar_*`
//! kernels in this file are the original loops, kept verbatim as the
//! [`ScalarBackend`](super::ScalarBackend)'s frozen reference: an i-k-j
//! loop over row panels with an unrolled 8-wide FMA body, parallelized
//! over row blocks with scoped threads (`matmul_acc` excepted — its
//! callers parallelize over disjoint accumulators already).

use super::{default_backend, Mat};
use crate::util::pool::default_workers;

/// Rows-per-task granularity for the thread fan-out.
const PAR_MIN_ROWS: usize = 16;
/// Only parallelize when the total FLOP count is worth a thread wake-up.
const PAR_MIN_FLOPS: usize = 1 << 22;

/// `dst += a * x`, the scaled-accumulate primitive behind every kernel
/// here and the server aggregation plane's dense folds.
#[inline]
pub fn axpy(dst: &mut [f32], a: f32, x: &[f32]) {
    // dst += a * x ; 8-wide unroll, tail handled scalar. The compiler
    // auto-vectorizes this loop (verified via benches/linalg.rs).
    let n = dst.len();
    let chunks = n / 8;
    let (dh, dt) = dst.split_at_mut(chunks * 8);
    let (xh, xt) = x.split_at(chunks * 8);
    for (d8, x8) in dh.chunks_exact_mut(8).zip(xh.chunks_exact(8)) {
        d8[0] += a * x8[0];
        d8[1] += a * x8[1];
        d8[2] += a * x8[2];
        d8[3] += a * x8[3];
        d8[4] += a * x8[4];
        d8[5] += a * x8[5];
        d8[6] += a * x8[6];
        d8[7] += a * x8[7];
    }
    for (d, &xv) in dt.iter_mut().zip(xt) {
        *d += a * xv;
    }
}

/// Compute one row-panel of `C = A·B`: rows `r0..r1`.
fn mm_panel(a: &Mat, b: &Mat, r0: usize, r1: usize, c_panel: &mut [f32]) {
    let n = b.cols();
    for (pi, i) in (r0..r1).enumerate() {
        let crow = &mut c_panel[pi * n..(pi + 1) * n];
        let arow = a.row(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik != 0.0 {
                axpy(crow, aik, b.row(k));
            }
        }
    }
}

/// Row-parallel driver shared by the scalar and blocked backends: fill
/// `m × cols` output rows via disjoint contiguous row panels. Safe for
/// any kernel whose per-element result is independent of the row
/// partition (each element is produced entirely by one thread).
pub(super) fn parallel_rows(
    m: usize,
    flops: usize,
    panel: impl Fn(usize, usize, &mut [f32]) + Sync,
    cols: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * cols];
    let workers = default_workers();
    if workers <= 1 || m < 2 * PAR_MIN_ROWS || flops < PAR_MIN_FLOPS {
        panel(0, m, &mut out);
        return out;
    }
    // Split rows into contiguous panels; each thread fills its own disjoint
    // slice of `out`.
    let nchunks = workers.min(m / PAR_MIN_ROWS).max(1);
    let chunk = m.div_ceil(nchunks);
    let mut slices: Vec<(usize, usize, &mut [f32])> = Vec::new();
    {
        let mut rest: &mut [f32] = &mut out;
        let mut r = 0;
        while r < m {
            let r1 = (r + chunk).min(m);
            let (head, tail) = rest.split_at_mut((r1 - r) * cols);
            slices.push((r, r1, head));
            rest = tail;
            r = r1;
        }
    }
    let panel = &panel;
    std::thread::scope(|scope| {
        for (r0, r1, slice) in slices {
            scope.spawn(move || panel(r0, r1, slice));
        }
    });
    out
}

/// `C = A·B` (shapes `(m,k)·(k,n) -> (m,n)`), on the process-default
/// backend.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    default_backend().matmul(a, b)
}

/// `C += α · A·B` in place (shapes `(m,k)·(k,n) += (m,n)`), the fused
/// reconstruct-and-accumulate kernel of the server aggregation plane, on
/// the process-default backend.
///
/// For a low-rank update `Ĝ = M·A` folded with FedAvg weight α, this
/// scales the `k`-sized inner loop (one multiply per `(i,k)` pair) instead
/// of the `l×m` dense product — the whole point of aggregating in the
/// compressed domain (paper Eq. 14 shapes).
///
/// Deliberately single-threaded on every backend: the caller
/// ([`ServerAggregator`](crate::coordinator::ServerAggregator)) already
/// fans out over disjoint per-layer accumulators, and each output element
/// accumulates in a fixed `k`-order, so results are bit-identical at any
/// outer parallelism.
pub fn matmul_acc(c: &mut Mat, alpha: f32, a: &Mat, b: &Mat) {
    default_backend().matmul_acc(c, alpha, a, b);
}

/// `C = Aᵀ·B` (shapes `(k,m)ᵀ·(k,n) -> (m,n)`), without the caller
/// forming `Aᵀ`, on the process-default backend.
///
/// This is the compressor's projection `A = MᵀG` with `M: l×k`, `G: l×m`.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    default_backend().matmul_at_b(a, b)
}

/// `C = A·Bᵀ` (shapes `(m,k)·(n,k)ᵀ -> (m,n)`), without the caller
/// forming `Bᵀ`, on the process-default backend.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    default_backend().matmul_a_bt(a, b)
}

/// Scalar reference `C = A·B`.
pub(super) fn scalar_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul: {}x{} · {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, n) = (a.rows(), b.cols());
    let flops = 2 * m * n * a.cols();
    let out = parallel_rows(m, flops, |r0, r1, panel| mm_panel(a, b, r0, r1, panel), n);
    Mat::from_vec(m, n, out)
}

/// Scalar reference `C += α·A·B` (single-threaded; each element
/// accumulates in fixed ascending-`k` order).
pub(super) fn scalar_matmul_acc(c: &mut Mat, alpha: f32, a: &Mat, b: &Mat) {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul_acc: {}x{} · {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    assert_eq!(
        (c.rows(), c.cols()),
        (a.rows(), b.cols()),
        "matmul_acc: accumulator is {}x{}, product is {}x{}",
        c.rows(),
        c.cols(),
        a.rows(),
        b.cols()
    );
    for i in 0..a.rows() {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            // No zero-skip here: an `α·aik == 0` test would silently drop
            // non-finite B rows exactly when inputs misbehave (and basis
            // rows are dense Gaussians, so the branch saves nothing).
            axpy(crow, alpha * aik, b.row(k));
        }
    }
}

/// Scalar reference `C = Aᵀ·B` without forming `Aᵀ`.
///
/// Historical caveat, frozen with the rest of the reference: the
/// parallel path splits `k` into per-thread partial accumulators reduced
/// in chunk order, and the chunk count comes from the *process-wide*
/// worker default — constant within a process (which the w1-vs-wN
/// determinism tests rely on) but not a pure function of shape. The
/// blocked backend replaces this with a shape-pure reduction.
pub(super) fn scalar_matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: {}x{} ᵀ· {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, n, kk) = (a.cols(), b.cols(), a.rows());
    // C[i,j] = sum_k A[k,i] * B[k,j]  — accumulate outer products of the
    // k-th rows; each row of A scatters into all rows of C, so parallelize
    // over k-chunks with per-thread accumulators then reduce.
    let workers = default_workers();
    let flops = 2 * m * n * kk;
    if workers <= 1 || flops < PAR_MIN_FLOPS || kk < 64 {
        let mut c = vec![0.0f32; m * n];
        for k in 0..kk {
            let arow = a.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki != 0.0 {
                    axpy(&mut c[i * n..(i + 1) * n], aki, brow);
                }
            }
        }
        return Mat::from_vec(m, n, c);
    }
    let nchunks = workers;
    let chunk = kk.div_ceil(nchunks);
    let partials: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c0 in (0..kk).step_by(chunk) {
            let c1 = (c0 + chunk).min(kk);
            handles.push(scope.spawn(move || {
                let mut acc = vec![0.0f32; m * n];
                for k in c0..c1 {
                    let arow = a.row(k);
                    let brow = b.row(k);
                    for (i, &aki) in arow.iter().enumerate() {
                        if aki != 0.0 {
                            axpy(&mut acc[i * n..(i + 1) * n], aki, brow);
                        }
                    }
                }
                acc
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut c = vec![0.0f32; m * n];
    for p in partials {
        for (ci, pi) in c.iter_mut().zip(p) {
            *ci += pi;
        }
    }
    Mat::from_vec(m, n, c)
}

/// Scalar reference `C = A·Bᵀ` without forming `Bᵀ` (4-wide grouped dot).
pub(super) fn scalar_matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: {}x{} · {}x{}ᵀ", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, n) = (a.rows(), b.rows());
    let flops = 2 * m * n * a.cols();
    let out = parallel_rows(
        m,
        flops,
        |r0, r1, panel| {
            for (pi, i) in (r0..r1).enumerate() {
                let arow = a.row(i);
                for j in 0..n {
                    let brow = b.row(j);
                    let mut s = 0.0f32;
                    // dot product, 4-wide unroll
                    let mut k = 0;
                    let kk = arow.len();
                    while k + 4 <= kk {
                        s += arow[k] * brow[k]
                            + arow[k + 1] * brow[k + 1]
                            + arow[k + 2] * brow[k + 2]
                            + arow[k + 3] * brow[k + 3];
                        k += 4;
                    }
                    while k < kk {
                        s += arow[k] * brow[k];
                        k += 1;
                    }
                    panel[pi * n + j] = s;
                }
            }
        },
        n,
    );
    Mat::from_vec(m, n, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(1);
        for &(m, k, n) in &[(3, 4, 5), (17, 9, 13), (64, 32, 48), (1, 7, 1)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_large_parallel_path() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(300, 200, &mut rng);
        let b = Mat::randn(200, 150, &mut rng);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 2e-2);
    }

    #[test]
    fn at_b_matches_transpose() {
        let mut rng = Pcg64::seeded(3);
        for &(k, m, n) in &[(5, 3, 4), (128, 16, 33), (200, 31, 64)] {
            let a = Mat::randn(k, m, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = matmul_at_b(&a, &b);
            let expect = naive(&a.transpose(), &b);
            assert!(c.max_abs_diff(&expect) < 2e-2, "({k},{m},{n})");
        }
    }

    #[test]
    fn a_bt_matches_transpose() {
        let mut rng = Pcg64::seeded(4);
        for &(m, k, n) in &[(4, 6, 3), (31, 64, 17), (100, 90, 80)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let c = matmul_a_bt(&a, &b);
            let expect = naive(&a, &b.transpose());
            assert!(c.max_abs_diff(&expect) < 2e-2, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_acc_accumulates_scaled_products() {
        let mut rng = Pcg64::seeded(6);
        let a1 = Mat::randn(24, 4, &mut rng);
        let b1 = Mat::randn(4, 9, &mut rng);
        let a2 = Mat::randn(24, 4, &mut rng);
        let b2 = Mat::randn(4, 9, &mut rng);
        let mut c = Mat::zeros(24, 9);
        matmul_acc(&mut c, 0.25, &a1, &b1);
        matmul_acc(&mut c, -1.5, &a2, &b2);
        let mut expect = Mat::zeros(24, 9);
        for (i, src) in [(0.25f32, naive(&a1, &b1)), (-1.5, naive(&a2, &b2))] {
            for (e, s) in expect.as_mut_slice().iter_mut().zip(src.as_slice()) {
                *e += i * s;
            }
        }
        assert!(c.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    #[should_panic]
    fn matmul_acc_shape_checked() {
        let mut c = Mat::zeros(3, 3);
        let a = Mat::zeros(3, 2);
        let b = Mat::zeros(2, 4); // product is 3x4, accumulator 3x3
        matmul_acc(&mut c, 1.0, &a, &b);
    }

    #[test]
    fn scalar_kernels_match_naive() {
        // The dispatching entry points above default to the blocked
        // backend; pin the frozen scalar reference against the oracle
        // explicitly so it cannot rot unexercised.
        let mut rng = Pcg64::seeded(7);
        let a = Mat::randn(40, 33, &mut rng);
        let b = Mat::randn(33, 21, &mut rng);
        assert!(scalar_matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
        let c = Mat::randn(33, 40, &mut rng);
        assert!(scalar_matmul_at_b(&c, &b).max_abs_diff(&naive(&c.transpose(), &b)) < 1e-3);
        let d = Mat::randn(21, 33, &mut rng);
        assert!(scalar_matmul_a_bt(&a, &d).max_abs_diff(&naive(&a, &d.transpose())) < 1e-3);
        let mut acc = Mat::zeros(40, 21);
        scalar_matmul_acc(&mut acc, 0.5, &a, &b);
        let mut expect = naive(&a, &b);
        for x in expect.as_mut_slice() {
            *x *= 0.5;
        }
        assert!(acc.max_abs_diff(&expect) < 1e-3);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seeded(5);
        let a = Mat::randn(20, 20, &mut rng);
        let c = matmul(&a, &Mat::eye(20));
        assert!(c.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = matmul(&a, &b);
    }
}
