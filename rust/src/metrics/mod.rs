//! Metrics: round records, CSV/JSONL sinks, communication accounting
//! (charged from the encoded frames that cross [`crate::net::Transport`]),
//! the per-client heterogeneous-link [`NetworkModel`], and the
//! cosine-similarity probe behind the paper's Fig. 1.

pub mod accounting;
pub mod recorder;
pub mod similarity;

pub use accounting::{CommLedger, NetworkModel};
pub use recorder::{RoundRecord, RunRecorder, RunReport};
pub use similarity::{cosine, SimilarityProbe};
