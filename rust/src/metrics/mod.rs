//! Metrics: round records, CSV/JSONL sinks, communication accounting
//! (charged from the encoded frames that cross [`crate::net::Transport`]),
//! the per-client heterogeneous-link [`NetworkModel`], and the
//! cosine-similarity probe behind the paper's Fig. 1.
//!
//! The similarity probe consumes the telemetry plane's
//! [`Observer`](crate::telemetry::Observer) stream (via the round-hook
//! adapter), so it works under every scheduler; the per-round telemetry
//! snapshot rides along on [`RoundRecord::ext`] without entering any CSV
//! or report math.

pub mod accounting;
pub mod recorder;
pub mod similarity;

pub use accounting::{CommLedger, NetworkModel};
pub use recorder::{RoundRecord, RunRecorder, RunReport};
pub use similarity::{cosine, SimilarityProbe};
