//! Communication accounting and the bandwidth-constrained network model.
//!
//! Every byte that would cross the wire in a real deployment is charged to
//! a [`CommLedger`]: uplink per client per round (compressed payloads,
//! replacement indices, headers) and downlink (global model broadcast).
//! The paper's headline metrics — total uplink and uplink-at-threshold —
//! read directly from the ledger. [`NetworkModel`] converts bytes into
//! simulated wallclock for time-to-accuracy plots, with the asymmetric
//! up/down bandwidth that motivates uplink-focused compression (§I).

/// Running totals of simulated communication.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    uplink_bytes: u64,
    downlink_bytes: u64,
    per_round_uplink: Vec<u64>,
    current_round_uplink: u64,
    current_round_downlink: u64,
}

impl CommLedger {
    /// Fresh ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge client→server traffic for the current round.
    pub fn charge_uplink(&mut self, bytes: u64) {
        self.uplink_bytes += bytes;
        self.current_round_uplink += bytes;
    }

    /// Charge server→client traffic for the current round.
    pub fn charge_downlink(&mut self, bytes: u64) {
        self.downlink_bytes += bytes;
        self.current_round_downlink += bytes;
    }

    /// Close the round; returns `(uplink, downlink)` charged in it.
    pub fn end_round(&mut self) -> (u64, u64) {
        let out = (self.current_round_uplink, self.current_round_downlink);
        self.per_round_uplink.push(self.current_round_uplink);
        self.current_round_uplink = 0;
        self.current_round_downlink = 0;
        out
    }

    /// Cumulative uplink bytes.
    pub fn total_uplink(&self) -> u64 {
        self.uplink_bytes
    }

    /// Cumulative downlink bytes.
    pub fn total_downlink(&self) -> u64 {
        self.downlink_bytes
    }

    /// Per-round uplink history.
    pub fn per_round_uplink(&self) -> &[u64] {
        &self.per_round_uplink
    }
}

/// Simple asymmetric link model shared by all clients.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Client→server bandwidth in bytes/sec.
    pub uplink_bps: f64,
    /// Server→client bandwidth in bytes/sec.
    pub downlink_bps: f64,
    /// Per-message latency in seconds.
    pub latency_s: f64,
}

impl NetworkModel {
    /// A bandwidth-constrained edge setting: 10 Mbit/s up, 50 Mbit/s down,
    /// 30 ms latency — the regime the paper's intro targets.
    pub fn edge_default() -> Self {
        NetworkModel {
            uplink_bps: 10e6 / 8.0,
            downlink_bps: 50e6 / 8.0,
            latency_s: 0.03,
        }
    }

    /// Seconds to move `bytes` up the constrained link.
    pub fn uplink_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.uplink_bps
    }

    /// Seconds to move `bytes` down.
    pub fn downlink_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.downlink_bps
    }

    /// Wallclock for one synchronous round: slowest participant's
    /// down+up transfer (clients transfer in parallel).
    pub fn round_time(&self, per_client_up: &[u64], broadcast_bytes: u64) -> f64 {
        let slowest_up = per_client_up.iter().copied().max().unwrap_or(0);
        self.downlink_time(broadcast_bytes) + self.uplink_time(slowest_up)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_per_round() {
        let mut l = CommLedger::new();
        l.charge_uplink(100);
        l.charge_uplink(50);
        l.charge_downlink(10);
        assert_eq!(l.end_round(), (150, 10));
        l.charge_uplink(7);
        assert_eq!(l.end_round(), (7, 0));
        assert_eq!(l.total_uplink(), 157);
        assert_eq!(l.total_downlink(), 10);
        assert_eq!(l.per_round_uplink(), &[150, 7]);
    }

    #[test]
    fn network_times_monotone_in_bytes() {
        let n = NetworkModel::edge_default();
        assert!(n.uplink_time(1_000_000) > n.uplink_time(1_000));
        // Uplink is the constrained direction.
        assert!(n.uplink_time(1_000_000) > n.downlink_time(1_000_000));
    }

    #[test]
    fn round_time_uses_slowest_client() {
        let n = NetworkModel::edge_default();
        let t_small = n.round_time(&[100, 100, 100], 1000);
        let t_skew = n.round_time(&[100, 100, 10_000_000], 1000);
        assert!(t_skew > t_small);
    }
}
