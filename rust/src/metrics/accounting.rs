//! Communication accounting over the real transport, and the
//! heterogeneous-link network model.
//!
//! Every byte that crosses the [`Transport`](crate::net::Transport) is
//! charged to a [`CommLedger`] from the *actual encoded frame lengths* the
//! coordinator drains: uplink per client per round (the
//! [`net::wire`](crate::net::wire)-encoded payload buffers — compressed
//! tensors, replacement indices, frame headers and all) and downlink (the
//! dense model-broadcast frame, once per surviving participant). The
//! paper's headline metrics — total uplink and uplink-at-threshold — read
//! directly from the ledger; since the codec guarantees
//! `encode(p).len() == p.wire_bytes()`, those totals are byte-identical to
//! the pre-transport analytical accounting.
//!
//! [`NetworkModel`] converts bytes into simulated wallclock for
//! time-to-accuracy plots. It holds one [`LinkProfile`] *per client*
//! (sampled from `ExperimentConfig::net`, heterogeneous when
//! `het_spread > 0`), with the asymmetric up/down bandwidth that motivates
//! uplink-focused compression (§I); a round takes as long as its slowest
//! surviving participant, clipped to the straggler deadline when one is
//! configured.

use crate::net::LinkProfile;

/// Running totals of simulated communication.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    uplink_bytes: u64,
    downlink_bytes: u64,
    per_round_uplink: Vec<u64>,
    current_round_uplink: u64,
    current_round_downlink: u64,
}

impl CommLedger {
    /// Fresh ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge client→server traffic for the current round.
    pub fn charge_uplink(&mut self, bytes: u64) {
        self.uplink_bytes += bytes;
        self.current_round_uplink += bytes;
    }

    /// Charge server→client traffic for the current round.
    pub fn charge_downlink(&mut self, bytes: u64) {
        self.downlink_bytes += bytes;
        self.current_round_downlink += bytes;
    }

    /// Close the round; returns `(uplink, downlink)` charged in it.
    pub fn end_round(&mut self) -> (u64, u64) {
        let out = (self.current_round_uplink, self.current_round_downlink);
        self.per_round_uplink.push(self.current_round_uplink);
        self.current_round_uplink = 0;
        self.current_round_downlink = 0;
        out
    }

    /// Cumulative uplink bytes.
    pub fn total_uplink(&self) -> u64 {
        self.uplink_bytes
    }

    /// Cumulative downlink bytes.
    pub fn total_downlink(&self) -> u64 {
        self.downlink_bytes
    }

    /// Per-round uplink history.
    pub fn per_round_uplink(&self) -> &[u64] {
        &self.per_round_uplink
    }
}

/// Per-client link model: one [`LinkProfile`] per client id.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    links: Vec<LinkProfile>,
}

impl NetworkModel {
    /// Build from per-client profiles (index = client id).
    pub fn from_profiles(links: Vec<LinkProfile>) -> Self {
        assert!(!links.is_empty(), "network model needs at least one link");
        NetworkModel { links }
    }

    /// Every client on the same link.
    pub fn homogeneous(num_clients: usize, link: LinkProfile) -> Self {
        Self::from_profiles(vec![link; num_clients.max(1)])
    }

    /// `num_clients` identical bandwidth-constrained edge links
    /// ([`LinkProfile::edge_default`]).
    pub fn edge_default(num_clients: usize) -> Self {
        Self::homogeneous(num_clients, LinkProfile::edge_default())
    }

    /// Client `cid`'s link.
    pub fn link(&self, cid: usize) -> &LinkProfile {
        &self.links[cid]
    }

    /// Wallclock for one synchronous round: the slowest surviving
    /// participant's broadcast-download plus update-upload on *its own*
    /// link (clients transfer in parallel). With a straggler `deadline`,
    /// the server stops waiting at the deadline, so no round costs more
    /// than that.
    pub fn round_time(
        &self,
        per_client_up: &[(usize, u64)],
        broadcast_bytes: u64,
        deadline: Option<f64>,
    ) -> f64 {
        let mut worst = 0.0f64;
        for &(cid, up) in per_client_up {
            let mut t = self.link(cid).round_trip_time(broadcast_bytes, up);
            if let Some(d) = deadline {
                t = t.min(d);
            }
            worst = worst.max(t);
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_per_round() {
        let mut l = CommLedger::new();
        l.charge_uplink(100);
        l.charge_uplink(50);
        l.charge_downlink(10);
        assert_eq!(l.end_round(), (150, 10));
        l.charge_uplink(7);
        assert_eq!(l.end_round(), (7, 0));
        assert_eq!(l.total_uplink(), 157);
        assert_eq!(l.total_downlink(), 10);
        assert_eq!(l.per_round_uplink(), &[150, 7]);
    }

    #[test]
    fn round_time_uses_slowest_client() {
        let n = NetworkModel::edge_default(3);
        let t_small = n.round_time(&[(0, 100), (1, 100), (2, 100)], 1000, None);
        let t_skew = n.round_time(&[(0, 100), (1, 100), (2, 10_000_000)], 1000, None);
        assert!(t_skew > t_small);
        // Equal links: the skewed round costs exactly the slowest client's
        // round trip.
        let l = LinkProfile::edge_default();
        assert_eq!(t_skew.to_bits(), l.round_trip_time(1000, 10_000_000).to_bits());
    }

    #[test]
    fn heterogeneous_links_dominate_round_time() {
        let fast = LinkProfile::edge_default();
        let slow = LinkProfile { uplink_bps: fast.uplink_bps / 100.0, ..fast };
        let n = NetworkModel::from_profiles(vec![fast, slow]);
        // Same byte counts, but the client on the slow link sets the pace.
        let t = n.round_time(&[(0, 10_000), (1, 10_000)], 1000, None);
        assert_eq!(t.to_bits(), slow.round_trip_time(1000, 10_000).to_bits());
        assert!(t > fast.round_trip_time(1000, 10_000));
    }

    #[test]
    fn deadline_caps_round_time() {
        let n = NetworkModel::edge_default(2);
        let uncapped = n.round_time(&[(0, 100), (1, 100_000_000)], 1000, None);
        assert!(uncapped > 1.0);
        let capped = n.round_time(&[(0, 100), (1, 100_000_000)], 1000, Some(0.5));
        assert_eq!(capped, 0.5);
        // Deadline above the slowest client changes nothing.
        let loose = n.round_time(&[(0, 100), (1, 100_000_000)], 1000, Some(1e9));
        assert_eq!(loose.to_bits(), uncapped.to_bits());
    }

    #[test]
    fn empty_round_costs_nothing() {
        let n = NetworkModel::edge_default(4);
        assert_eq!(n.round_time(&[], 1_000_000, None), 0.0);
    }
}
