//! Run recording: per-round records, CSV emission and end-of-run reports.

use std::io::Write as _;
use std::path::Path;

/// One global round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Round index (0-based).
    pub round: usize,
    /// Mean training loss across participating clients.
    pub train_loss: f64,
    /// Server-side test accuracy in `[0,1]` (NaN when not evaluated).
    pub test_accuracy: f64,
    /// Server-side test loss (NaN when not evaluated).
    pub test_loss: f64,
    /// Uplink bytes charged this round.
    pub uplink_bytes: u64,
    /// Downlink bytes charged this round.
    pub downlink_bytes: u64,
    /// Simulated round wallclock (seconds) under the network model: the
    /// duration of this round (sync/semi-sync) or of this apply window
    /// (async).
    pub sim_time_s: f64,
    /// Virtual simulation clock at the end of this round (seconds since
    /// the run started) — the x-axis of time-to-accuracy plots. For the
    /// sync scheduler this is exactly the running sum of `sim_time_s`.
    pub sim_clock_s: f64,
    /// Sum of rSVD candidate counts `d` across clients/layers this round
    /// (the paper's Table IV computational-overhead proxy; 0 for baselines).
    pub sum_d: u64,
    /// Clients whose updates this record covers, sorted. Sync: the
    /// dropout survivors that ran the round (equals the sampled set when
    /// `net.dropout == 0`). Semi-sync: the clients whose updates this
    /// round *aggregated* (on-time participants plus rolled-over
    /// stragglers). Async: the `k` arrivals folded into this apply (a
    /// fast client may appear more than once).
    pub survivors: Vec<usize>,
    /// Telemetry extension: this round's frozen metrics snapshot
    /// (per-phase time, payload-variant bytes, staleness histogram, pool
    /// gauges). `None` when telemetry is disabled — the named scalar
    /// fields above are the determinism contract; `ext` is observation
    /// only and never enters CSV or report math.
    pub ext: Option<std::sync::Arc<crate::telemetry::RoundSnapshot>>,
}

/// Collects [`RoundRecord`]s and derives the paper's summary metrics.
#[derive(Clone, Debug, Default)]
pub struct RunRecorder {
    rounds: Vec<RoundRecord>,
}

/// End-of-run summary (the numbers Table III/IV report).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Best test accuracy seen.
    pub best_accuracy: f64,
    /// Total uplink bytes.
    pub total_uplink: u64,
    /// Cumulative uplink when accuracy first reached `threshold`
    /// (None if never reached).
    pub uplink_at_threshold: Option<u64>,
    /// The threshold used.
    pub threshold: f64,
    /// Round when the threshold was first reached.
    pub rounds_to_threshold: Option<usize>,
    /// Σd over the whole run (compute-overhead proxy).
    pub sum_d: u64,
    /// Final-round test accuracy.
    pub final_accuracy: f64,
}

impl RunRecorder {
    /// Fresh recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a round.
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// All rounds.
    pub fn rounds(&self) -> &[RoundRecord] {
        &self.rounds
    }

    /// Best accuracy over the run (NaN-safe).
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_accuracy)
            .filter(|a| !a.is_nan())
            .fold(0.0, f64::max)
    }

    /// Build the summary report. `threshold` is an absolute accuracy in
    /// `[0,1]`; Table III uses `threshold_frac · best_accuracy` of the
    /// *uncompressed* run so all methods chase the same bar.
    pub fn report(&self, threshold: f64) -> RunReport {
        let mut cum_uplink = 0u64;
        let mut uplink_at_threshold = None;
        let mut rounds_to_threshold = None;
        for r in &self.rounds {
            cum_uplink += r.uplink_bytes;
            if uplink_at_threshold.is_none()
                && !r.test_accuracy.is_nan()
                && r.test_accuracy >= threshold
            {
                uplink_at_threshold = Some(cum_uplink);
                rounds_to_threshold = Some(r.round);
            }
        }
        let final_accuracy = self
            .rounds
            .iter()
            .rev()
            .find(|r| !r.test_accuracy.is_nan())
            .map(|r| r.test_accuracy)
            .unwrap_or(f64::NAN);
        RunReport {
            best_accuracy: self.best_accuracy(),
            total_uplink: cum_uplink,
            uplink_at_threshold,
            threshold,
            rounds_to_threshold,
            sum_d: self.rounds.iter().map(|r| r.sum_d).sum(),
            final_accuracy,
        }
    }

    /// Write the per-round trace as CSV (the data behind Figs. 5/6/7/8/9).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,train_loss,test_accuracy,test_loss,uplink_bytes,downlink_bytes,cum_uplink_bytes,sim_time_s,sim_clock_s,sum_d,n_survivors"
        )?;
        let mut cum = 0u64;
        for r in &self.rounds {
            cum += r.uplink_bytes;
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{},{},{},{:.4},{:.4},{},{}",
                r.round,
                r.train_loss,
                r.test_accuracy,
                r.test_loss,
                r.uplink_bytes,
                r.downlink_bytes,
                cum,
                r.sim_time_s,
                r.sim_clock_s,
                r.sum_d,
                r.survivors.len()
            )?;
        }
        Ok(())
    }
}

/// Format a byte count as MB with 4 decimals (Table III's unit).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.4}", bytes as f64 / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            test_accuracy: acc,
            test_loss: 1.0,
            uplink_bytes: up,
            downlink_bytes: 5,
            sim_time_s: 0.1,
            sim_clock_s: 0.1 * (round + 1) as f64,
            sum_d: 3,
            survivors: vec![0, 1],
            ext: None,
        }
    }

    #[test]
    fn threshold_metrics() {
        let mut r = RunRecorder::new();
        r.push(rec(0, 0.2, 100));
        r.push(rec(1, 0.5, 100));
        r.push(rec(2, 0.8, 100));
        r.push(rec(3, 0.7, 100));
        let rep = r.report(0.75);
        assert_eq!(rep.uplink_at_threshold, Some(300));
        assert_eq!(rep.rounds_to_threshold, Some(2));
        assert_eq!(rep.total_uplink, 400);
        assert!((rep.best_accuracy - 0.8).abs() < 1e-12);
        assert!((rep.final_accuracy - 0.7).abs() < 1e-12);
        assert_eq!(rep.sum_d, 12);
    }

    #[test]
    fn threshold_never_reached() {
        let mut r = RunRecorder::new();
        r.push(rec(0, 0.2, 10));
        let rep = r.report(0.9);
        assert_eq!(rep.uplink_at_threshold, None);
        assert_eq!(rep.rounds_to_threshold, None);
    }

    #[test]
    fn nan_evals_skipped() {
        let mut r = RunRecorder::new();
        r.push(rec(0, f64::NAN, 10));
        r.push(rec(1, 0.6, 10));
        let rep = r.report(0.5);
        assert_eq!(rep.uplink_at_threshold, Some(20));
        assert!((rep.final_accuracy - 0.6).abs() < 1e-12);
    }

    #[test]
    fn csv_written() {
        let mut r = RunRecorder::new();
        r.push(rec(0, 0.3, 10));
        let dir = std::env::temp_dir().join("gradestc-test-csv");
        let path = dir.join("run.csv");
        r.write_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("round,"));
        assert!(body.lines().count() == 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
