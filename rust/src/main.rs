//! `gradestc` — CLI launcher for the federated-learning coordinator.
//!
//! Subcommands:
//!
//! * `train`  — run one experiment from flags.
//! * `exp`    — regenerate a paper table/figure (fig1, fig2, table3,
//!   table4, fig7, fig8, fig9; fig4/5/6 come from table3's CSVs).
//! * `info`   — inspect the artifact manifest.
//!
//! Every run writes per-round CSVs under `results/` and prints the
//! summary rows the paper reports.

mod experiments;

use gradestc::config::{
    AvailConfig, BackendKind, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig,
    GradEstcParams, LaneConfig, ModelKind, NetConfig, SchedConfig, SchedKind,
};
use gradestc::util::args::ArgSpec;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.clone(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let code = match cmd.as_str() {
        "train" => cmd_train(rest),
        "exp" => experiments::cmd_exp(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "gradestc — communication-efficient FL (GradESTC reproduction)\n\n\
     USAGE:\n  gradestc train [OPTIONS]      run one experiment\n  \
     gradestc exp <id> [OPTIONS]   regenerate a paper table/figure\n  \
     gradestc info [--artifacts d] inspect the artifact manifest\n\n\
     exp ids: fig1 fig2 table3 table4 fig7 fig8 fig9 async1 scale1 scale2 diag1 churn1\n\
     try: gradestc train --help"
        .to_string()
}

/// Parse a compressor spec like `gradestc`, `gradestc:k=16`, `topk:frac=0.1`,
/// `fedpaq:bits=8`, `fedqclip:bits=8,clip=2.5`, `svdfed:k=32,gamma=0.3`,
/// `signsgd`, `fedavg`.
pub fn parse_compressor(spec: &str) -> Result<CompressorKind, String> {
    let (name, kv) = match spec.split_once(':') {
        Some((n, rest)) => (n, rest),
        None => (spec, ""),
    };
    let mut opts = std::collections::BTreeMap::new();
    for pair in kv.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').ok_or_else(|| format!("bad option '{pair}'"))?;
        opts.insert(k.to_string(), v.to_string());
    }
    let f = |k: &str, d: f64| -> Result<f64, String> {
        opts.get(k).map(|v| v.parse().map_err(|e| format!("{k}: {e}"))).unwrap_or(Ok(d))
    };
    let u = |k: &str, d: usize| -> Result<usize, String> {
        opts.get(k).map(|v| v.parse().map_err(|e| format!("{k}: {e}"))).unwrap_or(Ok(d))
    };
    let b = |k: &str| -> bool { opts.get(k).map(|v| v == "1" || v == "true").unwrap_or(false) };
    Ok(match name {
        "fedavg" | "none" => CompressorKind::None,
        "topk" => CompressorKind::TopK { frac: f("frac", 0.1)? },
        "fedpaq" => CompressorKind::FedPaq { bits: u("bits", 8)? as u8 },
        "signsgd" => CompressorKind::SignSgd,
        "svdfed" => CompressorKind::SvdFed { k: u("k", 32)?, gamma: f("gamma", 0.3)? },
        "fedqclip" => {
            CompressorKind::FedQClip { bits: u("bits", 8)? as u8, clip: f("clip", 2.5)? }
        }
        "gradestc" => CompressorKind::GradEstc(GradEstcParams {
            k: u("k", 32)?,
            alpha: f("alpha", 1.3)?,
            beta: f("beta", 1.0)?,
            coverage: f("coverage", 0.9)?,
            freeze_after_init: b("first"),
            replace_all: b("all"),
            fixed_d: b("fixedd"),
            error_feedback: b("ef"),
        }),
        other => return Err(format!("unknown compressor '{other}'")),
    })
}

/// Parse a dataset name.
pub fn parse_dataset(s: &str) -> Result<DatasetKind, String> {
    Ok(match s {
        "mnist" | "synth-mnist" => DatasetKind::SynthMnist,
        "cifar10" | "synth-cifar10" => DatasetKind::SynthCifar10,
        "cifar100" | "synth-cifar100" => DatasetKind::SynthCifar100,
        "corpus" | "tiny-corpus" => DatasetKind::TinyCorpus,
        other => return Err(format!("unknown dataset '{other}'")),
    })
}

/// Parse a distribution spec: `iid`, `dir0.5`, `dir0.1`.
pub fn parse_dist(s: &str) -> Result<DataDistribution, String> {
    if s == "iid" {
        return Ok(DataDistribution::Iid);
    }
    if let Some(a) = s.strip_prefix("dir") {
        return a
            .parse()
            .map(DataDistribution::Dirichlet)
            .map_err(|e| format!("bad dirichlet alpha: {e}"));
    }
    Err(format!("unknown distribution '{s}' (iid | dir<alpha>)"))
}

fn default_model_for(d: DatasetKind) -> ModelKind {
    match d {
        DatasetKind::SynthMnist => ModelKind::LeNet5,
        DatasetKind::SynthCifar10 => ModelKind::ResNetLite,
        DatasetKind::SynthCifar100 => ModelKind::AlexNetLite,
        DatasetKind::TinyCorpus => ModelKind::TinyTransformer,
    }
}

fn cmd_train(argv: Vec<String>) -> i32 {
    let spec = ArgSpec::new("gradestc train", "run one FL experiment")
        .opt("dataset", "mnist", "mnist | cifar10 | cifar100 | corpus")
        .opt("dist", "iid", "iid | dir<alpha> (e.g. dir0.5)")
        .opt(
            "compressor",
            "gradestc",
            "fedavg|topk|fedpaq|signsgd|svdfed|fedqclip|gradestc[:k=..,..]",
        )
        .opt("rounds", "30", "global rounds")
        .opt("clients", "10", "number of clients")
        .opt("participation", "1.0", "fraction of clients per round")
        .opt("local-epochs", "1", "local epochs per round")
        .opt("samples", "384", "training samples per client")
        .opt("test-samples", "512", "held-out samples")
        .opt("lr", "0.03", "SGD learning rate")
        .opt("seed", "7", "rng seed")
        .opt(
            "workers",
            "0",
            "worker threads for the per-client phase (0 = auto via GRADESTC_WORKERS / cores; results are identical for any value)",
        )
        .opt("up-mbps", "10", "mean client uplink bandwidth, Mbit/s")
        .opt("down-mbps", "50", "mean client downlink bandwidth, Mbit/s")
        .opt("latency-ms", "30", "mean per-message latency, ms")
        .opt(
            "het-spread",
            "0",
            "per-client link heterogeneity: bandwidth/latency scaled by exp(spread*N(0,1)); 0 = identical links",
        )
        .opt("dropout", "0", "per-round per-client dropout probability in [0,1)")
        .opt(
            "deadline",
            "0",
            "straggler deadline in seconds (late updates are excluded from the aggregate); 0 = wait for everyone",
        )
        .opt(
            "sched",
            "sync",
            "round scheduler: sync | semisync | async[:k=8,staleness=0.5,adaptive=1,lr_tau=0.5,conc=2] (semisync rolls stragglers into the next round; async folds each arrival and applies every k)",
        )
        .opt(
            "avail",
            "1",
            "diurnal availability duty cycle in (0,1]: fraction of each period a client is on, per-client phase-shifted; 1 = always on (requires --sched semisync|async when < 1)",
        )
        .opt("avail-period", "20", "diurnal availability period (and churn window), virtual seconds")
        .opt(
            "churn",
            "0",
            "Poisson departure rate per client per virtual second; a departed client's in-flight upload faults (zero bytes, lane discarded); 0 = no churn",
        )
        .opt("outage", "5", "max churn outage duration, virtual seconds (capped at the period)")
        .opt(
            "concurrency",
            "1",
            "per-client concurrent dispatches (async only): train while previous uploads are in flight, arrivals delivered in dispatch order per client",
        )
        .opt(
            "lr-tau",
            "0",
            "FedAsync-style server LR exponent: each async apply scaled by 1/(1+mean staleness)^lr_tau; 0 = off",
        )
        .flag(
            "adaptive-k",
            "adapt the async apply threshold k to the observed arrival rate (shrink under churn, grow when arrivals outpace the initial cadence)",
        )
        .opt(
            "backend",
            "auto",
            "compute backend for the linalg hot path: auto | scalar | blocked (auto = blocked; env GRADESTC_BACKEND overrides auto)",
        )
        .opt("compute-s", "0", "mean per-dispatch local-compute latency, seconds (0 = free)")
        .opt(
            "compute-spread",
            "0",
            "compute heterogeneity: per-dispatch compute scaled by exp(spread*N(0,1)); 0 = constant",
        )
        .opt(
            "lanes",
            "lazy",
            "client-lane materialization: lazy (on first dispatch) | eager (all at build); bit-identical either way",
        )
        .opt(
            "lane-cap",
            "0",
            "max resident (materialized) client lanes; LRU-evicted past the cap and re-materialized on demand; 0 = unbounded; requires --lanes lazy",
        )
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("out", "results", "results directory")
        .opt(
            "trace",
            "",
            "write a Chrome trace_event JSON here (chrome://tracing / Perfetto; a .jsonl span stream lands alongside); empty = telemetry off",
        )
        .opt(
            "metrics",
            "",
            "write per-round telemetry metrics JSON here (phase times, payload-variant bytes, staleness histogram, pool gauges); empty = off",
        )
        .opt(
            "diag",
            "",
            "write gradient-structure diagnostics CSV here (subspace drift, adjacent-round cosine, reconstruction NRMSE, bytes-per-loss; a 'diag' section lands in --metrics too); empty = off",
        )
        .flag("native", "use the native Rust trainer instead of XLA artifacts")
        .flag(
            "legacy-shards",
            "frozen reference: shards from the pre-virtual-lane sequential RNG walk (implies eager)",
        )
        .flag("quiet", "suppress per-round lines");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let dataset = match parse_dataset(args.str("dataset")) {
        Ok(d) => d,
        Err(e) => return fail(&e),
    };
    let dist = match parse_dist(args.str("dist")) {
        Ok(d) => d,
        Err(e) => return fail(&e),
    };
    let compressor = match parse_compressor(args.str("compressor")) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    // The --sched spec can carry the async plane-10 fields inline
    // (adaptive=/lr_tau=/conc=); the dedicated flags below override when
    // explicitly set, so both spellings work.
    let mut sched = match SchedConfig::parse_spec(args.str("sched")) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    sched.avail = AvailConfig {
        duty: args.f64("avail"),
        period_s: args.f64("avail-period"),
        churn_per_s: args.f64("churn"),
        outage_s: args.f64("outage"),
    };
    if args.has_flag("adaptive-k") {
        sched.adaptive_k = true;
    }
    let conc = args.usize("concurrency");
    if conc != 1 {
        sched.concurrency = conc;
    }
    let lr_tau = args.f64("lr-tau");
    if lr_tau != 0.0 {
        sched.lr_tau = lr_tau;
    }
    let backend = match BackendKind::parse(args.str("backend")) {
        Ok(b) => b,
        Err(e) => return fail(&e),
    };
    let legacy_shards = args.has_flag("legacy-shards");
    let lanes = LaneConfig {
        lazy: match args.str("lanes") {
            "lazy" => !legacy_shards,
            "eager" => false,
            other => return fail(&format!("--lanes must be lazy|eager, got '{other}'")),
        },
        max_resident: args.usize("lane-cap"),
        legacy_shards,
    };
    let model = default_model_for(dataset);
    let use_xla = !args.has_flag("native");
    // Default-sync runs keep their historical result paths; the scheduler
    // tag appears only when a non-default control flow is selected.
    let sched_tag = match sched.kind {
        SchedKind::Sync => String::new(),
        other => format!("-{}", other.name()),
    };
    let cfg = ExperimentConfig {
        name: format!(
            "train-{}-{}-{}{}",
            args.str("dataset"),
            args.str("dist"),
            compressor.name(),
            sched_tag
        ),
        dataset,
        model,
        distribution: dist,
        num_clients: args.usize("clients"),
        participation: args.f64("participation"),
        rounds: args.usize("rounds"),
        local_epochs: args.usize("local-epochs"),
        batch_size: if matches!(model, ModelKind::TinyTransformer) { 16 } else { 32 },
        lr: args.f64("lr") as f32,
        samples_per_client: args.usize("samples"),
        test_samples: args.usize("test-samples"),
        eval_every: 1,
        threshold_frac: 0.95,
        compressor,
        seed: args.f64("seed") as u64,
        use_xla,
        artifacts_dir: args.str("artifacts").to_string(),
        workers: args.usize("workers"),
        net: NetConfig {
            uplink_mbps: args.f64("up-mbps"),
            downlink_mbps: args.f64("down-mbps"),
            latency_ms: args.f64("latency-ms"),
            het_spread: args.f64("het-spread"),
            dropout: args.f64("dropout"),
            deadline_s: args.f64("deadline"),
        },
        sched: SchedConfig {
            compute_base_s: args.f64("compute-s"),
            compute_spread: args.f64("compute-spread"),
            ..sched
        },
        backend,
        lanes,
    };
    let quiet = args.has_flag("quiet");
    let opt_path = |key: &str| {
        let p = args.str(key);
        (!p.is_empty()).then(|| std::path::PathBuf::from(p))
    };
    let sinks = experiments::TraceSinks {
        trace: opt_path("trace"),
        metrics: opt_path("metrics"),
        diag: opt_path("diag"),
    };
    match experiments::run_one_traced(&cfg, args.str("out"), !quiet, &sinks) {
        Ok(report) => {
            println!(
                "\n{}: best acc {:.2}% | total uplink {:.4} MB | uplink@{:.0}% {}",
                cfg.name,
                report.best_accuracy * 100.0,
                report.total_uplink as f64 / 1e6,
                report.threshold * 100.0,
                report
                    .uplink_at_threshold
                    .map(|b| format!("{:.4} MB", b as f64 / 1e6))
                    .unwrap_or_else(|| "-".into()),
            );
            0
        }
        Err(e) => fail(&format!("{e:#}")),
    }
}

fn cmd_info(argv: Vec<String>) -> i32 {
    let spec = ArgSpec::new("gradestc info", "inspect the artifact manifest")
        .opt("artifacts", "artifacts", "artifacts directory");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    match gradestc::runtime::Runtime::open(args.str("artifacts")) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            for (name, m) in &rt.manifest().models {
                println!(
                    "model {name}: {} tensors, {} params, batch {}, eval_batch {}",
                    m.layers.len(),
                    m.total_params,
                    m.batch,
                    m.eval_batch
                );
            }
            for (key, k) in &rt.manifest().kernels {
                println!("kernel {key}: {} ({}x{} rank {})", k.kind, k.l, k.m, k.rank);
            }
            0
        }
        Err(e) => fail(&format!("{e:#}")),
    }
}

fn fail(msg: &str) -> i32 {
    eprintln!("error: {msg}");
    1
}
