//! Artifact manifest: the typed index of everything `aot.py` produced.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::json::Json;
use crate::model::meta::{LayerMeta, LayerRole};

/// One lowered step function (train/grad/eval).
#[derive(Clone, Debug)]
pub struct StepEntry {
    /// HLO text file name (relative to the artifacts dir).
    pub file: String,
    /// Truncated sha256 of the HLO text (staleness checks).
    pub sha256_16: String,
}

/// One model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Layer table as lowered (must match `model::meta::layer_table`).
    pub layers: Vec<LayerMeta>,
    /// Input feature shape.
    pub input_shape: Vec<usize>,
    /// Classes / vocab.
    pub classes: usize,
    /// Train batch size baked into the HLO.
    pub batch: usize,
    /// Eval batch size baked into the HLO.
    pub eval_batch: usize,
    /// Total parameter count.
    pub total_params: usize,
    /// `(params…, x, y, lr) -> (loss, params…)`.
    pub train_step: StepEntry,
    /// `(params…, x, y) -> (loss, grads…)`.
    pub grad_step: StepEntry,
    /// `(params…, x, y) -> (loss_sum, correct)`.
    pub eval_step: StepEntry,
}

/// One lowered compression kernel.
#[derive(Clone, Debug)]
pub struct KernelEntry {
    /// HLO text file name.
    pub file: String,
    /// `project` / `reconstruct` / `sketch`.
    pub kind: String,
    /// Row dimension `l`.
    pub l: usize,
    /// Column dimension `m`.
    pub m: usize,
    /// Rank `k` (or sketch width `s`).
    pub rank: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Models by name.
    pub models: std::collections::BTreeMap<String, ModelEntry>,
    /// Kernels by key (e.g. `project.1152x128x32`).
    pub kernels: std::collections::BTreeMap<String, KernelEntry>,
}

fn parse_role(s: &str) -> Result<LayerRole> {
    Ok(match s {
        "conv" => LayerRole::ConvKernel,
        "dense" => LayerRole::DenseKernel,
        "bias" => LayerRole::Bias,
        "embed" => LayerRole::Embedding,
        "norm" => LayerRole::Norm,
        _ => return Err(anyhow!("unknown layer role '{s}'")),
    })
}

fn parse_step(j: &Json) -> Result<StepEntry> {
    Ok(StepEntry {
        file: j
            .req("file")
            .map_err(|e| anyhow!(e))?
            .as_str()
            .ok_or_else(|| anyhow!("step file"))?
            .to_string(),
        sha256_16: j
            .get("sha256_16")
            .and_then(|x| x.as_str())
            .unwrap_or_default()
            .to_string(),
    })
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let body = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&body)
    }

    /// Parse manifest JSON text.
    pub fn parse(body: &str) -> Result<Manifest> {
        let j = Json::parse(body).map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut out = Manifest::default();

        if let Some(Json::Obj(models)) = j.get("models") {
            for (name, mj) in models {
                let mut layers = Vec::new();
                for lj in mj
                    .req("layers")
                    .map_err(|e| anyhow!(e))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("layers not array"))?
                {
                    let shape: Vec<usize> = lj
                        .req("shape")
                        .map_err(|e| anyhow!(e))?
                        .as_arr()
                        .ok_or_else(|| anyhow!("shape"))?
                        .iter()
                        .map(|x| x.as_usize().ok_or_else(|| anyhow!("shape dim")))
                        .collect::<Result<_>>()?;
                    layers.push(LayerMeta {
                        name: lj
                            .req("name")
                            .map_err(|e| anyhow!(e))?
                            .as_str()
                            .ok_or_else(|| anyhow!("layer name"))?
                            .to_string(),
                        shape,
                        role: parse_role(
                            lj.req("role")
                                .map_err(|e| anyhow!(e))?
                                .as_str()
                                .ok_or_else(|| anyhow!("role"))?,
                        )?,
                    });
                }
                let get_usize = |k: &str| -> Result<usize> {
                    mj.req(k)
                        .map_err(|e| anyhow!(e))?
                        .as_usize()
                        .ok_or_else(|| anyhow!("{k} not usize"))
                };
                out.models.insert(
                    name.clone(),
                    ModelEntry {
                        layers,
                        input_shape: mj
                            .req("input_shape")
                            .map_err(|e| anyhow!(e))?
                            .as_arr()
                            .ok_or_else(|| anyhow!("input_shape"))?
                            .iter()
                            .map(|x| x.as_usize().unwrap_or(0))
                            .collect(),
                        classes: get_usize("classes")?,
                        batch: get_usize("batch")?,
                        eval_batch: get_usize("eval_batch")?,
                        total_params: get_usize("total_params")?,
                        train_step: parse_step(mj.req("train_step").map_err(|e| anyhow!(e))?)?,
                        grad_step: parse_step(mj.req("grad_step").map_err(|e| anyhow!(e))?)?,
                        eval_step: parse_step(mj.req("eval_step").map_err(|e| anyhow!(e))?)?,
                    },
                );
            }
        }

        if let Some(Json::Obj(kernels)) = j.get("kernels") {
            for (key, kj) in kernels {
                let rank = kj
                    .get("k")
                    .or_else(|| kj.get("s"))
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("kernel {key}: missing k/s"))?;
                out.kernels.insert(
                    key.clone(),
                    KernelEntry {
                        file: kj
                            .req("file")
                            .map_err(|e| anyhow!(e))?
                            .as_str()
                            .ok_or_else(|| anyhow!("kernel file"))?
                            .to_string(),
                        kind: kj
                            .req("kind")
                            .map_err(|e| anyhow!(e))?
                            .as_str()
                            .ok_or_else(|| anyhow!("kind"))?
                            .to_string(),
                        l: kj.req("l").map_err(|e| anyhow!(e))?.as_usize().unwrap_or(0),
                        m: kj.req("m").map_err(|e| anyhow!(e))?.as_usize().unwrap_or(0),
                        rank,
                    },
                );
            }
        }
        Ok(out)
    }

    /// Find a kernel entry by kind and geometry.
    pub fn find_kernel(&self, kind: &str, l: usize, m: usize) -> Option<&KernelEntry> {
        self.kernels.values().find(|k| k.kind == kind && k.l == l && k.m == m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "lenet5": {
          "layers": [
            {"name": "conv1.kernel", "shape": [5,5,1,6], "role": "conv"},
            {"name": "conv1.bias", "shape": [6], "role": "bias"}
          ],
          "input_shape": [28,28,1], "classes": 10,
          "batch": 32, "eval_batch": 64, "total_params": 156,
          "train_step": {"file": "lenet5.train_step.hlo.txt", "sha256_16": "ab"},
          "grad_step": {"file": "lenet5.grad_step.hlo.txt", "sha256_16": "cd"},
          "eval_step": {"file": "lenet5.eval_step.hlo.txt", "sha256_16": "ef"}
        }
      },
      "kernels": {
        "project.96x48x8": {"file": "kernel.project.96x48x8.hlo.txt",
          "kind": "project", "l": 96, "m": 48, "k": 8},
        "sketch.96x48x14": {"file": "kernel.sketch.96x48x14.hlo.txt",
          "kind": "sketch", "l": 96, "m": 48, "s": 14}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let lenet = &m.models["lenet5"];
        assert_eq!(lenet.layers.len(), 2);
        assert_eq!(lenet.layers[0].shape, vec![5, 5, 1, 6]);
        assert_eq!(lenet.layers[0].role, LayerRole::ConvKernel);
        assert_eq!(lenet.batch, 32);
        assert_eq!(lenet.train_step.file, "lenet5.train_step.hlo.txt");
        assert_eq!(m.kernels["project.96x48x8"].rank, 8);
        assert_eq!(m.kernels["sketch.96x48x14"].rank, 14);
    }

    #[test]
    fn find_kernel_by_geometry() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find_kernel("project", 96, 48).is_some());
        assert!(m.find_kernel("project", 96, 49).is_none());
    }

    #[test]
    fn rejects_bad_role() {
        let bad = SAMPLE.replace("\"conv\"", "\"frobnicator\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
