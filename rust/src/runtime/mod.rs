//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust request path.
//!
//! The python side (`python/compile/aot.py`) runs once at build time and
//! lowers every L2 graph / L1 Pallas kernel to HLO *text* under
//! `artifacts/`, indexed by `manifest.json`. The PJRT half of this module
//! wraps the `xla` crate (PJRT C API, CPU plugin):
//!
//! ```text
//! PjRtClient::cpu() → HloModuleProto::from_text_file → client.compile → execute
//! ```
//!
//! Compilation happens lazily per artifact and is cached for the process
//! lifetime ([`Runtime`] is cheap to clone; executables are shared).
//!
//! # The `xla` cargo feature
//!
//! PJRT support is gated behind the off-by-default `xla` feature (the `xla`
//! crate is not vendored; see `rust/Cargo.toml`). Without the feature this
//! module still type-checks — [`HostTensor`] and the manifest schema are
//! pure Rust — but [`Runtime::open`] returns a clear runtime error, so any
//! configuration requesting artifacts (`use_xla = true`) fails fast with an
//! actionable message instead of a link error. The native trainer
//! ([`crate::nn::NativeTrainer`]) covers every CNN workload without it.

pub mod manifest;

pub use manifest::{KernelEntry, Manifest, ModelEntry, StepEntry};

use anyhow::{anyhow, Result};

/// A typed host tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    /// f32 data + dims.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data + dims.
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// f32 tensor.
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        HostTensor::F32(data, dims.to_vec())
    }

    /// i32 tensor.
    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        HostTensor::I32(data, dims.to_vec())
    }

    /// Scalar f32.
    pub fn scalar(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    /// Flat f32 view (errors for other dtypes).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Consume into flat f32 data.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// First element as f32.
    pub fn scalar_f32(&self) -> Result<f32> {
        Ok(self.as_f32()?.first().copied().unwrap_or(f32::NAN))
    }
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    use anyhow::{anyhow, Context, Result};

    use super::{HostTensor, Manifest};

    /// A compiled-and-loaded PJRT executable.
    pub type Executable = xla::PjRtLoadedExecutable;

    impl HostTensor {
        fn to_literal(&self) -> Result<xla::Literal> {
            match self {
                HostTensor::F32(data, dims) => {
                    let bytes: &[u8] = unsafe {
                        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        dims,
                        bytes,
                    )
                    .map_err(|e| anyhow!("literal f32: {e:?}"))
                }
                HostTensor::I32(data, dims) => {
                    let bytes: &[u8] = unsafe {
                        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        dims,
                        bytes,
                    )
                    .map_err(|e| anyhow!("literal i32: {e:?}"))
                }
            }
        }

        fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
            let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            match shape.ty() {
                xla::ElementType::F32 => {
                    let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
                    Ok(HostTensor::F32(v, dims))
                }
                xla::ElementType::S32 => {
                    let v = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
                    Ok(HostTensor::I32(v, dims))
                }
                other => Err(anyhow!("unsupported output dtype {other:?}")),
            }
        }
    }

    struct Inner {
        client: xla::PjRtClient,
        dir: PathBuf,
        manifest: Manifest,
        cache: Mutex<HashMap<String, Arc<Executable>>>,
    }

    /// Shared handle to the PJRT CPU client + compiled-executable cache.
    #[derive(Clone)]
    pub struct Runtime {
        inner: Arc<Inner>,
    }

    impl Runtime {
        /// Open the artifacts directory (must contain `manifest.json`).
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::load(&dir.join("manifest.json"))
                .with_context(|| format!("loading manifest from {}", dir.display()))?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            Ok(Runtime {
                inner: Arc::new(Inner {
                    client,
                    dir,
                    manifest,
                    cache: Mutex::new(HashMap::new()),
                }),
            })
        }

        /// The parsed manifest.
        pub fn manifest(&self) -> &Manifest {
            &self.inner.manifest
        }

        /// PJRT platform name (e.g. "Host" for the CPU plugin).
        pub fn platform(&self) -> String {
            self.inner.client.platform_name()
        }

        /// Compile (or fetch from cache) the artifact stored in `file`.
        pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
            {
                let cache = self.inner.cache.lock().unwrap();
                if let Some(exe) = cache.get(file) {
                    return Ok(exe.clone());
                }
            }
            let path = self.inner.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Arc::new(
                self.inner
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?,
            );
            self.inner.cache.lock().unwrap().insert(file.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute an artifact with host tensors; returns the tuple elements.
        ///
        /// All artifacts are lowered with `return_tuple=True`, so the single
        /// output literal is decomposed into its elements.
        pub fn call(&self, file: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let exe = self.load(file)?;
            self.call_exe(&exe, inputs)
        }

        /// Execute an already-loaded executable.
        pub fn call_exe(
            &self,
            exe: &Executable,
            inputs: &[HostTensor],
        ) -> Result<Vec<HostTensor>> {
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let outputs = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let buffer = outputs
                .first()
                .and_then(|replica| replica.first())
                .ok_or_else(|| anyhow!("empty execution result"))?;
            let tuple = buffer
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let parts = tuple.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
            parts.iter().map(HostTensor::from_literal).collect()
        }
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Runtime")
                .field("dir", &self.inner.dir)
                .field("models", &self.inner.manifest.models.len())
                .field("kernels", &self.inner.manifest.kernels.len())
                .finish()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{anyhow, Result};

    use super::{HostTensor, Manifest};

    /// Opaque compiled-executable handle. Uninhabited without the `xla`
    /// feature: code that stores or passes one still type-checks, but no
    /// value can ever exist.
    pub enum Executable {}

    enum Never {}

    /// Stub runtime compiled when the `xla` feature is off. Uninhabited —
    /// [`Runtime::open`] is the only constructor and it always returns a
    /// descriptive error, so every artifact-requiring path fails fast with
    /// an actionable message.
    pub struct Runtime {
        never: Never,
    }

    impl Runtime {
        /// Always fails: this build has no PJRT support.
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            Err(anyhow!(
                "XLA artifacts at '{}' were requested, but this binary was built without \
                 PJRT support (the off-by-default `xla` cargo feature). Either run with \
                 the native backend (--native on the CLI, or use_xla = false in the \
                 config), or add the `xla` crate to rust/Cargo.toml and rebuild with \
                 `cargo build --features xla`.",
                dir.as_ref().display()
            ))
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn manifest(&self) -> &Manifest {
            match self.never {}
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn platform(&self) -> String {
            match self.never {}
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn load(&self, _file: &str) -> Result<Arc<Executable>> {
            match self.never {}
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn call(&self, _file: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            match self.never {}
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn call_exe(
            &self,
            _exe: &Executable,
            _inputs: &[HostTensor],
        ) -> Result<Vec<HostTensor>> {
            match self.never {}
        }
    }

    impl Clone for Runtime {
        fn clone(&self) -> Self {
            match self.never {}
        }
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, _f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            match self.never {}
        }
    }
}

pub use pjrt::{Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        let t = HostTensor::f32(vec![1.0, 2.0], &[2]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.scalar_f32().is_ok());
    }

    #[test]
    #[should_panic]
    fn host_tensor_bad_shape_panics() {
        let _ = HostTensor::f32(vec![1.0, 2.0], &[3]);
    }

    #[test]
    fn i32_tensor_not_f32() {
        let t = HostTensor::i32(vec![1, 2], &[2]);
        assert!(t.as_f32().is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn open_without_feature_gives_actionable_error() {
        let err = Runtime::open("artifacts").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("xla"), "{msg}");
        assert!(msg.contains("--native"), "{msg}");
    }
}
