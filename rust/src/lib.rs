//! # GradESTC — communication-efficient federated learning
//!
//! A reproduction of *"Communication-Efficient Federated Learning by
//! Exploiting Spatio-Temporal Correlations of Gradients"* (Zheng et al.,
//! 2026) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator: round
//!   scheduling, client sampling, local-training orchestration, gradient
//!   compression (GradESTC + baselines), aggregation, and exact
//!   communication accounting.
//! * **Layer 2** — JAX model definitions (`python/compile/model.py`) lowered
//!   once to HLO text and executed from Rust via PJRT (see [`runtime`]).
//! * **Layer 1** — Pallas kernels for the compression hot path
//!   (`python/compile/kernels/`), lowered into the same artifacts.
//!
//! Python is build-time only; the round loop is pure Rust + XLA.
//!
//! The runtime is organized as nine planes — round engine → wire/network
//! → compressed-domain aggregation → scheduler → basis pool → compute
//! backend → telemetry → virtual lanes → diagnostics — each with its own
//! invariants;
//! the top-level `ARCHITECTURE.md` maps them, with per-scheduler
//! data-flow diagrams and the "where does a byte get charged"
//! walkthrough.
//!
//! ## Quick tour
//!
//! ```no_run
//! use gradestc::config::ExperimentConfig;
//! use gradestc::coordinator::Simulation;
//!
//! let mut cfg = ExperimentConfig::preset_quickstart();
//! cfg.workers = 0; // 0 = auto: GRADESTC_WORKERS env var, else CPU count
//! let mut sim = Simulation::build(cfg).unwrap();
//! let report = sim.run().unwrap();
//! println!("best accuracy {:.2}%", report.best_accuracy * 100.0);
//! ```
//!
//! The round engine ([`coordinator::engine`]) fans each round's per-client
//! phase — local SGD, compression, server-side decoding — across worker
//! threads, then aggregates **in the compressed domain**: the server never
//! inflates a survivor's payload into a dense model. Decoding returns
//! typed [`compress::LayerUpdate`]s (low-rank factors, sparse pairs,
//! packed quantization codes) and the
//! [`coordinator::ServerAggregator`] folds them straight into per-layer
//! accumulators — fusing low-rank reconstruction `Ĝ = M·A` with the
//! weighted FedAvg reduction via [`linalg::matmul_acc`] — so the server
//! phase peaks at `O(model)` memory instead of `O(survivors × model)`.
//! Dense per-client updates materialize only when a round hook (the
//! Fig. 1 probe) is installed.
//! Parallelism is controlled by `ExperimentConfig::workers` (`--workers` on
//! the CLI): `0` resolves to the `GRADESTC_WORKERS` environment variable or
//! the available CPU count, `1` runs fully sequential, and any value
//! produces bit-identical results — compressor state on both ends evolves
//! in lockstep no matter the execution order. The XLA backend runs its
//! lanes on the coordinator thread (PJRT handles don't cross threads), also
//! with identical results.
//!
//! ## The compute-backend plane ([`linalg`])
//!
//! The dense kernels under all of the above — the compressor projection
//! `A = MᵀG`, the fused server fold `Acc += α·M·A`, the QR/MGS/rSVD
//! panels — dispatch through the pluggable [`linalg::Backend`] trait.
//! Two CPU implementations ship: [`linalg::ScalarBackend`] (the original
//! loops, frozen as the bit-identity reference) and
//! [`linalg::BlockedBackend`] (cache-blocked, register-tiled,
//! SIMD-friendly — the default). Select per experiment with
//! `--backend auto|scalar|blocked` (`ExperimentConfig::backend`, JSON
//! `"backend"`); `auto` resolves to the `GRADESTC_BACKEND` environment
//! variable if set, else the blocked kernels. Every backend keeps the
//! same contract as the round engine: its reduction order is a pure
//! function of problem shape, never of worker count, so w1-vs-wN
//! determinism holds on any backend (`rust/tests/backend.rs`), and both
//! ends of a compressor lane always run the same backend so client and
//! server basis evolution replay identical arithmetic.
//!
//! ## The scheduler plane ([`sched`])
//!
//! Round *control flow* is pluggable (`--sched` on the CLI, the `"sched"`
//! JSON object, `ExperimentConfig::sched`), driven by a deterministic
//! discrete-event engine — a min-heap keyed `(f64 time, u64 seq)` with
//! [`f64::total_cmp`] and a push-order tie-break, so replay is
//! bit-identical at any worker count:
//!
//! * `--sched sync` — lockstep FedAvg, bit-identical to the legacy engine
//!   (it *is* [`coordinator::Simulation::step`]).
//! * `--sched semisync` — aggregate whatever arrived by the straggler
//!   deadline; late updates roll into the round open when they land
//!   instead of being discarded, and are charged exactly once.
//! * `--sched async:k=8,staleness=0.5` — FedBuff-style buffered
//!   asynchrony: each arriving update is folded into the
//!   [`coordinator::ServerAggregator`] as it lands, the model applies
//!   after every `k` arrivals, and an update `τ` versions stale is
//!   down-weighted by `1/(1+τ)^p` (`p` = `staleness`). Async honors
//!   `ExperimentConfig::participation` as a concurrency bound: only
//!   `round(participation · n)` clients are in flight at once, freed
//!   slots refill by uniform draws over the idle pool — so populations
//!   far larger than the working set are meaningful (see [`sched`]).
//!
//! Client completion times are `compute draw + LinkProfile round trip`
//! on the client's own link; the per-dispatch compute draw
//! ([`sched::ComputeModel`], `--compute-s`/`--compute-spread`) is a pure
//! function of `(seed, dispatch, cid)` like the dropout model. Every
//! record carries the virtual clock ([`metrics::RoundRecord::sim_clock_s`],
//! CSV column `sim_clock_s`) for time-to-accuracy plots;
//! `gradestc exp async1` compares the three control flows under
//! heterogeneous links.
//!
//! ## The network boundary ([`net`])
//!
//! All coordinator↔client traffic crosses the [`net::Transport`] as real
//! byte buffers: the broadcast is encoded with
//! [`net::wire::encode_params`], each client's compressed update is
//! encoded with [`net::wire::encode`] (whose output length *defines*
//! [`compress::Payload::wire_bytes`] — property-tested), and the
//! communication ledger is charged from the drained frames. Per-client
//! [`net::LinkProfile`]s (heterogeneous when `net.het_spread > 0`), a
//! per-round client-dropout model, and a straggler deadline are configured
//! through `ExperimentConfig::net` (`--dropout`, `--deadline`,
//! `--up-mbps`, `--down-mbps`, `--latency-ms`, `--het-spread` on the CLI).
//! The defaults — homogeneous edge links, no dropout, no deadline — are
//! byte- and bit-identical to the pre-transport engine.
//!
//! ## Module map
//!
//! * [`compress`] — GradESTC + every baseline compressor
//!   ([`compress::Payload`] on the wire, [`compress::LayerUpdate`] after
//!   the server decode, and [`compress::intern`]'s [`compress::BasisPool`]
//!   — one allocation per *distinct* server-side basis across the whole
//!   population).
//! * [`config`] — typed experiment configs, JSON round-tripping, presets.
//! * [`coordinator`] — the staged round engine,
//!   [`coordinator::ServerAggregator`] (compressed-domain FedAvg),
//!   [`coordinator::Simulation`], and the virtual-lane plane
//!   ([`coordinator::LanePool`] — lanes derived from `(seed, cid)` on
//!   first dispatch, LRU-bounded via `--lane-cap`, lazy ≡ eager
//!   bit-identically).
//! * [`data`] — synthetic datasets and non-IID partitioning.
//! * [`diag`] — the diagnostics plane: streaming estimators of the
//!   gradient structure the paper assumes (subspace drift via principal
//!   angles, adjacent-round cosine, compression-fidelity NRMSE,
//!   bytes-per-loss), driven by [`telemetry::DiagProbe`] and exported
//!   as `diag.csv` / a metrics-JSON section behind `--diag`.
//! * [`linalg`] — dense matrix kernels (rSVD, MGS, fused
//!   [`linalg::matmul_acc`]) for the compressors and the aggregation
//!   plane, dispatched through the pluggable [`linalg::Backend`]
//!   compute plane (`--backend`, `GRADESTC_BACKEND`).
//! * [`metrics`] — round records, CSV sinks, [`metrics::CommLedger`],
//!   heterogeneous [`metrics::NetworkModel`].
//! * [`model`] — layer tables and flat parameter stores.
//! * [`net`] — wire codec, link/dropout simulation, [`net::Transport`],
//!   and the per-model-version [`net::BroadcastCache`] every scheduler
//!   fetches broadcast frames through.
//! * [`nn`] — the native reference trainer.
//! * [`runtime`] — PJRT/XLA artifact execution (feature-gated).
//! * [`sched`] — the scheduler plane: deterministic event queue
//!   ([`sched::EventQueue`]) and the sync / semi-sync / async-buffered
//!   round control flows on a virtual clock.
//! * [`telemetry`] — the observability plane: dual-clock span tracing
//!   ([`telemetry::Telemetry`], Chrome-trace/JSONL/metrics-JSON
//!   exporters behind `--trace`/`--metrics`) and the streaming
//!   [`telemetry::Observer`] probe API called from every scheduler.
//!   Zero-cost when disabled; observation never perturbs results.
//! * [`util`] — RNG, CLI args, bench harness, property testing, thread pool.
//!
//! See `examples/` for runnable end-to-end drivers, `ARCHITECTURE.md`
//! (repo root) for the nine-plane system map, and `docs/EXPERIMENTS.md`
//! for the experiment catalogue.

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod diag;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod nn;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod util;

/// Crate-wide result alias (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
