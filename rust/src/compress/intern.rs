//! Basis interning: one allocation per *distinct* basis, shared across
//! every server-side lane that holds it.
//!
//! GradESTC's whole premise is that the basis `M` is shared structure —
//! spatially across a layer's segments, temporally across rounds — yet a
//! naive server stores one full decompressor basis per client lane, so
//! resident memory is `O(clients × basis)` and a 10⁴–10⁶-client population
//! (the scheduler plane's headroom) is unreachable. [`BasisPool`] is the
//! memory lever: a content-addressed pool keyed by the same FNV-1a
//! fingerprint the lockstep tests already use (the crate-internal
//! `basis_fingerprint` over dims + element bits), handing out
//! [`BasisHandle`]s — `Arc<Mat>` plus the content key —
//! so per-lane state shrinks to a pointer and a fingerprint:
//!
//! * **Dedup**: interning bit-identical content returns the *same*
//!   allocation. N lanes whose clients sent the same basis (SVDFed's
//!   globally-shared basis, identical shards, a warm-started fleet) cost
//!   one entry, not N.
//! * **Copy-on-write**: a lane updating its basis takes the matrix out of
//!   its handle ([`BasisHandle::into_mat`]) — zero-copy when the lane is
//!   the only owner, a clone when the allocation is still shared by
//!   another lane or by an in-flight
//!   [`LayerUpdate::LowRank`](super::LayerUpdate) snapshot — mutates it,
//!   and re-interns the result. Divergent updates therefore split shared
//!   entries; convergent updates re-dedupe.
//! * **No leak, no retention**: the pool holds only [`Weak`] references.
//!   Dropping the last handle (a lane being dropped, a basis being
//!   replaced) frees the matrix immediately; [`BasisPool::stats`] sweeps
//!   dead entries as it counts.
//!
//! The pool is `Send + Sync` (the server decode phase fans lanes across
//! worker threads) and never affects *values*: interning only decides
//! which allocation bit-identical content lives in, so round records and
//! state fingerprints are unchanged at any worker count. Fingerprint
//! collisions are handled, not assumed away: each key maps to a bucket of
//! candidates and interning compares full content before sharing.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, Weak};

use super::basis_fingerprint;
use crate::linalg::Mat;

/// Shared, thread-safe pool of interned basis matrices. Cloning the pool
/// clones the *handle* (all clones see one underlying store).
#[derive(Clone, Debug, Default)]
pub struct BasisPool {
    inner: Arc<Mutex<HashMap<u64, Vec<Weak<Mat>>>>>,
}

/// One lane's ownership of an interned basis: the shared allocation plus
/// its content fingerprint. This — not a `Mat` — is what server-side
/// decompressor state holds per compressed layer.
#[derive(Clone, Debug)]
pub struct BasisHandle {
    mat: Arc<Mat>,
    fp: u64,
}

/// Live-pool summary (after sweeping dead entries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolStats {
    /// Distinct live basis matrices.
    pub entries: usize,
    /// Total f32 elements across live entries.
    pub floats: usize,
}

impl PoolStats {
    /// Resident bytes of the live entries' element storage.
    pub fn bytes(&self) -> usize {
        self.floats * std::mem::size_of::<f32>()
    }
}

/// Content key of one matrix: dims word + every element's bit pattern,
/// FNV-1a — the same stream the lane-lockstep fingerprints hash, so a
/// pool key and a single-layer state fingerprint agree by construction.
fn content_key(mat: &Mat) -> u64 {
    basis_fingerprint(std::iter::once(Some(mat)))
}

impl BasisPool {
    /// Fresh empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a matrix: returns a handle to an existing allocation when
    /// bit-identical content is already pooled, otherwise adopts `mat` as
    /// a new entry. Opportunistically sweeps dead entries from the bucket
    /// it touches.
    pub fn intern(&self, mat: Mat) -> BasisHandle {
        let fp = content_key(&mat);
        let mut inner = self.inner.lock().expect("basis pool poisoned");
        let bucket = inner.entry(fp).or_default();
        bucket.retain(|w| w.strong_count() > 0);
        for weak in bucket.iter() {
            if let Some(existing) = weak.upgrade() {
                // Equal fingerprints almost always mean equal content, but
                // the pool must be correct under collisions too.
                if *existing == mat {
                    return BasisHandle { mat: existing, fp };
                }
            }
        }
        let arc = Arc::new(mat);
        bucket.push(Arc::downgrade(&arc));
        BasisHandle { mat: arc, fp }
    }

    /// Total weak slots currently resident in the store — live *and* dead
    /// (a dead slot is a dropped basis whose `Weak` hasn't been swept
    /// yet). Purely observational: no sweep, no allocation churn. The gap
    /// `resident_slots() - stats().entries` is exactly the garbage a
    /// sweep would reclaim; the telemetry plane's per-round snapshot
    /// calls [`BasisPool::stats`] every round precisely so this gap can't
    /// grow unboundedly between probes (regression-tested below).
    pub fn resident_slots(&self) -> usize {
        let inner = self.inner.lock().expect("basis pool poisoned");
        inner.values().map(|bucket| bucket.len()).sum()
    }

    /// Drop dead weak slots without computing stats. The lane pool calls
    /// this after an eviction batch: evicted lanes release their basis
    /// handles, and without a sweep the dead `Weak`s would accumulate
    /// O(lifetime materializations) between the telemetry plane's
    /// per-round [`BasisPool::stats`] sweeps (or forever, untraced).
    pub fn sweep(&self) {
        let mut inner = self.inner.lock().expect("basis pool poisoned");
        inner.retain(|_, bucket| {
            bucket.retain(|w| w.strong_count() > 0);
            !bucket.is_empty()
        });
    }

    /// Live entry count / element total. Sweeps dead entries first, so a
    /// dropped lane's bases stop counting the moment the last handle goes.
    pub fn stats(&self) -> PoolStats {
        let mut inner = self.inner.lock().expect("basis pool poisoned");
        let mut entries = 0usize;
        let mut floats = 0usize;
        inner.retain(|_, bucket| {
            bucket.retain(|w| w.strong_count() > 0);
            for weak in bucket.iter() {
                if let Some(mat) = weak.upgrade() {
                    entries += 1;
                    floats += mat.as_slice().len();
                }
            }
            !bucket.is_empty()
        });
        PoolStats { entries, floats }
    }
}

impl BasisHandle {
    /// Borrow the interned matrix.
    pub fn as_mat(&self) -> &Mat {
        &self.mat
    }

    /// Content fingerprint (the pool key).
    pub fn fingerprint(&self) -> u64 {
        self.fp
    }

    /// A shared `Arc` snapshot — what
    /// [`LayerUpdate::LowRank`](super::LayerUpdate) carries into the
    /// aggregation plane. O(1); keeps this round's view immutable while
    /// the lane's next update re-interns a successor.
    pub fn share(&self) -> Arc<Mat> {
        Arc::clone(&self.mat)
    }

    /// Take the matrix out for mutation (the copy-on-write step):
    /// zero-copy when this handle is the sole owner, a content clone when
    /// the allocation is still shared by another lane or an in-flight
    /// aggregate snapshot. The caller mutates and re-interns.
    pub fn into_mat(self) -> Mat {
        // The pool holds only Weaks, so "sole owner" is exactly "no other
        // lane and no in-flight LowRank snapshot".
        Arc::try_unwrap(self.mat).unwrap_or_else(|shared| (*shared).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn mat(seed: u64, l: usize, k: usize) -> Mat {
        Mat::randn(l, k, &mut Pcg64::seeded(seed))
    }

    #[test]
    fn identical_content_dedupes_to_one_entry() {
        let pool = BasisPool::new();
        let handles: Vec<BasisHandle> =
            (0..16).map(|_| pool.intern(mat(1, 12, 4))).collect();
        let stats = pool.stats();
        assert_eq!(stats.entries, 1, "16 identical basis copies must pool to one");
        assert_eq!(stats.floats, 12 * 4);
        // All handles share the same allocation, not just equal content.
        assert!(handles
            .iter()
            .all(|h| Arc::ptr_eq(&h.share(), &handles[0].share())));
        assert!(handles.iter().all(|h| h.fingerprint() == handles[0].fingerprint()));
    }

    #[test]
    fn distinct_content_gets_distinct_entries() {
        let pool = BasisPool::new();
        let a = pool.intern(mat(1, 8, 3));
        let b = pool.intern(mat(2, 8, 3));
        assert_eq!(pool.stats().entries, 2);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert!(!Arc::ptr_eq(&a.share(), &b.share()));
    }

    #[test]
    fn cow_take_is_zero_copy_when_sole_owner() {
        let pool = BasisPool::new();
        let h = pool.intern(mat(3, 6, 2));
        let buf = h.as_mat().as_slice().as_ptr() as usize;
        let m = h.into_mat(); // sole owner: the element buffer moves, no clone
        assert_eq!(m.as_slice().as_ptr() as usize, buf);
        // …and re-interning adopts it as a live entry again.
        let h2 = pool.intern(m);
        assert_eq!(h2.as_mat().as_slice().as_ptr() as usize, buf);
        assert_eq!(pool.stats().entries, 1);
    }

    #[test]
    fn divergent_update_splits_shared_entry() {
        let pool = BasisPool::new();
        let a = pool.intern(mat(4, 6, 2));
        let b = pool.intern(mat(4, 6, 2));
        assert_eq!(pool.stats().entries, 1);
        // Lane B diverges: COW must clone (A still shares the original).
        let mut m = b.into_mat();
        m.as_mut_slice()[0] += 1.0;
        let b2 = pool.intern(m);
        assert_eq!(pool.stats().entries, 2, "divergence must split the entry");
        assert_ne!(a.fingerprint(), b2.fingerprint());
        assert_ne!(a.as_mat(), b2.as_mat());
        // A's view never observed B's mutation.
        assert_eq!(*a.as_mat(), mat(4, 6, 2));
    }

    #[test]
    fn reconvergent_update_rededupes() {
        let pool = BasisPool::new();
        let a = pool.intern(mat(5, 4, 2));
        let mut m = pool.intern(mat(5, 4, 2)).into_mat();
        let orig = m.as_slice()[0];
        m.as_mut_slice()[0] = 42.0; // diverge…
        m.as_mut_slice()[0] = orig; // …and come back bit-identically
        let b = pool.intern(m);
        assert_eq!(pool.stats().entries, 1);
        assert!(Arc::ptr_eq(&a.share(), &b.share()));
    }

    #[test]
    fn dropping_last_handle_removes_entry() {
        let pool = BasisPool::new();
        let a = pool.intern(mat(6, 10, 3));
        let b = a.clone();
        drop(a);
        assert_eq!(pool.stats().entries, 1, "entry lives while any handle does");
        drop(b);
        assert_eq!(pool.stats(), PoolStats { entries: 0, floats: 0 });
    }

    #[test]
    fn in_flight_snapshot_keeps_entry_alive_and_forces_cow() {
        let pool = BasisPool::new();
        let h = pool.intern(mat(7, 5, 2));
        let snapshot = h.share(); // e.g. a LayerUpdate::LowRank in the aggregate
        let ptr = Arc::as_ptr(&snapshot) as usize;
        let mut m = h.into_mat(); // shared ⇒ clone, snapshot untouched
        m.as_mut_slice()[1] = 42.0;
        let h2 = pool.intern(m);
        assert_ne!(Arc::as_ptr(&h2.share()) as usize, ptr);
        assert_eq!(*snapshot, mat(7, 5, 2), "snapshot must not see the mutation");
        assert_eq!(pool.stats().entries, 2);
    }

    #[test]
    fn stats_sweep_reclaims_dead_slots() {
        // The sweep only ever ran inside `stats()` / the touched intern
        // bucket, so a pool that is never *asked* for stats accumulates
        // dead weak slots without bound. The telemetry round snapshot
        // drives `stats()` every round; this locks in that one such call
        // fully reclaims the garbage (and that the reported numbers can't
        // include freed bases).
        let pool = BasisPool::new();
        let handles: Vec<BasisHandle> =
            (0..8).map(|i| pool.intern(mat(100 + i, 6, 2))).collect();
        assert_eq!(pool.resident_slots(), 8);
        drop(handles); // all bases freed — but the weak slots linger…
        assert_eq!(pool.resident_slots(), 8, "no sweep happens on drop");
        let stats = pool.stats(); // …until one stats() sweep reclaims them
        assert_eq!(stats, PoolStats { entries: 0, floats: 0 });
        assert_eq!(pool.resident_slots(), 0, "stats() must sweep dead slots");
    }

    #[test]
    fn pool_clone_shares_one_store() {
        let pool = BasisPool::new();
        let view = pool.clone();
        let _h = pool.intern(mat(8, 3, 3));
        assert_eq!(view.stats().entries, 1);
    }
}
