//! Top-k magnitude sparsification baseline (Stich et al. 2018).
//!
//! Keeps the largest-|x| `frac` fraction of each compressible tensor's
//! entries (at least 1), sends (index, value) pairs; small tensors
//! (biases, norms) pass through raw, mirroring how the paper applies every
//! compressor only to the parameter-dominant weight tensors.

use super::codec::Payload;
use super::{CompressStats, Compressor, Decompressor};
use crate::model::meta::ModelMeta;

/// Minimum tensor size worth sparsifying (below this, raw is cheaper).
const MIN_SPARSE: usize = 256;

/// Client side.
pub struct TopKCompressor {
    frac: f64,
    compressible: Vec<bool>,
}

impl TopKCompressor {
    /// `frac` = kept fraction of entries (paper: 0.10 / 0.20).
    pub fn new(meta: &ModelMeta, frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "topk frac must be in (0,1]");
        TopKCompressor {
            frac,
            compressible: meta
                .layers
                .iter()
                .map(|l| l.compressible() && l.size() >= MIN_SPARSE)
                .collect(),
        }
    }
}

/// Select the `keep` largest-magnitude entries; returns sorted indices.
fn top_indices(data: &[f32], keep: usize) -> Vec<u32> {
    let keep = keep.clamp(1, data.len());
    // Partial selection via select_nth on an index permutation.
    let mut idx: Vec<u32> = (0..data.len() as u32).collect();
    let kth = keep - 1;
    idx.select_nth_unstable_by(kth, |&a, &b| {
        data[b as usize]
            .abs()
            .partial_cmp(&data[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut top: Vec<u32> = idx[..keep].to_vec();
    top.sort_unstable();
    top
}

impl Compressor for TopKCompressor {
    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats) {
        let payloads = update
            .iter()
            .zip(&self.compressible)
            .map(|(t, &comp)| {
                if !comp {
                    return Payload::Raw(t.clone());
                }
                let keep = ((t.len() as f64 * self.frac).round() as usize).max(1);
                let indices = top_indices(t, keep);
                let values = indices.iter().map(|&i| t[i as usize]).collect();
                Payload::Sparse { indices, values, len: t.len() }
            })
            .collect();
        (payloads, CompressStats::default())
    }
}

/// Server side.
pub struct TopKDecompressor {
    sizes: Vec<usize>,
}

impl TopKDecompressor {
    /// Build for a model.
    pub fn new(meta: &ModelMeta) -> Self {
        TopKDecompressor { sizes: meta.layers.iter().map(|l| l.size()).collect() }
    }
}

impl Decompressor for TopKDecompressor {
    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<super::LayerUpdate> {
        payloads
            .into_iter()
            .zip(&self.sizes)
            .map(|(p, &n)| match p {
                Payload::Raw(v) => super::LayerUpdate::Dense(v),
                Payload::Sparse { indices, values, len } => {
                    assert_eq!(len, n);
                    // Stays sparse: the aggregation plane scatter-adds the
                    // kept entries without densifying.
                    super::LayerUpdate::Sparse { indices, values, len }
                }
                other => panic!("TopKDecompressor got {other:?}"),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::model::meta::layer_table;
    use crate::util::prop::{check, VecF32};
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_largest_entries() {
        let data = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let idx = top_indices(&data, 2);
        assert_eq!(idx, vec![1, 3]);
    }

    #[test]
    fn roundtrip_preserves_topk_zeroes_rest() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(1);
        let update: Vec<Vec<f32>> =
            meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
        let mut c = TopKCompressor::new(&meta, 0.1);
        let (payloads, _) = c.compress(&update);
        let mut d = TopKDecompressor::new(&meta);
        let rec = d.decompress(&payloads);
        for ((orig, r), layer) in update.iter().zip(&rec).zip(&meta.layers) {
            if layer.compressible() && layer.size() >= MIN_SPARSE {
                let nonzero = r.iter().filter(|&&x| x != 0.0).count();
                let expect = ((layer.size() as f64) * 0.1).round() as usize;
                assert!((nonzero as i64 - expect as i64).abs() <= 1, "{}", layer.name);
                // kept values match the original
                for (o, v) in orig.iter().zip(r) {
                    assert!(*v == 0.0 || v == o);
                }
            } else {
                assert_eq!(orig, r);
            }
        }
    }

    #[test]
    fn sparse_payload_smaller_than_raw() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(2);
        let update: Vec<Vec<f32>> =
            meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
        let raw_bytes: u64 = update.iter().map(|t| 4 * t.len() as u64).sum();
        let mut c = TopKCompressor::new(&meta, 0.1);
        let (payloads, _) = c.compress(&update);
        let wire: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
        assert!(wire < raw_bytes / 2, "wire {wire} raw {raw_bytes}");
    }

    #[test]
    fn property_reconstruction_error_bounded_by_dropped_mass() {
        // ||x - topk(x)||² must equal the sum of squares of dropped entries
        // (exactly, as top-k keeps originals).
        let gen = VecF32 { min_len: 300, max_len: 600, scale: 2.0 };
        check("topk_error_identity", 42, 30, &gen, |v| {
            let keep = (v.len() / 10).max(1);
            let idx = top_indices(v, keep);
            let kept: std::collections::HashSet<u32> = idx.into_iter().collect();
            let dropped_sq: f64 = v
                .iter()
                .enumerate()
                .filter(|(i, _)| !kept.contains(&(*i as u32)))
                .map(|(_, &x)| (x as f64) * (x as f64))
                .sum();
            let max_kept_sq = v
                .iter()
                .enumerate()
                .filter(|(i, _)| kept.contains(&(*i as u32)))
                .map(|(_, &x)| (x as f64) * (x as f64))
                .fold(f64::INFINITY, f64::min);
            // every dropped entry ≤ every kept entry in magnitude
            v.iter()
                .enumerate()
                .filter(|(i, _)| !kept.contains(&(*i as u32)))
                .all(|(_, &x)| (x as f64) * (x as f64) <= max_kept_sq + 1e-12)
                && dropped_sq.is_finite()
        });
    }
}
