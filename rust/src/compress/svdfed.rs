//! SVDFed-style baseline (Wang et al., INFOCOM 2023).
//!
//! SVDFed captures a *shared* low-rank gradient representation via SVD:
//! a basis is fit once from warm-up gradients, clients then uplink only
//! combination coefficients, and the basis is re-fit (full re-transmission)
//! when the fitting quality degrades past a threshold — the γ knob.
//!
//! Faithful deviation: the original fits one
//! basis server-side from all clients' round-1 gradients; this
//! implementation fits per-client bases from each client's own round-1
//! gradient. That is the *stronger* variant (a personalized basis fits at
//! least as well as a shared one), so the baseline is not handicapped;
//! what it preserves is SVDFed's defining behaviour — a static basis
//! between expensive refreshes — whose staleness under drift is exactly
//! what GradESTC's incremental updates fix.
//!
//! # Basis ownership and lifecycle
//!
//! The client owns its `Mat` outright and re-fits it wholesale when the
//! relative fitting error crosses γ. The server holds a
//! [`BasisHandle`](crate::compress::BasisHandle) per compressed layer into
//! the simulation-wide [`BasisPool`](crate::compress::BasisPool):
//! coefficient-only rounds (SVDFed's steady state between refreshes —
//! *most* rounds by design) leave the handle untouched, and a refit
//! payload interns the freshly-received basis, so lanes whose clients
//! transmit bit-identical bases (SVDFed's original globally-shared-basis
//! regime, or identical shards) collapse to one allocation. Decoding
//! returns [`LayerUpdate::LowRank`] factor snapshots — the aggregation
//! plane fuses `Ĝ = M·A` into the FedAvg fold; nothing densifies here
//! (the pre-aggregation-plane decode path that inflated `Ĝ` per client is
//! gone). Fingerprints hash the same basis bits on both ends of the lane,
//! so client/server lockstep is externally checkable exactly as for
//! GradESTC.

use super::codec::Payload;
use super::intern::{BasisHandle, BasisPool};
use super::{
    assemble_updates, basis_fingerprint, CompressStats, Compressor, Decompressor, LayerUpdate,
    SegmentGeom,
};
use crate::config::GradEstcParams;
use crate::linalg::{default_backend, randomized_svd_in, Backend, Mat, RsvdOptions};
use crate::model::meta::ModelMeta;
use crate::util::rng::Pcg64;

// Reuse GradESTC's geometry helpers: same segmentation, same layer picks.
use super::gradestc::geometry::{layer_geoms, to_g, LayerGeom};

struct LayerState {
    geom: LayerGeom,
    basis: Option<Mat>,
}

/// Server-side layer state: the basis is a handle into the shared
/// [`BasisPool`], so decoded [`LayerUpdate::LowRank`]s borrow it at O(1)
/// and bit-identical bases across lanes share one allocation.
struct ServerLayerState {
    geom: LayerGeom,
    basis: Option<BasisHandle>,
}

/// Client-side SVDFed compressor.
pub struct SvdFedCompressor {
    layers: Vec<LayerState>,
    ntensors: usize,
    /// Relative fitting error that triggers a basis re-fit.
    gamma: f64,
    rng: Pcg64,
    backend: &'static dyn Backend,
}

impl SvdFedCompressor {
    /// `k` = basis rank; `gamma` = relative-error refresh threshold.
    /// Uses the process-default compute backend; see [`Self::with_backend`].
    pub fn new(meta: &ModelMeta, k: usize, gamma: f64, seed: u64) -> Self {
        Self::with_backend(meta, k, gamma, seed, default_backend())
    }

    /// [`Self::new`] pinned to an explicit compute backend.
    pub fn with_backend(
        meta: &ModelMeta,
        k: usize,
        gamma: f64,
        seed: u64,
        backend: &'static dyn Backend,
    ) -> Self {
        let params = GradEstcParams { k, ..Default::default() };
        SvdFedCompressor {
            layers: layer_geoms(meta, &params)
                .into_iter()
                .map(|geom| LayerState { geom, basis: None })
                .collect(),
            ntensors: meta.layers.len(),
            gamma,
            rng: Pcg64::new(seed, 0x57DF),
            backend,
        }
    }

    fn fit_basis(bk: &dyn Backend, g: &Mat, k: usize, rng: &mut Pcg64) -> Mat {
        let svd = randomized_svd_in(bk, g, k, RsvdOptions::default(), rng);
        let mut basis = Mat::zeros(g.rows(), k);
        for j in 0..svd.s.len() {
            basis.set_col(j, &svd.u.col(j));
        }
        basis
    }
}

impl Compressor for SvdFedCompressor {
    fn state_fingerprint(&self) -> u64 {
        basis_fingerprint(self.layers.iter().map(|s| s.basis.as_ref()))
    }

    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats) {
        assert_eq!(update.len(), self.ntensors);
        let mut stats = CompressStats::default();
        let mut payloads: Vec<Payload> =
            update.iter().map(|t| Payload::Raw(t.clone())).collect();
        let bk = self.backend;
        for state in &mut self.layers {
            let geom = state.geom;
            let g = to_g(&geom, &update[geom.tensor]);
            let (l, k, m) = (geom.l, geom.k, geom.m);

            let mut refit_basis = None;
            let needs_fit = match &state.basis {
                None => true,
                Some(basis) => {
                    // Relative fitting error against the static basis.
                    let a = bk.matmul_at_b(basis, &g);
                    let e = g.sub(&bk.matmul(basis, &a));
                    let rel = e.fro_norm() as f64 / (g.fro_norm() as f64).max(1e-20);
                    rel > self.gamma
                }
            };
            if needs_fit {
                let basis = Self::fit_basis(bk, &g, k, &mut self.rng);
                refit_basis = Some(basis.as_slice().to_vec());
                state.basis = Some(basis);
                stats.sum_d += k as u64;
                stats.replaced += k as u64;
            }
            let basis = state.basis.as_ref().unwrap();
            let a = bk.matmul_at_b(basis, &g);
            payloads[geom.tensor] = Payload::SvdCoeffs {
                coeffs: a.as_slice().to_vec(),
                refit_basis,
                l,
                k,
                m,
            };
        }
        (payloads, stats)
    }
}

/// Server-side SVDFed decompressor.
pub struct SvdFedDecompressor {
    layers: Vec<ServerLayerState>,
    pool: BasisPool,
}

impl SvdFedDecompressor {
    /// Build for a model with a private single-lane pool (same geometry as
    /// the compressor at any k — the payload carries its own dims,
    /// geometry only selects tensors). A real server shares one pool
    /// across all lanes: [`Self::with_pool`].
    pub fn new(meta: &ModelMeta) -> Self {
        Self::with_pool(meta, BasisPool::new())
    }

    /// Build for a model, interning received bases in `pool`.
    pub fn with_pool(meta: &ModelMeta, pool: BasisPool) -> Self {
        let params = GradEstcParams::default();
        SvdFedDecompressor {
            layers: layer_geoms(meta, &params)
                .into_iter()
                .map(|geom| ServerLayerState { geom, basis: None })
                .collect(),
            pool,
        }
    }

    /// Snapshot of the server-side bases as `(tensor index, basis)` pairs,
    /// one per compressed layer, `None` before the warm-up fit lands. The
    /// `Arc` shares the pool allocation (no copy); the diagnostics plane
    /// diffs consecutive snapshots for subspace drift.
    pub fn layer_bases(&self) -> Vec<(usize, Option<std::sync::Arc<Mat>>)> {
        self.layers
            .iter()
            .map(|s| (s.geom.tensor, s.basis.as_ref().map(BasisHandle::share)))
            .collect()
    }
}

impl Decompressor for SvdFedDecompressor {
    fn state_fingerprint(&self) -> u64 {
        basis_fingerprint(self.layers.iter().map(|s| s.basis.as_ref().map(BasisHandle::as_mat)))
    }

    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<LayerUpdate> {
        let mut slots: Vec<Option<Payload>> = payloads.into_iter().map(Some).collect();
        let mut structured = Vec::with_capacity(self.layers.len());
        for state in &mut self.layers {
            let geom = state.geom;
            let Some(Payload::SvdCoeffs { coeffs, refit_basis, l, k, m }) =
                slots[geom.tensor].take()
            else {
                panic!("SvdFedDecompressor: expected SvdCoeffs for {}", geom.tensor)
            };
            if let Some(b) = refit_basis {
                // A refit replaces the basis wholesale: intern the received
                // content (deduping against any lane that got the same
                // bits) and drop the old handle. Coefficient-only rounds —
                // the steady state — never touch the pool.
                state.basis = Some(self.pool.intern(Mat::from_vec(l, k, b)));
            }
            let basis = state
                .basis
                .as_ref()
                .expect("coefficients received before any basis");
            structured.push((
                geom.tensor,
                LayerUpdate::LowRank {
                    coeffs: Mat::from_vec(k, m, coeffs),
                    basis: basis.share(),
                    // geom was built at default k; the segment dims come
                    // from the payload, the conv mapping from the layer.
                    geom: SegmentGeom { l, m, conv: geom.conv },
                },
            ));
        }
        assemble_updates(slots, structured, "SvdFedDecompressor")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::linalg::matmul;
    use crate::model::meta::layer_table;

    fn low_rank_update(meta: &ModelMeta, rng: &mut Pcg64, drift: f32) -> Vec<Vec<f32>> {
        meta.layers
            .iter()
            .map(|layer| {
                let l = layer.segment_len();
                let m = layer.segment_cols();
                let r = 4.min(l).min(m).max(1);
                let u = Mat::randn(l, r, rng);
                let mut v = Mat::randn(r, m, rng);
                for x in v.as_mut_slice() {
                    *x *= 1.0 + drift;
                }
                matmul(&u, &v).into_vec()
            })
            .collect()
    }

    #[test]
    fn first_round_sends_basis_then_coeffs_only() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(1);
        let mut c = SvdFedCompressor::new(&meta, 8, 0.95, 3);
        let u1 = low_rank_update(&meta, &mut rng, 0.0);
        let (p1, _) = c.compress(&u1);
        let has_refit = p1.iter().any(|p| {
            matches!(p, Payload::SvdCoeffs { refit_basis: Some(_), .. })
        });
        assert!(has_refit, "round 1 must carry the basis");
        // Round 2 on an update in the SAME column space: no refit.
        let (p2, _) = c.compress(&u1);
        for p in &p2 {
            if let Payload::SvdCoeffs { refit_basis, .. } = p {
                assert!(refit_basis.is_none(), "same-space update refit");
            }
        }
    }

    #[test]
    fn drifted_update_triggers_refit() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(2);
        let mut c = SvdFedCompressor::new(&meta, 8, 0.30, 3);
        let u1 = low_rank_update(&meta, &mut rng, 0.0);
        let _ = c.compress(&u1);
        // Entirely new column space → large fitting error → refit.
        let u2 = low_rank_update(&meta, &mut rng, 0.0);
        let (p2, stats) = c.compress(&u2);
        assert!(stats.replaced > 0);
        assert!(p2
            .iter()
            .any(|p| matches!(p, Payload::SvdCoeffs { refit_basis: Some(_), .. })));
    }

    #[test]
    fn roundtrip_reconstruction() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(3);
        let mut c = SvdFedCompressor::new(&meta, 8, 0.9, 5);
        let mut d = SvdFedDecompressor::new(&meta);
        let u = low_rank_update(&meta, &mut rng, 0.0);
        let (p, _) = c.compress(&u);
        let rec = d.decompress(&p);
        // Low-rank (4) update with k=8 basis must reconstruct well.
        for (i, (orig, r)) in u.iter().zip(&rec).enumerate() {
            if matches!(p[i], Payload::SvdCoeffs { .. }) {
                let num: f64 =
                    orig.iter().zip(r).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                let den: f64 = orig.iter().map(|&x| (x as f64).powi(2)).sum();
                assert!((num / den).sqrt() < 0.05, "tensor {i}");
            } else {
                assert_eq!(orig, r);
            }
        }
    }
}
