//! GradESTC — the paper's method (Algorithms 1 & 2, §III).
//!
//! Per compressed layer, the client and server each hold a copy of the
//! basis matrix `M ∈ R^{l×k}`. Every round the client:
//!
//! 1. projects the segmented gradient `G`: `A = MᵀG` (Eq. 4), fitting error
//!    `E = G − MA` (Eq. 6) — the Pallas `projection` kernel's math;
//! 2. mines candidate directions from `E` via randomized SVD (first `d`
//!    left singular vectors), which are orthogonal to `M` by construction
//!    (Eq. 7–9);
//! 3. scores old + candidate vectors by squared coefficient-row norms
//!    (Eq. 11), keeps the top `k`, and swaps the losers for winners
//!    (Eq. 12);
//! 4. adapts the candidate budget `d ← min(α·d_r + β, k)` (Eq. 13) where
//!    `d_r` is the number of vectors actually replaced;
//! 5. uplinks only ℙ (replaced indices), 𝕄 (replacement vectors) and `A`
//!    — `k·m + d_r·l + d_r` floats instead of `l·m` (Eq. 14).
//!
//! The server mirrors the replacement (Alg. 2). Reconstruction `Ĝ = M·A`
//! is *deferred*: [`GradEstcServer::decode`](crate::compress::Decompressor)
//! returns the factors as a [`LayerUpdate::LowRank`] and the aggregation
//! plane ([`crate::coordinator::ServerAggregator`]) fuses `M·A` into the
//! weighted FedAvg fold — the server never densifies one model per client
//! (dense materialization is the round-hook probes' opt-in path).
//!
//! # Basis ownership and lifecycle
//!
//! The basis `M` exists on both ends of a lane, with different ownership:
//!
//! * **Client** ([`GradEstcClient`]): owns its `Mat` outright, one per
//!   compressed layer, lazily initialized on the first compress. This is
//!   genuinely per-client state — every client's basis evolves from its
//!   own gradient stream.
//! * **Server** ([`GradEstcServer`]): holds a
//!   [`BasisHandle`](crate::compress::BasisHandle) per compressed layer
//!   into a [`BasisPool`](crate::compress::BasisPool) shared by *every*
//!   lane of the simulation — per-lane state is a pointer + fingerprint,
//!   and bit-identical bases across lanes dedupe to one allocation.
//!
//! A round **without** a basis change (the temporally-stable steady state
//! the paper's Fig. 1 motivates: `d_r = 0`, or the GradESTC-first
//! ablation after init) leaves the handle untouched — no hash, no copy.
//! A round **with** a change (replacements ℙ/𝕄, or the periodic re-ortho)
//! runs copy-on-write: take the matrix out of the handle (zero-copy when
//! this lane is the sole owner; a clone when another lane or an in-flight
//! [`LayerUpdate::LowRank`] snapshot still shares it), mutate, re-intern.
//! Snapshots handed to the aggregation plane therefore never observe a
//! later round's state, exactly like the pre-pool `Arc` copy-on-write.
//!
//! # Fingerprint semantics
//!
//! [`Compressor::state_fingerprint`] / [`Decompressor::state_fingerprint`]
//! hash the basis bits (dims + every element, layer order, FNV-1a). The
//! paired halves of a lane must report equal fingerprints whenever their
//! states are in lockstep — the invariant the straggler/out-of-order
//! scheduler tests assert — and the pool's content key is the same hash
//! over a single matrix, so "two lanes share a pool entry" and "their
//! per-layer fingerprints agree" coincide by construction.
//!
//! Client and server state evolve in lockstep from identical updates; a
//! deterministic periodic Gram–Schmidt repair (same round schedule on
//! both sides) bounds float drift without extra traffic.
//!
//! Ablation variants (paper §V-E) are flags on [`GradEstcParams`]:
//! `freeze_after_init` (GradESTC-first), `replace_all` (GradESTC-all),
//! `fixed_d` (GradESTC-k).

use super::codec::Payload;
use super::intern::{BasisHandle, BasisPool};
use super::{
    assemble_updates, basis_fingerprint, CompressStats, Compressor, Decompressor, LayerUpdate,
    SegmentGeom,
};
use crate::config::GradEstcParams;
use crate::linalg::{
    default_backend, mgs_orthonormalize_in, randomized_svd_in, Backend, Mat, RsvdOptions,
};
use crate::model::meta::{LayerRole, ModelMeta};
use crate::util::rng::Pcg64;

/// Re-orthonormalize the shared basis every this many rounds (both sides,
/// deterministically — see module docs).
const REORTHO_PERIOD: usize = 32;

/// Shared geometry helpers (also used by the SVDFed baseline, which
/// segments gradients identically).
pub(crate) mod geometry {
    use super::*;

    /// Static geometry of one compressed layer.
    #[derive(Clone, Copy, Debug)]
    pub(crate) struct LayerGeom {
        /// Tensor index in the model.
        pub(crate) tensor: usize,
        /// Segment length (rows of G).
        pub(crate) l: usize,
        /// Columns of G.
        pub(crate) m: usize,
        /// Effective basis size (k clamped to min(l, m)).
        pub(crate) k: usize,
        /// HWIO conv dims when the tensor needs layout conversion.
        pub(crate) conv: Option<(usize, usize, usize, usize)>,
    }

    impl LayerGeom {
        /// The public segment-space geometry (basis size `k` stripped).
        pub(crate) fn seg(&self) -> SegmentGeom {
            SegmentGeom { l: self.l, m: self.m, conv: self.conv }
        }
    }

    pub(crate) fn layer_geoms(meta: &ModelMeta, params: &GradEstcParams) -> Vec<LayerGeom> {
        meta.compression_set(params.coverage)
            .into_iter()
            .filter_map(|i| {
                let layer = &meta.layers[i];
                let l = layer.segment_len();
                let m = layer.segment_cols();
                let k = params.k.min(l).min(m);
                // Steady-state uplink ≈ k·m (coefficients) + d_r·l with
                // d_r ≪ k; skip layers where even a conservative estimate
                // (d_r ≈ k/4) beats the raw size — compression would not
                // pay for itself there.
                if k == 0 || k * m + k * l / 4 >= l * m {
                    return None;
                }
                let conv = match layer.role {
                    LayerRole::ConvKernel => Some((
                        layer.shape[0],
                        layer.shape[1],
                        layer.shape[2],
                        layer.shape[3],
                    )),
                    _ => None,
                };
                Some(LayerGeom { tensor: i, l, m, k, conv })
            })
            .collect()
    }

    /// Flatten a tensor into fan-in-major order and segment it into G
    /// (delegates to [`SegmentGeom::flat_to_segments`]; the inverse is
    /// [`SegmentGeom::segments_to_flat`]).
    pub(crate) fn to_g(geom: &LayerGeom, flat: &[f32]) -> Mat {
        geom.seg().flat_to_segments(flat)
    }

    /// Apply the Eq. 12 replacement to a basis matrix.
    pub(crate) fn apply_replacements(
        m: &mut Mat,
        replace_idx: &[u32],
        new_vectors: &[f32],
        l: usize,
    ) {
        for (slot, &col) in replace_idx.iter().enumerate() {
            let v = &new_vectors[slot * l..(slot + 1) * l];
            m.set_col(col as usize, v);
        }
    }
}

use geometry::{apply_replacements, layer_geoms, to_g, LayerGeom};

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

struct ClientLayer {
    geom: LayerGeom,
    basis: Option<Mat>,
    d: usize,
}

/// Client-side GradESTC compressor (paper Algorithm 1).
pub struct GradEstcClient {
    params: GradEstcParams,
    ntensors: usize,
    layers: Vec<ClientLayer>,
    rng: Pcg64,
    round: usize,
    backend: &'static dyn Backend,
}

impl GradEstcClient {
    /// Build for a model; `seed` drives the randomized SVD sketches.
    /// Uses the process-default compute backend; see [`Self::with_backend`].
    pub fn new(meta: &ModelMeta, params: GradEstcParams, seed: u64) -> Self {
        Self::with_backend(meta, params, seed, default_backend())
    }

    /// [`Self::new`] pinned to an explicit compute backend (projection,
    /// rSVD mining and the periodic MGS repair all run through it).
    pub fn with_backend(
        meta: &ModelMeta,
        params: GradEstcParams,
        seed: u64,
        backend: &'static dyn Backend,
    ) -> Self {
        let layers = layer_geoms(meta, &params)
            .into_iter()
            .map(|geom| ClientLayer { geom, basis: None, d: geom.k })
            .collect();
        GradEstcClient {
            params,
            ntensors: meta.layers.len(),
            layers,
            rng: Pcg64::new(seed, 0xE57C),
            round: 0,
            backend,
        }
    }

    /// Tensor indices being compressed (for tests / instrumentation).
    pub fn compressed_tensors(&self) -> Vec<usize> {
        self.layers.iter().map(|s| s.geom.tensor).collect()
    }

    /// The compute backend this client runs on (the error-feedback wrapper
    /// builds its mirror decompressor on the same one).
    pub fn backend(&self) -> &'static dyn Backend {
        self.backend
    }

    /// Current basis matrices (initialized layers only) — exposed for the
    /// orthonormality property tests and the §Perf instrumentation.
    pub fn basis_matrices(&self) -> Vec<&Mat> {
        self.layers.iter().filter_map(|s| s.basis.as_ref()).collect()
    }

    fn compress_layer(
        bk: &dyn Backend,
        state: &mut ClientLayer,
        params: &GradEstcParams,
        flat: &[f32],
        rng: &mut Pcg64,
        round: usize,
        stats: &mut CompressStats,
    ) -> Payload {
        let geom = state.geom;
        let g = to_g(&geom, flat);
        let (l, k, m) = (geom.l, geom.k, geom.m);

        let reortho_due =
            round > 0 && round % REORTHO_PERIOD == 0 && !params.freeze_after_init;

        match &mut state.basis {
            // ---- first round: initialize via rSVD of G (Alg. 1 l.2-8) ----
            None => {
                let svd = randomized_svd_in(bk, &g, k, RsvdOptions::default(), rng);
                let rank = svd.s.len();
                let mut basis = Mat::zeros(l, k);
                for j in 0..rank {
                    basis.set_col(j, &svd.u.col(j));
                }
                // Rank-deficient G: fill remaining columns with unit vectors
                // orthogonal to the rest so M stays well-formed.
                for j in rank..k {
                    let mut e = vec![0.0f32; l];
                    e[j % l] = 1.0;
                    basis.set_col(j, &e);
                }
                let ortho_fill = rank < k;
                if ortho_fill {
                    mgs_orthonormalize_in(bk, &mut basis, 1e-7);
                }
                // A = Σ Vᵀ (equivalently MᵀG; recompute if we touched M).
                let coeffs = if ortho_fill {
                    bk.matmul_at_b(&basis, &g)
                } else {
                    let mut a = Mat::zeros(k, m);
                    for i in 0..rank {
                        for j in 0..m {
                            a[(i, j)] = svd.s[i] * svd.vt[(i, j)];
                        }
                    }
                    a
                };
                stats.sum_d += k as u64;
                stats.replaced += k as u64;
                state.d = k;
                let payload = Payload::Basis {
                    replace_idx: (0..k as u32).collect(),
                    new_vectors: (0..k).flat_map(|j| basis.col(j)).collect(),
                    coeffs: coeffs.as_slice().to_vec(),
                    l,
                    k,
                    m,
                };
                state.basis = Some(basis);
                payload
            }
            // ---- subsequent rounds (Alg. 1 l.10-29) ----
            Some(basis) => {
                if reortho_due {
                    mgs_orthonormalize_in(bk, basis, 1e-7);
                }
                // GradESTC-first ablation: static basis, only coefficients.
                if params.freeze_after_init {
                    let a = bk.matmul_at_b(basis, &g);
                    return Payload::Basis {
                        replace_idx: Vec::new(),
                        new_vectors: Vec::new(),
                        coeffs: a.as_slice().to_vec(),
                        l,
                        k,
                        m,
                    };
                }
                // GradESTC-all ablation: refresh the whole basis each round.
                if params.replace_all {
                    let svd = randomized_svd_in(bk, &g, k, RsvdOptions::default(), rng);
                    let rank = svd.s.len();
                    for j in 0..rank {
                        basis.set_col(j, &svd.u.col(j));
                    }
                    let a = bk.matmul_at_b(basis, &g);
                    stats.sum_d += k as u64;
                    stats.replaced += rank as u64;
                    return Payload::Basis {
                        replace_idx: (0..rank as u32).collect(),
                        new_vectors: (0..rank).flat_map(|j| basis.col(j)).collect(),
                        coeffs: a.as_slice().to_vec(),
                        l,
                        k,
                        m,
                    };
                }

                let d = if params.fixed_d { k } else { state.d.clamp(1, k) };
                stats.sum_d += d as u64;

                // A = MᵀG ; E = G − MA (the projection kernel).
                let mut a = bk.matmul_at_b(basis, &g);
                let e = g.sub(&bk.matmul(basis, &a));

                // Candidates from the fitting error.
                let svd_e = randomized_svd_in(bk, &e, d, RsvdOptions::default(), rng);
                // Keep only genuinely non-zero directions.
                let d_eff = svd_e.s.iter().take_while(|&&s| s > 1e-7).count();

                // Contribution scores R (Eq. 11): rows of A and of Aᵉ=ΣᵉVᵉᵀ.
                let mut scores: Vec<(f64, usize)> = (0..k)
                    .map(|i| (a.row_norm_sq(i) as f64, i))
                    .collect();
                for i in 0..d_eff {
                    let se = svd_e.s[i] as f64;
                    let row_sq: f64 = (0..m)
                        .map(|j| {
                            let v = se * svd_e.vt[(i, j)] as f64;
                            v * v
                        })
                        .sum();
                    scores.push((row_sq, k + i));
                }
                // Top-k by score. `total_cmp` is NaN-safe (a NaN score —
                // e.g. from an overflowed row norm — orders deterministically
                // instead of panicking) and the stable sort preserves the
                // original order of tied scores, so the deterministic
                // tie-break is unchanged from the `partial_cmp` days.
                scores.sort_by(|x, y| y.0.total_cmp(&x.0));
                let top: std::collections::HashSet<usize> =
                    scores.iter().take(k).map(|&(_, i)| i).collect();

                // ℙ: old indices leaving; winners: new candidate ranks.
                let leaving: Vec<u32> =
                    (0..k).filter(|i| !top.contains(i)).map(|i| i as u32).collect();
                let arriving: Vec<usize> =
                    (0..d_eff).filter(|i| top.contains(&(k + i))).collect();
                debug_assert_eq!(leaving.len(), arriving.len());
                let d_r = arriving.len();

                // Eq. 12: swap basis columns and coefficient rows.
                let mut new_vectors = Vec::with_capacity(d_r * l);
                for (slot, &cand) in arriving.iter().enumerate() {
                    let col = svd_e.u.col(cand);
                    basis.set_col(leaving[slot] as usize, &col);
                    new_vectors.extend_from_slice(&col);
                    let se = svd_e.s[cand];
                    for j in 0..m {
                        a[(leaving[slot] as usize, j)] = se * svd_e.vt[(cand, j)];
                    }
                }

                // Eq. 13: adapt the candidate budget.
                state.d = (((params.alpha * d_r as f64) + params.beta).round() as usize)
                    .clamp(1, k);
                stats.replaced += d_r as u64;

                Payload::Basis {
                    replace_idx: leaving,
                    new_vectors,
                    coeffs: a.as_slice().to_vec(),
                    l,
                    k,
                    m,
                }
            }
        }
    }
}

impl Compressor for GradEstcClient {
    fn state_fingerprint(&self) -> u64 {
        basis_fingerprint(self.layers.iter().map(|s| s.basis.as_ref()))
    }

    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats) {
        assert_eq!(update.len(), self.ntensors);
        let mut stats = CompressStats::default();
        let mut payloads: Vec<Payload> =
            update.iter().map(|t| Payload::Raw(t.clone())).collect();
        let round = self.round;
        for state in &mut self.layers {
            let tensor = state.geom.tensor;
            payloads[tensor] = Self::compress_layer(
                self.backend,
                state,
                &self.params,
                &update[tensor],
                &mut self.rng,
                round,
                &mut stats,
            );
        }
        self.round += 1;
        (payloads, stats)
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

struct ServerLayer {
    geom: LayerGeom,
    /// Mirrored basis as a handle into the shared [`BasisPool`]: per-lane
    /// state is one pointer + fingerprint; bit-identical bases across
    /// lanes share a single allocation. Updated copy-on-write so a
    /// snapshot held by the aggregation plane can never observe a later
    /// round's state (see the module docs' lifecycle section).
    basis: Option<BasisHandle>,
}

/// Server-side GradESTC decompressor (paper Algorithm 2).
pub struct GradEstcServer {
    params: GradEstcParams,
    layers: Vec<ServerLayer>,
    round: usize,
    pool: BasisPool,
    backend: &'static dyn Backend,
}

impl GradEstcServer {
    /// Build the mirror of [`GradEstcClient`] for the same model/params
    /// with a private single-lane pool (tests, the error-feedback mirror).
    /// A real server shares one pool across all lanes: [`Self::with_pool`].
    pub fn new(meta: &ModelMeta, params: GradEstcParams) -> Self {
        Self::with_pool(meta, params, BasisPool::new())
    }

    /// Build the mirror interning its basis state in `pool` (shared with
    /// every other lane of the simulation).
    pub fn with_pool(meta: &ModelMeta, params: GradEstcParams, pool: BasisPool) -> Self {
        Self::with_pool_backend(meta, params, pool, default_backend())
    }

    /// [`Self::with_pool`] pinned to an explicit compute backend (the
    /// mirrored MGS repair runs through it — it must match the client's
    /// backend for the lockstep invariant to hold bit-exactly).
    pub fn with_pool_backend(
        meta: &ModelMeta,
        params: GradEstcParams,
        pool: BasisPool,
        backend: &'static dyn Backend,
    ) -> Self {
        let layers = layer_geoms(meta, &params)
            .into_iter()
            .map(|geom| ServerLayer { geom, basis: None })
            .collect();
        GradEstcServer { params, layers, round: 0, pool, backend }
    }

    /// Bytes this lane's basis handles *reference* in the shared pool
    /// (Σ 4·l·k over initialized layers). What the lane would own outright
    /// without interning; the pool's [`stats`](BasisPool::stats) report
    /// what is actually resident across all lanes.
    pub fn referenced_basis_bytes(&self) -> usize {
        self.layers
            .iter()
            .filter(|s| s.basis.is_some())
            .map(|s| 4 * s.geom.l * s.geom.k)
            .sum()
    }

    /// Snapshot of the server-side bases as `(tensor index, basis)` pairs,
    /// one per compressed layer, `None` until that layer first initializes.
    /// The `Arc` shares the pool allocation (no copy); the diagnostics
    /// plane diffs consecutive snapshots for subspace drift.
    pub fn layer_bases(&self) -> Vec<(usize, Option<std::sync::Arc<Mat>>)> {
        self.layers
            .iter()
            .map(|s| (s.geom.tensor, s.basis.as_ref().map(BasisHandle::share)))
            .collect()
    }
}

/// Bytes one lane's fully-initialized GradESTC basis set occupies
/// (Σ 4·l·k over the compressed layers) — the per-client server cost the
/// [`BasisPool`] exists to shrink. Used by the scale experiment, bench,
/// and memory tests to compute the naive `clients × basis` baseline.
pub fn basis_bytes_per_lane(meta: &ModelMeta, params: &GradEstcParams) -> usize {
    layer_geoms(meta, params).iter().map(|g| 4 * g.l * g.k).sum()
}

impl Decompressor for GradEstcServer {
    fn state_fingerprint(&self) -> u64 {
        basis_fingerprint(self.layers.iter().map(|s| s.basis.as_ref().map(BasisHandle::as_mat)))
    }

    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<LayerUpdate> {
        let round = self.round;
        self.round += 1;
        let mut slots: Vec<Option<Payload>> = payloads.into_iter().map(Some).collect();
        let mut structured = Vec::with_capacity(self.layers.len());
        for state in &mut self.layers {
            let geom = state.geom;
            let Some(Payload::Basis { replace_idx, new_vectors, coeffs, l, k, m }) =
                slots[geom.tensor].take()
            else {
                panic!("GradEstcServer: expected Basis payload for tensor {}", geom.tensor)
            };
            assert_eq!((l, k, m), (geom.l, geom.k, geom.m));
            let reortho_due = round > 0
                && round % REORTHO_PERIOD == 0
                && !self.params.freeze_after_init;
            // Copy-on-write only when this payload actually changes the
            // basis; a stable round (d_r = 0, or GradESTC-first after
            // init) keeps the interned handle untouched — no hash, no
            // copy, and cross-lane sharing survives.
            if reortho_due || !replace_idx.is_empty() || state.basis.is_none() {
                let mut basis = match state.basis.take() {
                    // Zero-copy when sole owner; clones when another lane
                    // or an in-flight LowRank snapshot still shares it.
                    Some(handle) => handle.into_mat(),
                    None => Mat::zeros(geom.l, geom.k),
                };
                if reortho_due {
                    // Mirror the client's deterministic repair (same
                    // schedule, same algorithm, same backend →
                    // bit-identical state).
                    mgs_orthonormalize_in(self.backend, &mut basis, 1e-7);
                }
                apply_replacements(&mut basis, &replace_idx, &new_vectors, geom.l);
                state.basis = Some(self.pool.intern(basis));
            }
            // Alg. 2's reconstruction Ĝ = M·A is *deferred*: the aggregate
            // plane either fuses it into the per-layer accumulator
            // (`matmul_acc`) or a probe densifies it explicitly.
            let handle = state.basis.as_ref().expect("basis initialized above");
            structured.push((
                geom.tensor,
                LayerUpdate::LowRank {
                    coeffs: Mat::from_vec(geom.k, geom.m, coeffs),
                    basis: handle.share(),
                    geom: geom.seg(),
                },
            ));
        }
        assemble_updates(slots, structured, "GradEstcServer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::linalg::{matmul, ortho_defect};
    use crate::model::meta::layer_table;

    fn params(k: usize) -> GradEstcParams {
        GradEstcParams { k, ..Default::default() }
    }

    /// Synthetic temporally-correlated update stream: low-rank structure
    /// drifting slowly, like real FL gradients (paper Fig. 1).
    fn update_stream(
        meta: &ModelMeta,
        rounds: usize,
        seed: u64,
    ) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Pcg64::seeded(seed);
        // Per-tensor latent factors.
        let bases: Vec<(Mat, Mat)> = meta
            .layers
            .iter()
            .map(|l| {
                let ll = l.segment_len();
                let mm = l.segment_cols();
                let r = 6.min(ll).min(mm).max(1);
                (Mat::randn(ll, r, &mut rng), Mat::randn(r, mm, &mut rng))
            })
            .collect();
        (0..rounds)
            .map(|t| {
                meta.layers
                    .iter()
                    .zip(&bases)
                    .map(|(l, (u, v))| {
                        let mut vt = v.clone();
                        // slow drift of the right factor
                        let drift = Mat::randn(v.rows(), v.cols(), &mut rng);
                        for (x, d) in vt.as_mut_slice().iter_mut().zip(drift.as_slice())
                        {
                            *x += 0.15 * t as f32 * 0.2 * d;
                        }
                        let g = matmul(u, &vt);
                        let noise = Mat::randn(g.rows(), g.cols(), &mut rng);
                        let mut flat = g.as_slice().to_vec();
                        for (x, n) in flat.iter_mut().zip(noise.as_slice()) {
                            *x += 0.02 * n;
                        }
                        // Return in the tensor's natural layout: invert to_g
                        // by treating flat as G column-major-ish — use the
                        // segment geometry's inverse map for exactness.
                        let geom = SegmentGeom {
                            l: l.segment_len(),
                            m: l.segment_cols(),
                            conv: match l.role {
                                LayerRole::ConvKernel => Some((
                                    l.shape[0], l.shape[1], l.shape[2], l.shape[3],
                                )),
                                _ => None,
                            },
                        };
                        let g = Mat::from_vec(geom.l, geom.m, flat);
                        geom.segments_to_flat(&g)
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn roundtrip_reconstruction_close() {
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 6, 1);
        let mut c = GradEstcClient::new(&meta, params(8), 7);
        let mut s = GradEstcServer::new(&meta, params(8));
        let compressed = c.compressed_tensors();
        assert!(!compressed.is_empty());
        for (t, update) in stream.iter().enumerate() {
            let (payloads, _) = c.compress(update);
            let rec = s.decompress(&payloads);
            for &i in &compressed {
                let num: f64 = update[i]
                    .iter()
                    .zip(&rec[i])
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                let den: f64 = update[i].iter().map(|&x| (x as f64).powi(2)).sum();
                let rel = (num / den.max(1e-30)).sqrt();
                assert!(rel < 0.35, "round {t} tensor {i}: rel err {rel}");
            }
            // Uncompressed tensors pass through bit-exactly.
            for i in 0..update.len() {
                if !compressed.contains(&i) {
                    assert_eq!(update[i], rec[i]);
                }
            }
        }
    }

    #[test]
    fn basis_stays_orthonormal_over_rounds() {
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 40, 2);
        let mut c = GradEstcClient::new(&meta, params(8), 3);
        for update in &stream {
            let _ = c.compress(update);
        }
        for layer in &c.layers {
            let defect = ortho_defect(layer.basis.as_ref().unwrap());
            assert!(defect < 5e-3, "defect {defect}");
        }
    }

    #[test]
    fn uplink_much_smaller_than_raw_after_init() {
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 4, 3);
        let mut c = GradEstcClient::new(&meta, params(8), 9);
        let mut sizes = Vec::new();
        for update in &stream {
            let (payloads, _) = c.compress(update);
            sizes.push(payloads.iter().map(|p| p.wire_bytes()).sum::<u64>());
        }
        let raw: u64 = meta.layers.iter().map(|l| 4 * l.size() as u64).sum();
        // After init the per-round uplink must be a small fraction of raw.
        assert!(sizes[2] < raw / 3, "steady-state {} vs raw {raw}", sizes[2]);
        // Init round carries the full basis and is allowed to be bigger.
        assert!(sizes[0] >= sizes[2]);
    }

    #[test]
    fn temporal_correlation_shrinks_d() {
        // On a strongly-correlated stream, the adaptive d must fall well
        // below k (the paper's Table IV effect: Σd ≪ rounds·k).
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 12, 4);
        let mut c = GradEstcClient::new(&meta, params(8), 5);
        let mut total_d = 0u64;
        for update in &stream {
            let (_, st) = c.compress(update);
            total_d += st.sum_d;
        }
        let nlayers = c.layers.len() as u64;
        let max_d = 12 * 8 * nlayers;
        // The stream's slow drift keeps gradients in a fixed 6-dim column
        // space; only the noise floor churns, so Σd must sit well below the
        // fixed-d budget (the paper's Table IV effect).
        assert!(
            total_d < max_d * 3 / 4,
            "sum_d {total_d} not below 3/4 of fixed-d {max_d}"
        );
    }

    #[test]
    fn ablation_first_sends_no_vectors_after_init() {
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 3, 5);
        let mut p = params(8);
        p.freeze_after_init = true;
        let mut c = GradEstcClient::new(&meta, p.clone(), 1);
        let mut s = GradEstcServer::new(&meta, p);
        for (t, update) in stream.iter().enumerate() {
            let (payloads, _) = c.compress(update);
            if t > 0 {
                for pl in &payloads {
                    if let Payload::Basis { replace_idx, new_vectors, .. } = pl {
                        assert!(replace_idx.is_empty());
                        assert!(new_vectors.is_empty());
                    }
                }
            }
            let _ = s.decompress(&payloads);
        }
    }

    #[test]
    fn ablation_all_replaces_everything() {
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 3, 6);
        let mut p = params(8);
        p.replace_all = true;
        let mut c = GradEstcClient::new(&meta, p, 1);
        for (t, update) in stream.iter().enumerate() {
            let (payloads, st) = c.compress(update);
            if t > 0 {
                for pl in &payloads {
                    if let Payload::Basis { replace_idx, k, .. } = pl {
                        assert_eq!(replace_idx.len(), *k);
                    }
                }
                assert!(st.sum_d > 0);
            }
        }
    }

    #[test]
    fn fixed_d_ablation_uses_k_candidates() {
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 4, 7);
        let mut p = params(8);
        p.fixed_d = true;
        let mut c = GradEstcClient::new(&meta, p, 1);
        let nlayers = c.layers.len() as u64;
        for (t, update) in stream.iter().enumerate() {
            let (_, st) = c.compress(update);
            if t > 0 {
                assert_eq!(st.sum_d, 8 * nlayers, "round {t}");
            }
        }
    }

    #[test]
    fn server_client_state_lockstep() {
        // After many rounds the server basis must equal the client basis
        // bit-for-bit (the lockstep invariant the protocol relies on).
        let meta = layer_table(ModelKind::LeNet5);
        let stream = update_stream(&meta, 35, 8); // crosses REORTHO_PERIOD
        let mut c = GradEstcClient::new(&meta, params(8), 11);
        let mut s = GradEstcServer::new(&meta, params(8));
        for update in &stream {
            let (payloads, _) = c.compress(update);
            let _ = s.decompress(&payloads);
        }
        for (cl, sl) in c.layers.iter().zip(&s.layers) {
            assert_eq!(
                cl.basis.as_ref().unwrap(),
                sl.basis.as_ref().unwrap().as_mat(),
                "basis diverged"
            );
        }
        // The public fingerprints must agree exactly when (and only when)
        // the bases are bit-identical.
        assert_eq!(c.state_fingerprint(), s.state_fingerprint());
        assert_ne!(c.state_fingerprint(), 0);
    }
}
