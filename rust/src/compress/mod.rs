//! Gradient compression framework: the paper's GradESTC plus every
//! baseline it is evaluated against.
//!
//! A [`Compressor`] turns a client's per-layer update into a compact
//! [`Payload`]; the server-side [`Decompressor`] *decodes* it into a typed
//! [`LayerUpdate`] while advancing whatever server state the protocol
//! keeps (basis replacements, periodic re-orthonormalization). Payload
//! byte sizes are *exact wire sizes* (what the binary serializer emits),
//! charged to the communication ledger by the coordinator.
//!
//! ## The decode / aggregate split
//!
//! Decoding is deliberately **not** densification. `decode` returns the
//! update in its structured form — low-rank factors, sparse pairs, packed
//! quantization codes — and the server aggregation plane
//! ([`crate::coordinator::ServerAggregator`]) folds those structures
//! directly into per-layer accumulators, so a round's server phase never
//! materializes one dense model per client. Reconstructing a dense tensor
//! ([`LayerUpdate::to_dense`], or the [`Decompressor::decompress`]
//! convenience) is the opt-in path, used by the round-hook probes and the
//! error-feedback mirror. Crucially, `decode` still runs for stragglers
//! whose updates are excluded from the aggregate: client and server state
//! must evolve in lockstep (the temporal-correlation contract), so the
//! state advance is unconditional and only the fold weight is withheld.
//!
//! Implementations:
//! * [`gradestc`] — the paper's method (Algorithms 1 & 2).
//! * [`topk`] — magnitude sparsification (Stich et al.).
//! * [`quant`] — FedPAQ stochastic uniform quantization + FedQClip clipped
//!   variant + SignSGD.
//! * [`svdfed`] — shared global basis via SVD with error-triggered refresh.
//! * [`error_feedback`] — local residual accumulation wrapper (paper's
//!   future-work extension).
//! * [`intern`] — the [`BasisPool`]: content-addressed interning of
//!   server-side basis state, one allocation per *distinct* basis across
//!   all client lanes (the `O(clients × basis)` → `O(distinct bases)`
//!   memory lever behind the 10⁴+-client scale plane).

pub mod codec;
pub mod error_feedback;
pub mod gradestc;
pub mod intern;
pub mod quant;
pub mod svdfed;
pub mod topk;

pub use codec::Payload;
pub use error_feedback::EfWrapper;
pub use gradestc::{GradEstcClient, GradEstcServer};
pub use intern::{BasisHandle, BasisPool, PoolStats};

use std::sync::Arc;

use crate::linalg::{matmul, Mat};
use crate::model::meta::ModelMeta;
use crate::model::reshape::{
    fanin_major_to_hwio, hwio_to_fanin_major, segment_matrix, unsegment_matrix,
};

/// Per-round, per-client compression statistics surfaced to the recorder.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompressStats {
    /// rSVD candidate count consumed this round (Σd proxy, paper Tab. IV).
    pub sum_d: u64,
    /// Basis vectors actually replaced this round (GradESTC only).
    pub replaced: u64,
}

/// Segment-space geometry of one compressed layer: how a flat tensor maps
/// to the paper's `G ∈ R^{l×m}` (§III-A) and back.
///
/// Carried by [`LayerUpdate::LowRank`] so the aggregation plane can keep a
/// per-layer accumulator in segment space and convert to the tensor's flat
/// layout exactly once per round, instead of once per client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegmentGeom {
    /// Segment length (rows of G; the layer's fan-in).
    pub l: usize,
    /// Segment count (columns of G; the layer's output units).
    pub m: usize,
    /// HWIO conv dims `(kh, kw, cin, cout)` when the tensor needs layout
    /// conversion; `None` for dense `[in, out]` kernels.
    pub conv: Option<(usize, usize, usize, usize)>,
}

impl SegmentGeom {
    /// Flatten a tensor into fan-in-major order and segment it into G.
    pub fn flat_to_segments(&self, flat: &[f32]) -> Mat {
        match self.conv {
            Some((kh, kw, ci, co)) => {
                let f = hwio_to_fanin_major(flat, kh, kw, ci, co);
                segment_matrix(&f, self.l, self.m)
            }
            None => {
                // Dense [in, out] row-major: column j of G must be output
                // unit j's fan-in — i.e. the transposed layout.
                let mut f = vec![0.0f32; flat.len()];
                for i in 0..self.l {
                    for o in 0..self.m {
                        f[o * self.l + i] = flat[i * self.m + o];
                    }
                }
                segment_matrix(&f, self.l, self.m)
            }
        }
    }

    /// Inverse of [`SegmentGeom::flat_to_segments`].
    pub fn segments_to_flat(&self, g: &Mat) -> Vec<f32> {
        let f = unsegment_matrix(g);
        match self.conv {
            Some((kh, kw, ci, co)) => fanin_major_to_hwio(&f, kh, kw, ci, co),
            None => {
                let mut flat = vec![0.0f32; f.len()];
                for o in 0..self.m {
                    for i in 0..self.l {
                        flat[i * self.m + o] = f[o * self.l + i];
                    }
                }
                flat
            }
        }
    }
}

/// One tensor's decoded update in its *structured* form — what
/// [`Decompressor::decode`] hands the server aggregation plane.
///
/// Every variant knows how to fold itself into a per-layer accumulator
/// without densifying first; [`LayerUpdate::to_dense`] is the explicit
/// opt-in reconstruction used by round-hook probes.
#[derive(Clone, Debug)]
pub enum LayerUpdate {
    /// Dense f32 data in the tensor's natural flat layout.
    Dense(Vec<f32>),
    /// Scatter (index, value) pairs into a `len`-element tensor.
    Sparse {
        /// Flat indices.
        indices: Vec<u32>,
        /// Values at those indices.
        values: Vec<f32>,
        /// Dense length.
        len: usize,
    },
    /// Bit-packed uniform quantization codes; `x̂ = lo + q·(hi-lo)/(2^bits-1)`.
    /// SignSGD decodes here too (`bits = 1`, `lo = -scale`, `hi = scale`).
    QuantDense {
        /// Minimum of the quantization range.
        lo: f32,
        /// Maximum of the quantization range.
        hi: f32,
        /// Bit width (1..=16).
        bits: u8,
        /// Bit-packed codes.
        packed: Vec<u8>,
        /// Dense length.
        len: usize,
    },
    /// Low-rank factorization `Ĝ = basis · coeffs` in segment space. The
    /// basis is a shared snapshot of the decompressor's state — an
    /// [`intern::BasisPool`] entry, O(1) to hand out, never a per-client
    /// copy; the lane's next basis update copy-on-writes a successor
    /// instead of mutating what this round's aggregate observes.
    LowRank {
        /// Combination coefficients A, `k × m`.
        coeffs: Mat,
        /// Basis M, `l × k` (shared server state).
        basis: Arc<Mat>,
        /// Segment geometry mapping G back to the flat tensor layout.
        geom: SegmentGeom,
    },
}

impl LayerUpdate {
    /// Dense element count of the tensor this update describes.
    pub fn dense_len(&self) -> usize {
        match self {
            LayerUpdate::Dense(v) => v.len(),
            LayerUpdate::Sparse { len, .. } | LayerUpdate::QuantDense { len, .. } => *len,
            LayerUpdate::LowRank { geom, .. } => geom.l * geom.m,
        }
    }

    /// f32-equivalents this update *owns* (the shared low-rank basis is
    /// server state, not a per-client copy) — the API-level memory
    /// accounting the aggregation-plane tests assert on.
    pub fn stored_floats(&self) -> usize {
        match self {
            LayerUpdate::Dense(v) => v.len(),
            LayerUpdate::Sparse { indices, values, .. } => indices.len() + values.len(),
            LayerUpdate::QuantDense { packed, .. } => packed.len().div_ceil(4),
            LayerUpdate::LowRank { coeffs, .. } => coeffs.as_slice().len(),
        }
    }

    /// Reconstruct the dense flat tensor. This is the opt-in
    /// materialization path (round hooks, error-feedback mirror); the
    /// aggregation plane folds the structured form directly instead.
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            LayerUpdate::Dense(v) => v.clone(),
            LayerUpdate::Sparse { indices, values, len } => {
                // Producer contract (enforced on the wire): indices are
                // strictly increasing, so assignment here and the
                // aggregator's scatter-add agree exactly.
                debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
                let mut out = vec![0.0f32; *len];
                for (&i, &v) in indices.iter().zip(values) {
                    out[i as usize] = v;
                }
                out
            }
            LayerUpdate::QuantDense { lo, hi, bits, packed, len } => {
                codec::dequant_values(*lo, *hi, *bits, packed, *len).collect()
            }
            LayerUpdate::LowRank { coeffs, basis, geom } => {
                let ghat = matmul(basis, coeffs);
                geom.segments_to_flat(&ghat)
            }
        }
    }

    /// Like [`LayerUpdate::to_dense`] but consumes the update, moving the
    /// buffer out of the `Dense` variant instead of cloning it.
    pub fn into_dense(self) -> Vec<f32> {
        match self {
            LayerUpdate::Dense(v) => v,
            other => other.to_dense(),
        }
    }
}

/// Client-side compressor over a full model update (all tensors, in layer
/// order; non-compressed tensors pass through as raw f32).
///
/// `Send` is a supertrait: the round engine moves each client lane — the
/// compressor together with its paired [`Decompressor`] — into worker
/// tasks, so every implementation must be transferable across threads.
pub trait Compressor: Send {
    /// Compress one round's update. `update[i]` is tensor `i`'s flat data.
    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats);

    /// Deterministic, layer-order-sensitive hash of the compressor's
    /// persistent state (0 for stateless compressors). Paired with
    /// [`Decompressor::state_fingerprint`] to assert the client/server
    /// lockstep invariant from outside the crate (tests, diagnostics);
    /// paired implementations must hash the same state in the same order
    /// (the in-crate implementations share one `basis_fingerprint` helper).
    fn state_fingerprint(&self) -> u64 {
        0
    }
}

/// Server-side decompressor paired with one client's compressor. `Send`
/// for the same reason as [`Compressor`]: it rides in the client lane.
pub trait Decompressor: Send {
    /// Decode payloads into typed per-tensor updates, advancing any
    /// server-side state (basis replacement, periodic re-ortho). Runs for
    /// *every* received upload — including stragglers whose fold weight is
    /// zero — because paired client/server state must stay in lockstep.
    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<LayerUpdate>;

    /// Decode and densify: the legacy reconstruction path, kept for probes
    /// and tests. Advances state exactly like [`Decompressor::decode`].
    fn decompress(&mut self, payloads: &[Payload]) -> Vec<Vec<f32>> {
        self.decode(payloads.to_vec())
            .into_iter()
            .map(LayerUpdate::into_dense)
            .collect()
    }

    /// Hash of the decompressor's persistent state; see
    /// [`Compressor::state_fingerprint`].
    fn state_fingerprint(&self) -> u64 {
        0
    }
}

// Compile-time proof that lane state crosses threads: the engine relies on
// `Box<dyn Compressor>` / `Box<dyn Decompressor>` being `Send`.
const _: () = {
    const fn assert_send<T: Send + ?Sized>() {}
    assert_send::<dyn Compressor>();
    assert_send::<dyn Decompressor>();
};

/// Assemble a decode result: remaining `Raw` payload slots become moved
/// [`LayerUpdate::Dense`] entries and the structured tensors (whose slots
/// were taken) receive their prepared updates.
pub(crate) fn assemble_updates(
    slots: Vec<Option<Payload>>,
    structured: Vec<(usize, LayerUpdate)>,
    who: &str,
) -> Vec<LayerUpdate> {
    let mut out: Vec<LayerUpdate> = slots
        .into_iter()
        .map(|s| match s {
            Some(Payload::Raw(v)) => LayerUpdate::Dense(v),
            Some(other) => panic!("{who}: unexpected {other:?} for an uncompressed tensor"),
            // Placeholder for a structured tensor, patched below.
            None => LayerUpdate::Dense(Vec::new()),
        })
        .collect();
    for (tensor, update) in structured {
        out[tensor] = update;
    }
    out
}

/// FNV-1a over a stream of words — the shared basis-state fingerprint
/// (must be identical on the client and server side of a lane).
pub(crate) fn fnv1a_words(words: impl Iterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        h ^= w;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Fingerprint a set of optional basis matrices (layer order): presence
/// flag, dims, and every element's bit pattern.
pub(crate) fn basis_fingerprint<'a>(bases: impl Iterator<Item = Option<&'a Mat>>) -> u64 {
    let mut words: Vec<u64> = Vec::new();
    for b in bases {
        match b {
            None => words.push(u64::MAX),
            Some(m) => {
                words.push(((m.rows() as u64) << 32) | m.cols() as u64);
                words.extend(m.as_slice().iter().map(|x| x.to_bits() as u64));
            }
        }
    }
    fnv1a_words(words.into_iter())
}

/// Build the (compressor, decompressor) pair for a config with a private
/// single-lane [`BasisPool`]. Convenience for benches/tests that exercise
/// one lane; a real server shares one pool across every lane — use
/// [`build_pair_in`].
pub fn build_pair(
    kind: &crate::config::CompressorKind,
    meta: &ModelMeta,
    seed: u64,
) -> (Box<dyn Compressor>, Box<dyn Decompressor>) {
    build_pair_in(&BasisPool::new(), kind, meta, seed)
}

/// Build the (compressor, decompressor) pair for a config, interning all
/// server-side basis state in `pool`. The coordinator calls this once per
/// client lane with one shared pool, so bit-identical bases across lanes
/// collapse to one allocation and per-lane server state is a handle, not
/// a matrix.
pub fn build_pair_in(
    pool: &BasisPool,
    kind: &crate::config::CompressorKind,
    meta: &ModelMeta,
    seed: u64,
) -> (Box<dyn Compressor>, Box<dyn Decompressor>) {
    build_pair_with(pool, kind, meta, seed, crate::linalg::default_backend())
}

/// [`build_pair_in`] pinned to an explicit compute [`Backend`]. Both ends
/// of the lane get the same backend — the GradESTC lockstep invariant
/// (client and server replay the identical MGS repair) requires it.
pub fn build_pair_with(
    pool: &BasisPool,
    kind: &crate::config::CompressorKind,
    meta: &ModelMeta,
    seed: u64,
    backend: &'static dyn crate::linalg::Backend,
) -> (Box<dyn Compressor>, Box<dyn Decompressor>) {
    use crate::config::CompressorKind as K;
    match kind {
        K::None => {
            let c = codec::RawCompressor::new(meta);
            let d = codec::RawDecompressor;
            (Box::new(c), Box::new(d))
        }
        K::TopK { frac } => {
            let c = topk::TopKCompressor::new(meta, *frac);
            let d = topk::TopKDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::FedPaq { bits } => {
            let c = quant::QuantCompressor::new(meta, *bits, None, seed);
            let d = quant::QuantDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::FedQClip { bits, clip } => {
            let c = quant::QuantCompressor::new(meta, *bits, Some(*clip as f32), seed);
            let d = quant::QuantDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::SignSgd => {
            let c = quant::SignCompressor::new(meta);
            let d = quant::SignDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::SvdFed { k, gamma } => {
            let c = svdfed::SvdFedCompressor::with_backend(meta, *k, *gamma, seed, backend);
            let d = svdfed::SvdFedDecompressor::with_pool(meta, pool.clone());
            (Box::new(c), Box::new(d))
        }
        K::GradEstc(p) => {
            let c = GradEstcClient::with_backend(meta, p.clone(), seed, backend);
            let d = GradEstcServer::with_pool_backend(meta, p.clone(), pool.clone(), backend);
            if p.error_feedback {
                (Box::new(EfWrapper::new(c, meta, p.clone())), Box::new(d))
            } else {
                (Box::new(c), Box::new(d))
            }
        }
    }
}
