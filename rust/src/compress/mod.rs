//! Gradient compression framework: the paper's GradESTC plus every
//! baseline it is evaluated against.
//!
//! A [`Compressor`] turns a client's per-layer update into a compact
//! [`Payload`]; the server-side [`Decompressor`] reconstructs it. Payload
//! byte sizes are *exact wire sizes* (what a real serializer would emit),
//! charged to the communication ledger by the coordinator.
//!
//! Implementations:
//! * [`gradestc`] — the paper's method (Algorithms 1 & 2).
//! * [`topk`] — magnitude sparsification (Stich et al.).
//! * [`quant`] — FedPAQ stochastic uniform quantization + FedQClip clipped
//!   variant + SignSGD.
//! * [`svdfed`] — shared global basis via SVD with error-triggered refresh.
//! * [`error_feedback`] — local residual accumulation wrapper (paper's
//!   future-work extension).

pub mod codec;
pub mod error_feedback;
pub mod gradestc;
pub mod quant;
pub mod svdfed;
pub mod topk;

pub use codec::Payload;
pub use error_feedback::EfWrapper;
pub use gradestc::{GradEstcClient, GradEstcServer};

use crate::model::meta::ModelMeta;

/// Per-round, per-client compression statistics surfaced to the recorder.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompressStats {
    /// rSVD candidate count consumed this round (Σd proxy, paper Tab. IV).
    pub sum_d: u64,
    /// Basis vectors actually replaced this round (GradESTC only).
    pub replaced: u64,
}

/// Client-side compressor over a full model update (all tensors, in layer
/// order; non-compressed tensors pass through as raw f32).
///
/// `Send` is a supertrait: the round engine moves each client lane — the
/// compressor together with its paired [`Decompressor`] — into worker
/// tasks, so every implementation must be transferable across threads.
pub trait Compressor: Send {
    /// Compress one round's update. `update[i]` is tensor `i`'s flat data.
    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats);
}

/// Server-side decompressor paired with one client's compressor. `Send`
/// for the same reason as [`Compressor`]: it rides in the client lane.
pub trait Decompressor: Send {
    /// Reconstruct tensor-aligned flat updates from payloads.
    fn decompress(&mut self, payloads: &[Payload]) -> Vec<Vec<f32>>;
}

// Compile-time proof that lane state crosses threads: the engine relies on
// `Box<dyn Compressor>` / `Box<dyn Decompressor>` being `Send`.
const _: () = {
    const fn assert_send<T: Send + ?Sized>() {}
    assert_send::<dyn Compressor>();
    assert_send::<dyn Decompressor>();
};

/// Build the (compressor, decompressor) pair for a config.
pub fn build_pair(
    kind: &crate::config::CompressorKind,
    meta: &ModelMeta,
    seed: u64,
) -> (Box<dyn Compressor>, Box<dyn Decompressor>) {
    use crate::config::CompressorKind as K;
    match kind {
        K::None => {
            let c = codec::RawCompressor::new(meta);
            let d = codec::RawDecompressor;
            (Box::new(c), Box::new(d))
        }
        K::TopK { frac } => {
            let c = topk::TopKCompressor::new(meta, *frac);
            let d = topk::TopKDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::FedPaq { bits } => {
            let c = quant::QuantCompressor::new(meta, *bits, None, seed);
            let d = quant::QuantDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::FedQClip { bits, clip } => {
            let c = quant::QuantCompressor::new(meta, *bits, Some(*clip as f32), seed);
            let d = quant::QuantDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::SignSgd => {
            let c = quant::SignCompressor::new(meta);
            let d = quant::SignDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::SvdFed { k, gamma } => {
            let c = svdfed::SvdFedCompressor::new(meta, *k, *gamma, seed);
            let d = svdfed::SvdFedDecompressor::new(meta);
            (Box::new(c), Box::new(d))
        }
        K::GradEstc(p) => {
            let c = GradEstcClient::new(meta, p.clone(), seed);
            let d = GradEstcServer::new(meta, p.clone());
            if p.error_feedback {
                (Box::new(EfWrapper::new(c, meta, p.clone())), Box::new(d))
            } else {
                (Box::new(c), Box::new(d))
            }
        }
    }
}
