//! Wire payloads and their exact byte accounting.
//!
//! Every compressor emits one [`Payload`] per model tensor. `wire_bytes`
//! is the exact size the binary serializer ([`crate::net::wire`]) puts on
//! the uplink — the number the paper's Table III totals are made of (paper
//! Eq. 14 for GradESTC: `C = k·n/l + d_r·l + k` floats… 4 bytes per f32,
//! 4 per index, plus the fixed 8-byte frame header).
//!
//! Since the transport subsystem landed, these payloads really are
//! serialized: the round engine encodes them with
//! [`wire::encode`](crate::net::wire::encode), ships the buffer across the
//! [`Transport`](crate::net::Transport), and decodes server-side, and the
//! communication ledger is charged from the encoded buffer's length.
//! `wire_bytes` is therefore a *checked invariant*, not an estimate:
//! `wire::encode([p]).len() == p.wire_bytes()` for every variant
//! (`debug_assert`ed on encode and property-tested in
//! `rust/tests/properties.rs`, including bit-packing edge cases).

/// Fixed per-payload frame header (type tag + length), bytes.
pub const FRAME_HEADER: u64 = 8;

/// One tensor's compressed update on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Uncompressed f32 data.
    Raw(Vec<f32>),
    /// Sparse (index, value) pairs for a tensor of `len` entries.
    Sparse {
        /// Flat indices.
        indices: Vec<u32>,
        /// Values at those indices.
        values: Vec<f32>,
        /// Dense length.
        len: usize,
    },
    /// Uniform quantization: `x ≈ lo + q·(hi-lo)/(2^bits-1)`.
    Quantized {
        /// Minimum of the quantization range.
        lo: f32,
        /// Maximum of the quantization range.
        hi: f32,
        /// Bit width (1..=16).
        bits: u8,
        /// Bit-packed codes.
        packed: Vec<u8>,
        /// Dense length.
        len: usize,
    },
    /// 1-bit signs with a single scale (SignSGD with magnitude).
    Signs {
        /// Per-tensor scale (mean |x|).
        scale: f32,
        /// Bit-packed signs.
        packed: Vec<u8>,
        /// Dense length.
        len: usize,
    },
    /// GradESTC uplink for one layer (paper Alg. 1 output): replacement
    /// indices ℙ, replacement vectors 𝕄 (d_r × l, row-major), and the full
    /// coefficient matrix A (k × m, row-major).
    Basis {
        /// Indices into the basis to overwrite (ℙ).
        replace_idx: Vec<u32>,
        /// New basis vectors, `replace_idx.len() × l` row-major (𝕄).
        new_vectors: Vec<f32>,
        /// Combination coefficients A, `k × m` row-major.
        coeffs: Vec<f32>,
        /// Segment length `l`.
        l: usize,
        /// Basis size `k`.
        k: usize,
        /// Columns `m`.
        m: usize,
    },
    /// SVDFed uplink: coefficients against the shared server basis, plus an
    /// optional basis refresh (k × l row-major) when the fit degraded.
    SvdCoeffs {
        /// Coefficients A, `k × m` row-major.
        coeffs: Vec<f32>,
        /// Replacement basis if this round triggered a re-fit.
        refit_basis: Option<Vec<f32>>,
        /// Segment length `l`.
        l: usize,
        /// Basis size `k`.
        k: usize,
        /// Columns `m`.
        m: usize,
    },
}

impl Payload {
    /// Exact uplink size in bytes.
    pub fn wire_bytes(&self) -> u64 {
        FRAME_HEADER
            + match self {
                Payload::Raw(v) => 4 * v.len() as u64,
                Payload::Sparse { indices, values, .. } => {
                    4 * indices.len() as u64 + 4 * values.len() as u64 + 4
                }
                Payload::Quantized { packed, .. } => packed.len() as u64 + 4 + 4 + 1 + 4,
                Payload::Signs { packed, .. } => packed.len() as u64 + 4 + 4,
                Payload::Basis { replace_idx, new_vectors, coeffs, .. } => {
                    4 * replace_idx.len() as u64
                        + 4 * new_vectors.len() as u64
                        + 4 * coeffs.len() as u64
                        + 12 // l,k,m
                }
                Payload::SvdCoeffs { coeffs, refit_basis, .. } => {
                    4 * coeffs.len() as u64
                        + refit_basis.as_ref().map(|b| 4 * b.len() as u64 + 1).unwrap_or(1)
                        + 12
                }
            }
    }
}

/// Pass-through compressor (FedAvg baseline): every tensor goes raw.
pub struct RawCompressor {
    ntensors: usize,
}

impl RawCompressor {
    /// Build for a model.
    pub fn new(meta: &crate::model::meta::ModelMeta) -> Self {
        RawCompressor { ntensors: meta.layers.len() }
    }
}

impl super::Compressor for RawCompressor {
    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, super::CompressStats) {
        assert_eq!(update.len(), self.ntensors);
        (
            update.iter().map(|t| Payload::Raw(t.clone())).collect(),
            super::CompressStats::default(),
        )
    }
}

/// Pass-through decompressor.
pub struct RawDecompressor;

impl super::Decompressor for RawDecompressor {
    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<super::LayerUpdate> {
        payloads
            .into_iter()
            .map(|p| match p {
                // Move the payload's buffer straight through — the only
                // dense copy in the raw pipeline is the wire decode itself.
                Payload::Raw(v) => super::LayerUpdate::Dense(v),
                other => panic!("RawDecompressor got {other:?}"),
            })
            .collect()
    }
}

/// Pack `bits`-wide codes into bytes (LSB-first within each byte).
pub fn pack_bits(codes: &[u32], bits: u8) -> Vec<u8> {
    assert!((1..=16).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(c < (1u32 << bits));
        for b in 0..bits as usize {
            if (c >> b) & 1 == 1 {
                out[bitpos >> 3] |= 1 << (bitpos & 7);
            }
            bitpos += 1;
        }
    }
    out
}

/// Dequantize bit-packed uniform codes: `x̂ = lo + q·(hi-lo)/(2^bits-1)`
/// per element. The *single* definition of the reconstruction formula —
/// both [`LayerUpdate::to_dense`](crate::compress::LayerUpdate::to_dense)
/// and the server aggregation plane's quantized fold stream from here, so
/// the two paths agree bit-for-bit by construction.
pub fn dequant_values(
    lo: f32,
    hi: f32,
    bits: u8,
    packed: &[u8],
    n: usize,
) -> impl Iterator<Item = f32> {
    let levels = (1u32 << bits) - 1;
    let step = (hi - lo) / levels as f32;
    unpack_bits(packed, bits, n).into_iter().map(move |c| lo + c as f32 * step)
}

/// Inverse of [`pack_bits`].
pub fn unpack_bits(packed: &[u8], bits: u8, n: usize) -> Vec<u32> {
    assert!((1..=16).contains(&bits));
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut c = 0u32;
        for b in 0..bits as usize {
            if (packed[bitpos >> 3] >> (bitpos & 7)) & 1 == 1 {
                c |= 1 << b;
            }
            bitpos += 1;
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_wire_bytes() {
        let p = Payload::Raw(vec![0.0; 100]);
        assert_eq!(p.wire_bytes(), FRAME_HEADER + 400);
    }

    #[test]
    fn basis_wire_bytes_matches_eq14() {
        // Paper Eq. 14: C = k·m (A) + d_r·l (new vectors) + d_r (indices),
        // in elements; we charge 4 bytes each + header.
        let (l, k, m, dr) = (64usize, 8usize, 32usize, 3usize);
        let p = Payload::Basis {
            replace_idx: vec![0; dr],
            new_vectors: vec![0.0; dr * l],
            coeffs: vec![0.0; k * m],
            l,
            k,
            m,
        };
        let expect = FRAME_HEADER + 4 * (dr + dr * l + k * m) as u64 + 12;
        assert_eq!(p.wire_bytes(), expect);
    }

    #[test]
    fn pack_roundtrip_all_widths() {
        for bits in [1u8, 2, 3, 4, 7, 8, 12, 16] {
            let max = (1u32 << bits) - 1;
            let codes: Vec<u32> =
                (0..257u64).map(|i| ((i * 2654435761) % (max as u64 + 1)) as u32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(unpack_bits(&packed, bits, codes.len()), codes);
            assert_eq!(packed.len(), (codes.len() * bits as usize).div_ceil(8));
        }
    }

    #[test]
    fn raw_pair_roundtrip() {
        use crate::compress::{Compressor, Decompressor};
        use crate::config::ModelKind;
        use crate::model::meta::layer_table;
        let meta = layer_table(ModelKind::LeNet5);
        let mut c = RawCompressor::new(&meta);
        let update: Vec<Vec<f32>> =
            meta.layers.iter().map(|l| vec![0.5; l.size()]).collect();
        let (payloads, _) = c.compress(&update);
        let mut d = RawDecompressor;
        assert_eq!(d.decompress(&payloads), update);
    }
}
