//! Quantization baselines.
//!
//! * FedPAQ (Reisizadeh et al. 2020): stochastic uniform quantization of
//!   each compressible tensor to `bits` bits over its [min, max] range.
//! * FedQClip (Qu et al. 2025): clip the tensor to `clip · rms` first,
//!   bounding the quantization range against heavy-tailed updates, then
//!   quantize.
//! * SignSGD (Bernstein et al. 2018): 1-bit signs scaled by mean |x|.
//!
//! Stochastic rounding keeps the quantizer unbiased:
//! `E[Q(x)] = x` — the property the FedPAQ convergence proof needs; tested
//! below.

use super::codec::{pack_bits, Payload};
use super::{CompressStats, Compressor, Decompressor};
use crate::model::meta::ModelMeta;
use crate::util::rng::Pcg64;

/// Tensors below this stay raw (range metadata would outweigh savings).
const MIN_QUANT: usize = 64;

/// FedPAQ / FedQClip client.
pub struct QuantCompressor {
    bits: u8,
    clip: Option<f32>,
    compressible: Vec<bool>,
    rng: Pcg64,
}

impl QuantCompressor {
    /// `clip = None` → FedPAQ; `clip = Some(c)` → FedQClip with range
    /// clipped to `c · rms(x)`.
    pub fn new(meta: &ModelMeta, bits: u8, clip: Option<f32>, seed: u64) -> Self {
        assert!((1..=16).contains(&bits), "bits must be 1..=16");
        QuantCompressor {
            bits,
            clip,
            compressible: meta
                .layers
                .iter()
                .map(|l| l.compressible() && l.size() >= MIN_QUANT)
                .collect(),
            rng: Pcg64::new(seed, 0x9A77),
        }
    }

    fn quantize(&mut self, t: &[f32]) -> Payload {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        // Optional clipping bound (FedQClip).
        let bound = self.clip.map(|c| {
            let rms = (t.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                / t.len().max(1) as f64)
                .sqrt() as f32;
            c * rms
        });
        let clipped: Vec<f32> = match bound {
            Some(b) if b > 0.0 => t.iter().map(|&x| x.clamp(-b, b)).collect(),
            _ => t.to_vec(),
        };
        for &x in &clipped {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !lo.is_finite() || !hi.is_finite() {
            lo = 0.0;
            hi = f32::EPSILON;
        } else if hi <= lo {
            // Constant tensor: keep lo so code 0 reconstructs the value.
            hi = lo + f32::EPSILON.max(lo.abs() * 1e-6);
        }
        let levels = (1u32 << self.bits) - 1;
        let scale = (hi - lo) / levels as f32;
        let codes: Vec<u32> = clipped
            .iter()
            .map(|&x| {
                let pos = (x - lo) / scale; // in [0, levels]
                let floor = pos.floor();
                let frac = pos - floor;
                // stochastic rounding: up with prob = frac
                let up = (self.rng.f32() < frac) as u32;
                ((floor as u32) + up).min(levels)
            })
            .collect();
        Payload::Quantized { lo, hi, bits: self.bits, packed: pack_bits(&codes, self.bits), len: t.len() }
    }
}

impl Compressor for QuantCompressor {
    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats) {
        let compressible = self.compressible.clone();
        let payloads = update
            .iter()
            .zip(&compressible)
            .map(|(t, &c)| if c { self.quantize(t) } else { Payload::Raw(t.clone()) })
            .collect();
        (payloads, CompressStats::default())
    }
}

/// FedPAQ / FedQClip server.
pub struct QuantDecompressor {
    sizes: Vec<usize>,
}

impl QuantDecompressor {
    /// Build for a model.
    pub fn new(meta: &ModelMeta) -> Self {
        QuantDecompressor { sizes: meta.layers.iter().map(|l| l.size()).collect() }
    }
}

impl Decompressor for QuantDecompressor {
    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<super::LayerUpdate> {
        payloads
            .into_iter()
            .zip(&self.sizes)
            .map(|(p, &n)| match p {
                Payload::Raw(v) => super::LayerUpdate::Dense(v),
                Payload::Quantized { lo, hi, bits, packed, len } => {
                    assert_eq!(len, n);
                    // Codes stay bit-packed: the aggregation plane folds
                    // `lo + q·step` per element straight from the packing.
                    super::LayerUpdate::QuantDense { lo, hi, bits, packed, len }
                }
                other => panic!("QuantDecompressor got {other:?}"),
            })
            .collect()
    }
}

/// SignSGD client: sign bits + mean-|x| scale.
pub struct SignCompressor {
    compressible: Vec<bool>,
}

impl SignCompressor {
    /// Build for a model.
    pub fn new(meta: &ModelMeta) -> Self {
        SignCompressor {
            compressible: meta
                .layers
                .iter()
                .map(|l| l.compressible() && l.size() >= MIN_QUANT)
                .collect(),
        }
    }
}

impl Compressor for SignCompressor {
    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats) {
        let payloads = update
            .iter()
            .zip(&self.compressible)
            .map(|(t, &c)| {
                if !c {
                    return Payload::Raw(t.clone());
                }
                let scale = t.iter().map(|&x| x.abs() as f64).sum::<f64>() as f32
                    / t.len().max(1) as f32;
                let codes: Vec<u32> = t.iter().map(|&x| (x >= 0.0) as u32).collect();
                Payload::Signs { scale, packed: pack_bits(&codes, 1), len: t.len() }
            })
            .collect();
        (payloads, CompressStats::default())
    }
}

/// SignSGD server.
pub struct SignDecompressor {
    sizes: Vec<usize>,
}

impl SignDecompressor {
    /// Build for a model.
    pub fn new(meta: &ModelMeta) -> Self {
        SignDecompressor { sizes: meta.layers.iter().map(|l| l.size()).collect() }
    }
}

impl Decompressor for SignDecompressor {
    fn decode(&mut self, payloads: Vec<Payload>) -> Vec<super::LayerUpdate> {
        payloads
            .into_iter()
            .zip(&self.sizes)
            .map(|(p, &n)| match p {
                Payload::Raw(v) => super::LayerUpdate::Dense(v),
                Payload::Signs { scale, packed, len } => {
                    assert_eq!(len, n);
                    // A sign field is 1-bit uniform quantization over
                    // [-scale, scale]: code 0 → -scale + 0·2scale = -scale,
                    // code 1 → -scale + 2scale = +scale, both exact in f32.
                    super::LayerUpdate::QuantDense {
                        lo: -scale,
                        hi: scale,
                        bits: 1,
                        packed,
                        len,
                    }
                }
                other => panic!("SignDecompressor got {other:?}"),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::model::meta::layer_table;

    fn lenet_update(seed: u64) -> (ModelMeta, Vec<Vec<f32>>) {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(seed);
        let update = meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
        (meta, update)
    }

    use crate::model::meta::ModelMeta;

    #[test]
    fn quant_error_bounded_by_step() {
        let (meta, update) = lenet_update(1);
        let mut c = QuantCompressor::new(&meta, 8, None, 7);
        let (payloads, _) = c.compress(&update);
        let mut d = QuantDecompressor::new(&meta);
        let rec = d.decompress(&payloads);
        for ((orig, r), layer) in update.iter().zip(&rec).zip(&meta.layers) {
            if !(layer.compressible() && layer.size() >= MIN_QUANT) {
                continue;
            }
            let lo = orig.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = orig.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let step = (hi - lo) / 255.0;
            for (o, v) in orig.iter().zip(r) {
                assert!((o - v).abs() <= step + 1e-6, "{}: |{o}-{v}| > {step}", layer.name);
            }
        }
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        // Quantize the same constant vector many times: the mean must
        // converge to the true value (unbiasedness).
        let meta = layer_table(ModelKind::LeNet5);
        let i = meta.index_of("fc1.kernel").unwrap();
        let n = meta.layers[i].size();
        let truth = 0.3337f32;
        let mut update: Vec<Vec<f32>> =
            meta.layers.iter().map(|l| vec![0.0; l.size()]).collect();
        // Give the tensor a range so lo/hi aren't degenerate.
        update[i] = (0..n).map(|j| if j < 2 { (j as f32) - 0.5 } else { truth }).collect();
        let mut c = QuantCompressor::new(&meta, 4, None, 3);
        let mut d = QuantDecompressor::new(&meta);
        let mut acc = 0.0f64;
        let trials = 60;
        for _ in 0..trials {
            let (p, _) = c.compress(&update);
            let rec = d.decompress(&p);
            acc += rec[i][10] as f64;
        }
        let mean = acc / trials as f64;
        assert!((mean - truth as f64).abs() < 0.02, "mean {mean} vs {truth}");
    }

    #[test]
    fn clip_bounds_range() {
        let (meta, mut update) = lenet_update(2);
        // Inject an outlier.
        let i = meta.index_of("fc1.kernel").unwrap();
        update[i][0] = 1000.0;
        let mut c = QuantCompressor::new(&meta, 8, Some(2.5), 9);
        let (payloads, _) = c.compress(&update);
        if let Payload::Quantized { lo, hi, .. } = &payloads[i] {
            assert!(*hi < 100.0, "clip failed: hi={hi}");
            assert!(*lo > -100.0);
        } else {
            panic!("expected quantized payload");
        }
    }

    #[test]
    fn sign_roundtrip() {
        let (meta, update) = lenet_update(3);
        let mut c = SignCompressor::new(&meta);
        let (payloads, _) = c.compress(&update);
        let mut d = SignDecompressor::new(&meta);
        let rec = d.decompress(&payloads);
        let i = meta.index_of("fc1.kernel").unwrap();
        for (o, v) in update[i].iter().zip(&rec[i]) {
            assert_eq!(o.signum(), v.signum());
        }
        // 1 bit per entry → payload ≈ n/8 bytes
        assert!(payloads[i].wire_bytes() < (update[i].len() / 8 + 64) as u64);
    }

    #[test]
    fn fedpaq_8bit_compression_ratio() {
        // ~4x smaller than raw (paper: 8-bit ≈ 1/4 of 32-bit).
        let (meta, update) = lenet_update(4);
        let mut c = QuantCompressor::new(&meta, 8, None, 5);
        let (payloads, _) = c.compress(&update);
        let raw: u64 = update.iter().map(|t| 4 * t.len() as u64).sum();
        let wire: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
        assert!(
            (wire as f64) < 0.30 * raw as f64,
            "wire {wire} raw {raw}"
        );
    }

    #[test]
    fn constant_tensor_quantizes_safely() {
        let meta = layer_table(ModelKind::LeNet5);
        let update: Vec<Vec<f32>> =
            meta.layers.iter().map(|l| vec![0.5; l.size()]).collect();
        let mut c = QuantCompressor::new(&meta, 8, None, 1);
        let (p, _) = c.compress(&update);
        let mut d = QuantDecompressor::new(&meta);
        let rec = d.decompress(&p);
        let i = meta.index_of("fc1.kernel").unwrap();
        for v in &rec[i] {
            assert!((v - 0.5).abs() < 1e-3);
        }
    }
}
