//! Error feedback wrapper (paper §VI future work, implemented as an
//! extension).
//!
//! Keeps the compression residual `e = u − decompress(compress(u))` on the
//! client and adds it to the next round's update before compressing, so
//! systematically-dropped components are eventually transmitted (Stich et
//! al. 2018; Seide et al. 2014).
//!
//! The wrapper needs a local decompressor twin to know what the server
//! will reconstruct; for GradESTC that twin shares the client's basis
//! state implicitly (the client can reconstruct `Ĝ = M·A` itself), so the
//! wrapper runs a mirrored [`GradEstcServer`].

use super::codec::Payload;
use super::gradestc::{GradEstcClient, GradEstcServer};
use super::{CompressStats, Compressor, Decompressor};

/// Error-feedback wrapper around [`GradEstcClient`].
pub struct EfWrapper {
    inner: GradEstcClient,
    mirror: GradEstcServer,
    residual: Option<Vec<Vec<f32>>>,
}

impl EfWrapper {
    /// Wrap a client compressor; the mirror decompressor is constructed
    /// from the same model/parameters so its state stays in lockstep with
    /// the real server's.
    pub fn new(
        inner: GradEstcClient,
        meta: &crate::model::meta::ModelMeta,
        params: crate::config::GradEstcParams,
    ) -> Self {
        // The mirror must replay the client's arithmetic exactly, so it
        // runs on the same compute backend.
        let mirror = GradEstcServer::with_pool_backend(
            meta,
            params,
            super::BasisPool::new(),
            inner.backend(),
        );
        EfWrapper { inner, mirror, residual: None }
    }
}

impl Compressor for EfWrapper {
    fn state_fingerprint(&self) -> u64 {
        // The lane's lockstep-relevant state is the wrapped client's basis;
        // the residual is local-only and has no server mirror.
        self.inner.state_fingerprint()
    }

    fn compress(&mut self, update: &[Vec<f32>]) -> (Vec<Payload>, CompressStats) {
        // u' = u + residual
        let corrected: Vec<Vec<f32>> = match &self.residual {
            None => update.to_vec(),
            Some(res) => update
                .iter()
                .zip(res)
                .map(|(u, r)| u.iter().zip(r).map(|(a, b)| a + b).collect())
                .collect(),
        };
        let (payloads, stats) = self.inner.compress(&corrected);
        // Residual = corrected − reconstruction.
        let rec = self.mirror.decompress(&payloads);
        let residual = corrected
            .iter()
            .zip(&rec)
            .map(|(u, r)| u.iter().zip(r).map(|(a, b)| a - b).collect())
            .collect();
        self.residual = Some(residual);
        (payloads, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GradEstcParams, ModelKind};
    use crate::model::meta::layer_table;
    use crate::util::rng::Pcg64;

    #[test]
    fn residual_tracked_and_bounded() {
        let meta = layer_table(ModelKind::LeNet5);
        let p = GradEstcParams { k: 8, error_feedback: true, ..Default::default() };
        let client = GradEstcClient::new(&meta, p.clone(), 3);
        let mut ef = EfWrapper::new(client, &meta, p.clone());
        let mut server = GradEstcServer::new(&meta, p);
        let mut rng = Pcg64::seeded(1);
        let mut prev_norm = f64::INFINITY;
        for round in 0..6 {
            let update: Vec<Vec<f32>> = meta
                .layers
                .iter()
                .map(|l| {
                    let mut v = rng.normal_vec(l.size());
                    v.iter_mut().for_each(|x| *x *= 0.01);
                    v
                })
                .collect();
            let (payloads, _) = ef.compress(&update);
            let _ = server.decompress(&payloads);
            let res_norm: f64 = ef
                .residual
                .as_ref()
                .unwrap()
                .iter()
                .flat_map(|t| t.iter())
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt();
            assert!(res_norm.is_finite());
            if round >= 4 {
                // residual must not blow up round over round
                assert!(res_norm < 10.0 * prev_norm.max(1e-9), "residual diverging");
            }
            prev_norm = res_norm;
        }
    }

    #[test]
    fn ef_transmits_what_plain_drops() {
        // A constant update orthogonal to the learned basis is dropped by
        // plain GradESTC each round; EF accumulates it so the *sum* of
        // reconstructions approaches the sum of updates.
        let meta = layer_table(ModelKind::LeNet5);
        let p = GradEstcParams { k: 4, error_feedback: true, ..Default::default() };
        let mut ef =
            EfWrapper::new(GradEstcClient::new(&meta, p.clone(), 5), &meta, p.clone());
        let mut server = GradEstcServer::new(&meta, p);
        let mut rng = Pcg64::seeded(2);
        let update: Vec<Vec<f32>> =
            meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect();
        let ct = ef.inner.compressed_tensors()[0];
        let mut sum_rec_t = vec![0.0f64; update[ct].len()];
        // Cumulative relative error must shrink ~1/T: the residual stays
        // bounded while the transmitted total grows, so EF eventually
        // delivers everything plain GradESTC would keep dropping.
        let err_at = |sum: &[f64], t: usize| -> f64 {
            let truth: Vec<f64> =
                update[ct].iter().map(|&x| x as f64 * t as f64).collect();
            let num: f64 = sum
                .iter()
                .zip(&truth)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            let den: f64 = truth.iter().map(|x| x * x).sum::<f64>().sqrt();
            num / den
        };
        let mut err_early = f64::NAN;
        let rounds = 40;
        for t in 1..=rounds {
            let (payloads, _) = ef.compress(&update);
            let rec = server.decompress(&payloads);
            for (s, &v) in sum_rec_t.iter_mut().zip(&rec[ct]) {
                *s += v as f64;
            }
            if t == 8 {
                err_early = err_at(&sum_rec_t, t);
            }
        }
        let err_late = err_at(&sum_rec_t, rounds);
        assert!(
            err_late < 0.6 * err_early,
            "cumulative error not shrinking: early {err_early} late {err_late}"
        );
    }
}
