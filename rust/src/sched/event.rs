//! The deterministic discrete-event core: a min-heap of events keyed by
//! `(f64 virtual time, u64 sequence number)`.
//!
//! Two properties make replay bit-identical at any worker count:
//!
//! * **Total order on time.** Keys compare with [`f64::total_cmp`], so
//!   every pair of finite times has one answer (no `PartialOrd` holes),
//!   and pushing a non-finite time is rejected eagerly (`assert!`) instead
//!   of corrupting the heap order.
//! * **Sequence tie-break.** Every push is stamped with a monotonically
//!   increasing sequence number; events scheduled for the *same* virtual
//!   instant pop in push order. Schedulers push in deterministic order
//!   (participant order, arrival-processing order), so simultaneous events
//!   never introduce nondeterminism.
//!
//! The queue itself is single-threaded — parallelism in the scheduler
//! plane lives inside the *handling* of an event (the fanned client phase),
//! never in the ordering of events.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One queued event: `(time, seq)` key plus the scheduler-defined payload.
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    /// Reversed comparison: [`BinaryHeap`] is a max-heap, so "greater"
    /// must mean "earlier (time, seq)".
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-queue of `(time, seq, event)` triples.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue; the first push gets sequence number 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `event` at virtual `time`; returns the sequence number
    /// stamped on it. `time` must be finite (virtual clocks never hold NaN
    /// or ±∞ — a non-finite completion time is a bug upstream, surfaced
    /// here instead of silently mis-ordering the heap).
    pub fn push(&mut self, time: f64, event: E) -> u64 {
        assert!(time.is_finite(), "event time {time} must be finite");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time, seq, event });
        seq
    }

    /// Remove and return the earliest `(time, seq, event)`.
    pub fn pop(&mut self) -> Option<(f64, u64, E)> {
        self.heap.pop().map(|e| (e.time, e.seq, e.event))
    }

    /// Virtual time of the earliest queued event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Queued event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, _, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_push_order() {
        let mut q = EventQueue::new();
        let s0 = q.push(5.0, "first");
        let s1 = q.push(5.0, "second");
        let s2 = q.push(5.0, "third");
        assert!(s0 < s1 && s1 < s2);
        assert_eq!(q.pop().map(|(_, s, e)| (s, e)), Some((s0, "first")));
        assert_eq!(q.pop().map(|(_, s, e)| (s, e)), Some((s1, "second")));
        assert_eq!(q.pop().map(|(_, s, e)| (s, e)), Some((s2, "third")));
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(2.0, 2);
        q.push(1.0, 1);
        assert_eq!(q.pop().map(|(t, _, e)| (t, e)), Some((1.0, 1)));
        q.push(0.5, 0);
        q.push(3.0, 3);
        assert_eq!(q.pop().map(|(t, _, e)| (t, e)), Some((0.5, 0)));
        assert_eq!(q.pop().map(|(t, _, e)| (t, e)), Some((2.0, 2)));
        assert_eq!(q.pop().map(|(t, _, e)| (t, e)), Some((3.0, 3)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_and_len_track_contents() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(4.0, 0);
        q.push(1.5, 1);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek_time(), Some(1.5));
        q.pop();
        assert_eq!(q.peek_time(), Some(4.0));
    }

    #[test]
    fn negative_zero_and_subnormal_times_total_order() {
        // total_cmp puts -0.0 before +0.0; determinism only needs "one
        // consistent answer", which this locks in.
        let mut q = EventQueue::new();
        q.push(0.0, "pos");
        q.push(-0.0, "neg");
        assert_eq!(q.pop().map(|(_, _, e)| e), Some("neg"));
        assert_eq!(q.pop().map(|(_, _, e)| e), Some("pos"));
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn non_finite_time_rejected() {
        EventQueue::new().push(f64::NAN, ());
    }

    #[test]
    fn replay_is_bit_identical() {
        // Same pushes ⇒ same pop sequence, including tie groups.
        let times = [2.0, 1.0, 1.0, 3.5, 1.0, 2.0, 0.25];
        let run = || {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.push(t, i);
            }
            std::iter::from_fn(|| q.pop()).map(|(t, s, e)| (t.to_bits(), s, e)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
