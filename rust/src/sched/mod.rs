//! The scheduler plane: synchronous, semi-synchronous, and asynchronous
//! federated rounds on a deterministic virtual clock.
//!
//! Everything below PR 4 simulated *what* crosses the network (encoded
//! frames, per-client links, dropout, straggler deadlines) but only ever
//! drove it with one control flow: lockstep FedAvg, where the server waits
//! for every survivor before aggregating. Under heterogeneous links
//! (`net.het_spread > 0`) that wastes wall-clock — the round is as slow as
//! its slowest client (Ozfatura et al.'s partial-participation setting;
//! Edin et al.'s practical-limitations study). This module turns the
//! per-client [`LinkProfile`](crate::net::LinkProfile) timing model into
//! an actual simulation clock and makes the control flow pluggable:
//!
//! * [`SyncScheduler`] — today's lockstep loop, verbatim: it drives
//!   [`Simulation::step`], so `--sched sync` is *structurally*
//!   bit-identical to the legacy engine (and `rust/tests/sched.rs` locks
//!   the equivalence in anyway).
//! * [`SemiSyncScheduler`] — aggregate whatever arrived by the straggler
//!   deadline; a straggler's update is **rolled into the round that is
//!   open when it lands** instead of discarded, and its uplink bytes are
//!   charged exactly once, in that round (the round they crossed the
//!   wire).
//! * [`AsyncBufferedScheduler`] — FedBuff-style buffered asynchrony: the
//!   server folds each arriving update into the
//!   [`ServerAggregator`](crate::coordinator::ServerAggregator) *as it
//!   lands* and applies after every `k` arrivals, discounting a stale
//!   update's fold weight by `1 / (1 + τ)^p`, where `τ` is the number of
//!   server model versions that elapsed between the client's dispatch and
//!   its arrival and `p` is the `staleness` knob (`0` disables the
//!   discount).
//!
//! # Virtual time
//!
//! A client dispatched at virtual time `t` with a `b`-byte broadcast and a
//! `u`-byte upload completes at
//!
//! ```text
//! t + ComputeModel::draw(dispatch, cid)            // local-SGD latency
//!   + LinkProfile::round_trip_time(b, u)           // downlink + uplink
//! ```
//!
//! Completions become events in the [`event::EventQueue`] — a min-heap
//! keyed `(f64 time, u64 seq)` with [`f64::total_cmp`] and a push-order
//! sequence tie-break — so replay is bit-identical at any worker count:
//! worker threads parallelize the *handling* of an event (the fanned
//! client phase), never the order of events.
//!
//! # Lockstep under out-of-order arrival
//!
//! Each client lane owns its paired compressor/decompressor
//! ([`Client`](crate::coordinator::Client)), and a lane is never
//! re-dispatched before its previous upload is decoded, so the per-lane
//! compress → decode alternation — the temporal-correlation contract — is
//! preserved no matter how arrivals interleave *across* lanes.
//! `rust/tests/sched.rs` asserts the paired state fingerprints stay equal
//! under both semi-sync rollover and async reordering.
//!
//! # Knobs
//!
//! [`SchedConfig`] rides in `ExperimentConfig::sched` (JSON object
//! `"sched"`, absent ⇒ sync — byte- and bit-identical to the pre-sched
//! engine) and on the CLI as
//! `--sched sync|semisync|async[:k=8,staleness=0.5,adaptive=1,lr_tau=0.5,conc=2]`
//! plus `--compute-s` / `--compute-spread` for the per-client compute-time
//! draw. The defaults (`sync`, zero compute time) change nothing.
//!
//! # Availability & churn (plane 10)
//!
//! [`avail::AvailModel`] answers "is client `cid` reachable at virtual
//! time `t`?" as a pure function of `(seed, cid, vtime)` on dedicated
//! seed streams (diurnal square waves + Poisson departure churn; see its
//! module docs). When armed (`--avail < 1` or `--churn > 0`):
//!
//! * the async sampler never dispatches an offline client, and a dispatch
//!   whose client departs mid-flight becomes a typed `Fault` event —
//!   slot released, zero bytes charged, counted and traced
//!   ([`Phase::Fault`]);
//! * a faulted lane is **discarded** (not just unpinned): its paired
//!   compressor state advanced at dispatch with no decode to match, so
//!   the only way a returning client stays in lockstep is a fresh
//!   re-materialization from `(seed, cid)` via the lane factory/basis
//!   pool;
//! * the semi-sync round loop skips offline clients at dispatch, faults
//!   departed arrivals, and fast-forwards an all-offline round to the
//!   population's earliest `next_on` instead of spinning;
//! * `--legacy-shards` is rejected (a fixed pool cannot re-materialize a
//!   discarded lane) and `--sched sync` is rejected (the lockstep loop is
//!   the frozen bit-identity reference).
//!
//! With the knobs at their defaults nothing above executes — the model is
//! unarmed and RNG-free, the async/semisync loops take their pre-plane-10
//! paths verbatim, and `rust/tests/churn.rs` locks the bit-identity in.

pub mod asyncbuf;
pub mod avail;
pub mod event;
pub mod semisync;
pub mod sync;

pub use asyncbuf::AsyncBufferedScheduler;
pub use avail::{AvailConfig, AvailModel};
pub use event::EventQueue;
pub use semisync::SemiSyncScheduler;
pub use sync::SyncScheduler;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Context;

use crate::compress::Decompressor as _;
use crate::coordinator::{engine, Simulation};
use crate::metrics::{RoundRecord, RunReport};
use crate::net::{wire, Transport as _};
use crate::telemetry::Phase;
use crate::util::rng::Pcg64;
use crate::Result;

/// Which round control flow drives the simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum SchedKind {
    /// Lockstep FedAvg: every round waits for all survivors (the legacy
    /// engine, bit-identical).
    #[default]
    Sync,
    /// Deadline-bounded rounds; stragglers roll into the next round.
    SemiSync,
    /// FedBuff-style buffered asynchrony.
    Async {
        /// Arrivals folded between consecutive model applies.
        k: usize,
        /// Staleness-discount exponent `p` in `1/(1+τ)^p`.
        staleness_p: f64,
    },
}

impl SchedKind {
    /// Stable short name for logs/CSV paths.
    pub fn name(&self) -> &'static str {
        match self {
            SchedKind::Sync => "sync",
            SchedKind::SemiSync => "semisync",
            SchedKind::Async { .. } => "async",
        }
    }

    /// Parse a CLI spec: `sync`, `semisync`, `async`,
    /// `async:k=8,staleness=1.0`.
    pub fn parse(spec: &str) -> std::result::Result<SchedKind, String> {
        let (name, kv) = spec.split_once(':').unwrap_or((spec, ""));
        let mut opts = std::collections::BTreeMap::new();
        for pair in kv.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("bad scheduler option '{pair}' (expect key=value)"))?;
            opts.insert(k.to_string(), v.to_string());
        }
        let reject_opts = |what: &str| -> std::result::Result<(), String> {
            if opts.is_empty() {
                Ok(())
            } else {
                Err(format!("scheduler '{what}' takes no options"))
            }
        };
        match name {
            "sync" => {
                reject_opts("sync")?;
                Ok(SchedKind::Sync)
            }
            "semisync" => {
                reject_opts("semisync")?;
                Ok(SchedKind::SemiSync)
            }
            "async" => {
                let mut k = DEFAULT_ASYNC_K;
                let mut staleness_p = DEFAULT_STALENESS_P;
                for (key, v) in &opts {
                    match key.as_str() {
                        "k" => k = v.parse().map_err(|e| format!("async k: {e}"))?,
                        "staleness" => {
                            staleness_p = v.parse().map_err(|e| format!("async staleness: {e}"))?
                        }
                        other => return Err(format!("unknown async option '{other}'")),
                    }
                }
                Ok(SchedKind::Async { k, staleness_p })
            }
            other => Err(format!("unknown scheduler '{other}' (sync | semisync | async[:k=..,staleness=..])")),
        }
    }
}

// (The async scheduler additionally honors `ExperimentConfig::
// participation` as a concurrency bound — see `asyncbuf`'s module docs.)

/// Default apply buffer size for `async` when `k=` is not given.
pub const DEFAULT_ASYNC_K: usize = 8;
/// Default staleness exponent for `async` when `staleness=` is not given.
pub const DEFAULT_STALENESS_P: f64 = 0.5;

/// Experiment-facing scheduler knobs (`ExperimentConfig::sched`, the
/// `"sched"` JSON object, and the `--sched`/`--compute-*`/availability
/// CLI flags).
///
/// The default — sync control flow, zero compute time, always-on clients,
/// concurrency 1, adaptive features off — keeps the simulation byte- and
/// bit-identical to the pre-scheduler engine.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedConfig {
    /// Round control flow.
    pub kind: SchedKind,
    /// Mean per-dispatch local-compute latency, seconds. `0` = compute is
    /// free (completion times are pure link times, the pre-sched model).
    pub compute_base_s: f64,
    /// Compute heterogeneity: each dispatch's compute time is scaled by
    /// `exp(spread · N(0,1))` (log-normal). `0` = every dispatch costs
    /// exactly `compute_base_s`.
    pub compute_spread: f64,
    /// Per-client availability/churn processes (plane 10). Unarmed by
    /// default; requires an event-driven scheduler when armed.
    pub avail: AvailConfig,
    /// Per-client concurrent dispatches (async only). `1` (default) =
    /// a lane is re-dispatched only after its previous upload is decoded;
    /// `>1` = a client trains while earlier uploads are still in flight,
    /// with arrivals delivered in dispatch order per client (FIFO link)
    /// so the lane's compress → decode alternation is preserved.
    pub concurrency: usize,
    /// Async only: adapt the apply threshold `k` to the observed
    /// arrival-rate estimate (shrink under churn, grow when arrivals
    /// outpace the initial cadence).
    pub adaptive_k: bool,
    /// Async only: FedAsync-style server learning-rate scaling — each
    /// apply is additionally scaled by `1/(1 + τ̄)^lr_tau`, with `τ̄` the
    /// mean observed staleness of the buffer. `0` (default) disables the
    /// scaling (no float op runs).
    pub lr_tau: f64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            kind: SchedKind::default(),
            compute_base_s: 0.0,
            compute_spread: 0.0,
            avail: AvailConfig::default(),
            concurrency: 1,
            adaptive_k: false,
            lr_tau: 0.0,
        }
    }
}

impl SchedConfig {
    /// Range-check the knobs; returns a description of the first problem.
    /// Called by `Simulation::build` so bad CLI/JSON values surface as
    /// config errors, not panics.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if let SchedKind::Async { k, staleness_p } = self.kind {
            if k == 0 {
                return Err("sched async k must be >= 1".into());
            }
            if !(staleness_p.is_finite() && staleness_p >= 0.0) {
                return Err(format!(
                    "sched async staleness = {staleness_p} must be finite and non-negative"
                ));
            }
        }
        for (name, v) in [
            ("compute_base_s", self.compute_base_s),
            ("compute_spread", self.compute_spread),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("sched.{name} = {v} must be finite and non-negative"));
            }
        }
        self.avail.validate()?;
        let is_async = matches!(self.kind, SchedKind::Async { .. });
        if self.concurrency == 0 {
            return Err("sched concurrency must be >= 1".into());
        }
        if self.concurrency > 1 && !is_async {
            return Err(format!(
                "sched concurrency = {} requires --sched async (sync/semisync lanes are \
                 busy until their upload lands)",
                self.concurrency
            ));
        }
        if self.adaptive_k && !is_async {
            return Err("adaptive-k requires --sched async (there is no apply threshold to \
                        adapt under sync/semisync)"
                .into());
        }
        if !(self.lr_tau.is_finite() && self.lr_tau >= 0.0) {
            return Err(format!("sched lr_tau = {} must be finite and non-negative", self.lr_tau));
        }
        if self.lr_tau > 0.0 && !is_async {
            return Err("lr_tau (staleness-adaptive server LR) requires --sched async".into());
        }
        if self.avail.armed() && matches!(self.kind, SchedKind::Sync) {
            return Err("availability/churn requires --sched semisync or async: the sync \
                        lockstep loop is the frozen bit-identity reference and has no \
                        notion of an offline client"
                .into());
        }
        Ok(())
    }

    /// Parse a full CLI spec into scheduler knobs: everything
    /// [`SchedKind::parse`] accepts plus the plane-10 async fields —
    /// `async:k=8,staleness=0.5,adaptive=1,lr_tau=0.5,conc=2`. Compute
    /// and availability knobs keep their defaults (they ride separate
    /// flags).
    pub fn parse_spec(spec: &str) -> std::result::Result<SchedConfig, String> {
        let (name, kv) = spec.split_once(':').unwrap_or((spec, ""));
        let mut cfg = SchedConfig::default();
        if name != "async" {
            cfg.kind = SchedKind::parse(spec)?;
            return Ok(cfg);
        }
        let mut k = DEFAULT_ASYNC_K;
        let mut staleness_p = DEFAULT_STALENESS_P;
        for pair in kv.split(',').filter(|s| !s.is_empty()) {
            let (key, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("bad scheduler option '{pair}' (expect key=value)"))?;
            match key {
                "k" => k = v.parse().map_err(|e| format!("async k: {e}"))?,
                "staleness" => {
                    staleness_p = v.parse().map_err(|e| format!("async staleness: {e}"))?
                }
                "adaptive" => {
                    cfg.adaptive_k = match v {
                        "1" | "true" => true,
                        "0" | "false" => false,
                        other => return Err(format!("async adaptive: '{other}' is not 0/1")),
                    }
                }
                "lr_tau" => cfg.lr_tau = v.parse().map_err(|e| format!("async lr_tau: {e}"))?,
                "conc" => cfg.concurrency = v.parse().map_err(|e| format!("async conc: {e}"))?,
                other => return Err(format!("unknown async option '{other}'")),
            }
        }
        cfg.kind = SchedKind::Async { k, staleness_p };
        Ok(cfg)
    }
}

/// Per-dispatch local-compute latency draws.
///
/// `draw(dispatch, cid)` is a pure function of `(seed, dispatch, cid)` —
/// no shared RNG stream to advance — mirroring
/// [`DropoutModel`](crate::net::DropoutModel): completion times are
/// identical at every worker count and independent of evaluation order,
/// which is what keeps the event order replayable.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    base_s: f64,
    spread: f64,
    seed: u64,
}

impl ComputeModel {
    /// Build from the sched knobs and the run seed (dedicated stream: the
    /// draw never perturbs data/model/sampler RNG).
    pub fn new(cfg: &SchedConfig, seed: u64) -> Self {
        ComputeModel {
            base_s: cfg.compute_base_s,
            spread: cfg.compute_spread,
            seed: seed ^ 0x5EED_C003_7001,
        }
    }

    /// Compute seconds for client `cid`'s `dispatch`-th local run.
    pub fn draw(&self, dispatch: u64, cid: usize) -> f64 {
        if self.base_s == 0.0 {
            return 0.0;
        }
        if self.spread == 0.0 {
            return self.base_s;
        }
        let mix = self.seed ^ dispatch.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut r = Pcg64::new(mix, 0xC03D_0000 ^ cid as u64);
        self.base_s * (self.spread * r.normal()).exp()
    }
}

/// One round control flow, driving a [`Simulation`] end to end on the
/// virtual clock. Implementations own no simulation state — everything
/// observable (model, ledger, recorder, lane state, `vclock`) lives in the
/// `Simulation`, so a finished run reads back identically no matter which
/// scheduler produced it.
pub trait Scheduler {
    /// Stable name (matches [`SchedKind::name`]).
    fn name(&self) -> &'static str;

    /// Run every configured round/apply, invoking `progress` after each
    /// pushed [`RoundRecord`], and produce the end-of-run report.
    fn run(
        &mut self,
        sim: &mut Simulation,
        progress: &mut dyn FnMut(usize, &RoundRecord),
    ) -> Result<RunReport>;
}

/// Build the scheduler for a config.
pub fn build_scheduler(cfg: &SchedConfig) -> Box<dyn Scheduler> {
    match cfg.kind {
        SchedKind::Sync => Box::new(SyncScheduler),
        SchedKind::SemiSync => Box::new(SemiSyncScheduler::new(cfg.clone())),
        SchedKind::Async { k, staleness_p } => {
            Box::new(AsyncBufferedScheduler::new(k, staleness_p, cfg.clone()))
        }
    }
}

/// One dispatched upload: everything an event-driven scheduler needs to
/// schedule, charge, decode, and fold it when it lands.
pub(crate) struct DispatchedUpload {
    /// Client id.
    pub cid: usize,
    /// Wire-encoded compressed update (its length is the uplink charge).
    pub frame: Vec<u8>,
    /// Undiscounted FedAvg weight (shard size).
    pub weight: f64,
    /// Mean minibatch loss over the dispatch's local training.
    pub mean_loss: f64,
    /// rSVD candidate count consumed (Σd proxy).
    pub sum_d: u64,
    /// Virtual time the upload finishes crossing the wire:
    /// `dispatch + compute draw + link round trip` on the client's link.
    pub arrival_s: f64,
}

/// The dispatch stage shared by the event-driven schedulers: ship the
/// encoded broadcast `frame` to `cids` through the transport (downlink
/// charged from the delivered frames), fan the client phase across
/// `workers` threads, upload the results, and stamp each drained frame
/// with its arrival time, consuming one `dispatches[cid]` compute draw
/// per upload. `round` tags telemetry spans (the open round for semisync,
/// the model version for async); with telemetry enabled, each upload's
/// compute draw and link transit become `client_compress`/
/// `uplink_transit` spans on the virtual-clock track.
///
/// The sync path deliberately keeps its own copy of this staging inside
/// [`Simulation::step`] — that loop is the frozen bit-identity reference
/// the equivalence tests compare against; this helper exists so the
/// semi-sync and async control flows share one implementation instead of
/// drifting copies.
pub(crate) fn dispatch_uploads(
    sim: &mut Simulation,
    frame: &Arc<[u8]>,
    cids: &[usize],
    now: f64,
    workers: usize,
    compute: &ComputeModel,
    dispatches: &mut [u64],
    round: u64,
) -> Result<Vec<DispatchedUpload>> {
    if cids.is_empty() {
        return Ok(Vec::new());
    }
    let tel = sim.telemetry.clone();
    if let Some(t) = tel.as_deref() {
        t.count("dispatches", cids.len() as u64);
    }
    let broadcast_bytes = frame.len() as u64;
    for &cid in cids {
        sim.transport.broadcast(cid, frame)?;
    }
    let delivered = sim.transport.drain_broadcasts();
    for (_, f) in &delivered {
        sim.ledger.charge_downlink(f.len() as u64);
    }
    // Every client received an identical frame: decode one copy and share
    // it read-only across lanes (bit-exact f32 ↔ LE round trip).
    let global_rx = match delivered.first() {
        Some((_, f)) => {
            wire::decode_params(&sim.meta, f).context("decoding the model broadcast")?
        }
        None => sim.global.clone(),
    };
    let inputs = engine::RoundInputs {
        global: &global_rx,
        local_epochs: sim.cfg.local_epochs,
        batch_size: sim.cfg.batch_size,
        lr: sim.cfg.lr,
    };
    // Materialize first-touch lanes (parallel, deterministic cid order),
    // then loan the lanes out to the engine. Every dispatched lane is
    // *pinned* until its upload is decoded: its paired compressor state
    // advanced at dispatch, so an eviction + re-materialization (which
    // resets the decompressor) would misdecode the in-flight frame. The
    // arrival paths unpin.
    sim.lanes.ensure_resident(cids, workers, tel.as_deref(), round);
    let mut taken = sim.lanes.take(cids);
    let outcomes = {
        let lane_refs: Vec<(usize, &mut crate::coordinator::Client)> =
            taken.iter_mut().map(|(cid, b)| (*cid, &mut **b)).collect();
        let plan = sim.trainer.plan(workers);
        engine::run_client_phase(plan, inputs, lane_refs, tel.as_deref(), round)
    };
    sim.lanes.restore(taken);
    let outcomes = outcomes?;
    for &cid in cids {
        sim.lanes.pin(cid);
    }

    // Keyed by cid (not a population-sized table): dispatch batches are
    // O(concurrency) while the population can be 10⁶.
    let mut outcome_of: HashMap<usize, (f64, u64, f64)> = HashMap::with_capacity(cids.len());
    for outcome in outcomes {
        outcome_of.insert(outcome.cid, (outcome.mean_loss, outcome.stats.sum_d, outcome.weight));
        sim.transport.upload(outcome.cid, outcome.frame)?;
    }
    Ok(sim
        .transport
        .drain_uploads()
        .into_iter()
        .map(|(cid, frame)| {
            let attempt = dispatches[cid];
            dispatches[cid] += 1;
            let compute_s = compute.draw(attempt, cid);
            let transit_s =
                sim.network.link(cid).round_trip_time(broadcast_bytes, frame.len() as u64);
            let arrival_s = now + compute_s + transit_s;
            if let Some(t) = tel.as_deref() {
                t.virt_span(Phase::ClientCompress, round, Some(cid as u32), now, now + compute_s);
                t.virt_span(
                    Phase::UplinkTransit,
                    round,
                    Some(cid as u32),
                    now + compute_s,
                    arrival_s,
                );
            }
            let (mean_loss, sum_d, weight) = outcome_of[&cid];
            DispatchedUpload { cid, frame, weight, mean_loss, sum_d, arrival_s }
        })
        .collect())
}

/// Charge and decode an upload the run is shutting down on: its bytes
/// crossed the wire (charged outside any recorded round) and the lane's
/// paired compressor/decompressor state must still end in lockstep, so
/// the decode is unconditional even though nothing aggregates the result.
pub(crate) fn absorb_trailing_upload(
    sim: &mut Simulation,
    cid: usize,
    frame: &[u8],
) -> Result<()> {
    sim.ledger.charge_uplink(frame.len() as u64);
    let payloads = wire::decode(frame)
        .with_context(|| format!("decoding client {cid}'s trailing upload"))?;
    let _ = sim.lanes.lane_mut(cid).decompressor.decode(payloads);
    sim.lanes.unpin(cid);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_every_kind() {
        assert_eq!(SchedKind::parse("sync").unwrap(), SchedKind::Sync);
        assert_eq!(SchedKind::parse("semisync").unwrap(), SchedKind::SemiSync);
        assert_eq!(
            SchedKind::parse("async").unwrap(),
            SchedKind::Async { k: DEFAULT_ASYNC_K, staleness_p: DEFAULT_STALENESS_P }
        );
        assert_eq!(
            SchedKind::parse("async:k=4,staleness=1.0").unwrap(),
            SchedKind::Async { k: 4, staleness_p: 1.0 }
        );
        assert_eq!(
            SchedKind::parse("async:staleness=0").unwrap(),
            SchedKind::Async { k: DEFAULT_ASYNC_K, staleness_p: 0.0 }
        );
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(SchedKind::parse("lockstep").is_err());
        assert!(SchedKind::parse("sync:k=2").is_err());
        assert!(SchedKind::parse("async:q=2").is_err());
        assert!(SchedKind::parse("async:k").is_err());
        assert!(SchedKind::parse("async:k=zero").is_err());
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(SchedConfig::default().validate().is_ok());
        let bad_k = SchedConfig {
            kind: SchedKind::Async { k: 0, staleness_p: 0.5 },
            ..Default::default()
        };
        assert!(bad_k.validate().is_err());
        let bad_p = SchedConfig {
            kind: SchedKind::Async { k: 4, staleness_p: f64::NAN },
            ..Default::default()
        };
        assert!(bad_p.validate().is_err());
        let bad_compute =
            SchedConfig { compute_base_s: -1.0, ..Default::default() };
        assert!(bad_compute.validate().is_err());
    }

    fn async_kind() -> SchedKind {
        SchedKind::Async { k: 4, staleness_p: 0.5 }
    }

    #[test]
    fn validate_rejects_incoherent_plane10_knobs() {
        // --concurrency 0 is meaningless everywhere.
        let zero = SchedConfig { concurrency: 0, ..Default::default() };
        assert!(zero.validate().is_err());
        // Concurrency > 1 only makes sense for the async scheduler.
        let conc_sync = SchedConfig { concurrency: 2, ..Default::default() };
        assert!(conc_sync.validate().is_err());
        let conc_semi =
            SchedConfig { kind: SchedKind::SemiSync, concurrency: 2, ..Default::default() };
        assert!(conc_semi.validate().is_err());
        let conc_async = SchedConfig { kind: async_kind(), concurrency: 2, ..Default::default() };
        assert!(conc_async.validate().is_ok());
        // Adaptive-k under sync/semisync has no apply threshold to adapt.
        let ak_sync = SchedConfig { adaptive_k: true, ..Default::default() };
        assert!(ak_sync.validate().is_err());
        let ak_async = SchedConfig { kind: async_kind(), adaptive_k: true, ..Default::default() };
        assert!(ak_async.validate().is_ok());
        // Staleness-adaptive server LR is async-only too.
        let lr_semi =
            SchedConfig { kind: SchedKind::SemiSync, lr_tau: 0.5, ..Default::default() };
        assert!(lr_semi.validate().is_err());
        let lr_nan = SchedConfig { kind: async_kind(), lr_tau: f64::NAN, ..Default::default() };
        assert!(lr_nan.validate().is_err());
        // Availability/churn is rejected under the frozen sync loop…
        let avail_sync = SchedConfig {
            avail: AvailConfig { duty: 0.5, ..Default::default() },
            ..Default::default()
        };
        assert!(avail_sync.validate().is_err());
        // …and accepted by the event-driven schedulers.
        let avail_semi = SchedConfig {
            kind: SchedKind::SemiSync,
            avail: AvailConfig { duty: 0.5, churn_per_s: 0.01, ..Default::default() },
            ..Default::default()
        };
        assert!(avail_semi.validate().is_ok());
        // Bad availability ranges surface through SchedConfig::validate.
        let bad_duty = SchedConfig {
            kind: async_kind(),
            avail: AvailConfig { duty: 2.0, ..Default::default() },
            ..Default::default()
        };
        assert!(bad_duty.validate().is_err());
    }

    #[test]
    fn parse_spec_covers_plane10_fields() {
        // Plain kinds fall through to SchedKind::parse.
        assert_eq!(SchedConfig::parse_spec("sync").unwrap(), SchedConfig::default());
        assert_eq!(
            SchedConfig::parse_spec("semisync").unwrap().kind,
            SchedKind::SemiSync
        );
        let full = SchedConfig::parse_spec("async:k=4,staleness=1.0,adaptive=1,lr_tau=0.5,conc=2")
            .unwrap();
        assert_eq!(full.kind, SchedKind::Async { k: 4, staleness_p: 1.0 });
        assert!(full.adaptive_k);
        assert_eq!(full.lr_tau, 0.5);
        assert_eq!(full.concurrency, 2);
        // Defaults when the new keys are absent.
        let plain = SchedConfig::parse_spec("async:k=3").unwrap();
        assert!(!plain.adaptive_k);
        assert_eq!(plain.lr_tau, 0.0);
        assert_eq!(plain.concurrency, 1);
        // adaptive accepts 0/1/true/false, nothing else.
        assert!(SchedConfig::parse_spec("async:adaptive=0").is_ok());
        assert!(SchedConfig::parse_spec("async:adaptive=false").is_ok());
        assert!(SchedConfig::parse_spec("async:adaptive=yes").is_err());
        // Unknown keys and non-async kinds with options still reject.
        assert!(SchedConfig::parse_spec("async:q=2").is_err());
        assert!(SchedConfig::parse_spec("sync:conc=2").is_err());
        assert!(SchedConfig::parse_spec("semisync:adaptive=1").is_err());
    }

    #[test]
    fn compute_model_zero_base_is_free_and_rng_free() {
        let m = ComputeModel::new(&SchedConfig::default(), 7);
        for d in 0..5 {
            for c in 0..5 {
                assert_eq!(m.draw(d, c), 0.0);
            }
        }
    }

    #[test]
    fn compute_model_pure_and_spread() {
        let cfg = SchedConfig {
            compute_base_s: 2.0,
            compute_spread: 0.5,
            ..Default::default()
        };
        let m = ComputeModel::new(&cfg, 11);
        // Pure: same query twice → same answer.
        assert_eq!(m.draw(3, 2).to_bits(), m.draw(3, 2).to_bits());
        // Varies across dispatches and clients.
        assert_ne!(m.draw(0, 0).to_bits(), m.draw(1, 0).to_bits());
        assert_ne!(m.draw(0, 0).to_bits(), m.draw(0, 1).to_bits());
        // Always positive (log-normal).
        assert!((0..20).all(|d| (0..8).all(|c| m.draw(d, c) > 0.0)));
        // Zero spread degenerates to the base.
        let flat = ComputeModel::new(
            &SchedConfig { compute_base_s: 2.0, ..Default::default() },
            11,
        );
        assert_eq!(flat.draw(9, 9), 2.0);
    }
}
