//! Deterministic per-client availability processes: diurnal on/off square
//! waves and Poisson departure churn.
//!
//! Production federated populations blink: devices follow day/night usage
//! cycles and drop off mid-round (Edin et al.'s practical-limitations
//! study; Ozfatura et al.'s time-correlated sparsification is exactly the
//! family of schemes most exposed to interrupted participation). This
//! module gives every scheduler one shared answer to "is client `cid`
//! reachable at virtual time `t`?" without perturbing anything else:
//!
//! * **Diurnal wave** — client `cid` is on for the first `duty · period_s`
//!   seconds of every `period_s`-second cycle, phase-shifted by a
//!   per-client uniform offset so the population ramps smoothly instead of
//!   toggling in lockstep.
//! * **Poisson churn** — time is cut into `period_s`-wide windows; in each
//!   window a client departs with probability `1 − exp(−churn · period)`,
//!   at a uniform offset, for a uniform outage of up to
//!   `min(outage_s, period_s)` seconds. Outages can spill into the next
//!   window (membership checks the current and previous window), so the
//!   query stays O(1).
//!
//! # Purity contract
//!
//! [`AvailModel::is_on`] is a **pure function of `(seed, cid, vtime)`** on
//! two dedicated seed streams (xor salts [`AVAIL_SALT`], [`CHURN_SALT`]):
//! no shared RNG advances, so the answer is identical at any worker count
//! and independent of query order — the same contract as
//! [`ComputeModel`](super::ComputeModel) and
//! [`DropoutModel`](crate::net::DropoutModel). With the default knobs
//! (`duty = 1.0`, `churn = 0.0`) the model is *unarmed*: every query
//! short-circuits to `true` without constructing an RNG, so defaults
//! perturb nothing — the bit-identity anchor `rust/tests/churn.rs` locks
//! in.

use crate::util::rng::Pcg64;

/// Seed salt for the diurnal phase stream.
pub const AVAIL_SALT: u64 = 0xAA11_AB1E_0000_0001;
/// Seed salt for the churn (departure) stream.
pub const CHURN_SALT: u64 = 0xC4E2_1D00_0000_0002;

/// Availability/churn knobs (part of
/// [`SchedConfig`](super::SchedConfig); CLI `--avail`, `--avail-period`,
/// `--churn`, `--outage`). Defaults are inert.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AvailConfig {
    /// Diurnal duty cycle in `(0, 1]`: the fraction of each period a
    /// client is on. `1.0` (default) disables the wave.
    pub duty: f64,
    /// Diurnal period, virtual seconds. Also the churn window width.
    pub period_s: f64,
    /// Poisson departure rate per client per virtual second. `0` (default)
    /// disables churn.
    pub churn_per_s: f64,
    /// Maximum outage duration for one churn departure, seconds
    /// (effective cap: `min(outage_s, period_s)`).
    pub outage_s: f64,
}

impl Default for AvailConfig {
    fn default() -> Self {
        AvailConfig { duty: 1.0, period_s: 20.0, churn_per_s: 0.0, outage_s: 5.0 }
    }
}

impl AvailConfig {
    /// True when the knobs actually perturb availability (non-default
    /// duty or churn). Unarmed ⇒ every `is_on` is `true`, RNG-free.
    pub fn armed(&self) -> bool {
        self.duty < 1.0 || self.churn_per_s > 0.0
    }

    /// Range-check the knobs; returns a description of the first problem.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.duty.is_finite() && self.duty > 0.0 && self.duty <= 1.0) {
            return Err(format!("avail duty = {} must be in (0, 1]", self.duty));
        }
        if !(self.period_s.is_finite() && self.period_s > 0.0) {
            return Err(format!("avail period_s = {} must be finite and positive", self.period_s));
        }
        if !(self.churn_per_s.is_finite() && self.churn_per_s >= 0.0) {
            return Err(format!(
                "avail churn = {} must be finite and non-negative",
                self.churn_per_s
            ));
        }
        if !(self.outage_s.is_finite() && self.outage_s > 0.0) {
            return Err(format!("avail outage_s = {} must be finite and positive", self.outage_s));
        }
        Ok(())
    }
}

/// The availability oracle: pure `(seed, cid, vtime)` queries (see the
/// module docs for the seed-stream contract).
#[derive(Clone, Copy, Debug)]
pub struct AvailModel {
    cfg: AvailConfig,
    seed: u64,
}

impl AvailModel {
    /// Build from the knobs and the run seed (dedicated streams — never
    /// perturbs data/model/sampler RNG).
    pub fn new(cfg: AvailConfig, seed: u64) -> Self {
        AvailModel { cfg, seed }
    }

    /// True when the knobs perturb anything (see [`AvailConfig::armed`]).
    pub fn armed(&self) -> bool {
        self.cfg.armed()
    }

    /// Per-client diurnal phase offset in `[0, period_s)`.
    fn phase(&self, cid: usize) -> f64 {
        let mut r = Pcg64::new(self.seed ^ AVAIL_SALT, 0x00D1_0000 ^ cid as u64);
        r.f64() * self.cfg.period_s
    }

    /// Is the diurnal square wave high for `cid` at `t`?
    fn diurnal_on(&self, cid: usize, t: f64) -> bool {
        if self.cfg.duty >= 1.0 {
            return true;
        }
        let p = self.cfg.period_s;
        ((t + self.phase(cid)) % p) < self.cfg.duty * p
    }

    /// The churn outage drawn for `(cid, window)`, if any, as
    /// `(start_s, end_s)`. One candidate departure per window; pure.
    fn outage(&self, cid: usize, window: u64) -> Option<(f64, f64)> {
        if self.cfg.churn_per_s <= 0.0 {
            return None;
        }
        let w = self.cfg.period_s;
        let mix = self.seed ^ CHURN_SALT ^ window.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut r = Pcg64::new(mix, 0x00C4_0000 ^ cid as u64);
        let p_dep = 1.0 - (-self.cfg.churn_per_s * w).exp();
        if r.f64() >= p_dep {
            return None;
        }
        let start = window as f64 * w + r.f64() * w;
        let dur = r.f64() * self.cfg.outage_s.min(w);
        Some((start, start + dur))
    }

    /// End of the outage covering `t`, if `cid` is departed at `t`.
    fn outage_end(&self, cid: usize, t: f64) -> Option<f64> {
        let w = (t / self.cfg.period_s).max(0.0) as u64;
        for window in [w.checked_sub(1), Some(w)].into_iter().flatten() {
            if let Some((start, end)) = self.outage(cid, window) {
                if t >= start && t < end {
                    return Some(end);
                }
            }
        }
        None
    }

    /// Is client `cid` reachable at virtual time `t`? Pure; `true`
    /// without touching an RNG when unarmed.
    pub fn is_on(&self, cid: usize, t: f64) -> bool {
        if !self.armed() {
            return true;
        }
        self.diurnal_on(cid, t) && self.outage_end(cid, t).is_none()
    }

    /// A virtual time strictly after `t` at which `cid` is (very likely)
    /// back on — the wake-up target for schedulers stalled on an all-
    /// offline pool. Conservative: callers re-check [`Self::is_on`] at the
    /// returned instant and may need another hop, but every hop strictly
    /// advances the clock, so stalls always terminate.
    pub fn next_on(&self, cid: usize, t: f64) -> f64 {
        let p = self.cfg.period_s;
        let mut cand = t;
        for _ in 0..32 {
            if !self.diurnal_on(cid, cand) {
                // Jump to the start of the next on-window.
                let ph = (cand + self.phase(cid)) % p;
                cand += p - ph;
                continue;
            }
            match self.outage_end(cid, cand) {
                Some(end) => cand = end,
                None => break,
            }
        }
        if cand > t {
            cand
        } else {
            t + p
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_unarmed_and_always_on() {
        let m = AvailModel::new(AvailConfig::default(), 42);
        assert!(!m.armed());
        for cid in 0..16 {
            for i in 0..50 {
                assert!(m.is_on(cid, i as f64 * 1.7));
            }
        }
    }

    #[test]
    fn queries_are_pure() {
        let cfg = AvailConfig { duty: 0.5, churn_per_s: 0.05, ..Default::default() };
        let a = AvailModel::new(cfg, 7);
        let b = AvailModel::new(cfg, 7);
        for cid in 0..8 {
            for i in 0..200 {
                let t = i as f64 * 0.37;
                assert_eq!(a.is_on(cid, t), b.is_on(cid, t));
            }
        }
    }

    #[test]
    fn duty_cycle_matches_on_fraction() {
        let cfg = AvailConfig { duty: 0.5, period_s: 10.0, ..Default::default() };
        let m = AvailModel::new(cfg, 3);
        let mut on = 0usize;
        let mut total = 0usize;
        for cid in 0..32 {
            for i in 0..1000 {
                total += 1;
                if m.is_on(cid, i as f64 * 0.01 * 10.0) {
                    on += 1;
                }
            }
        }
        let frac = on as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.05, "on fraction {frac} far from duty 0.5");
    }

    #[test]
    fn phases_differ_across_clients() {
        let cfg = AvailConfig { duty: 0.5, ..Default::default() };
        let m = AvailModel::new(cfg, 9);
        // At a fixed instant, a phase-shifted population is split — not
        // all-on or all-off in lockstep.
        let on = (0..64).filter(|&cid| m.is_on(cid, 3.0)).count();
        assert!(on > 0 && on < 64, "no phase diversity: {on}/64 on");
    }

    #[test]
    fn churn_produces_outages_and_next_on_recovers() {
        let cfg = AvailConfig { churn_per_s: 0.2, period_s: 10.0, outage_s: 5.0, ..Default::default() };
        let m = AvailModel::new(cfg, 5);
        assert!(m.armed());
        let mut saw_off = false;
        for cid in 0..16 {
            for i in 0..400 {
                let t = i as f64 * 0.25;
                if !m.is_on(cid, t) {
                    saw_off = true;
                    let back = m.next_on(cid, t);
                    assert!(back > t, "next_on must strictly advance");
                }
            }
        }
        assert!(saw_off, "churn 0.2/s produced no outage in 100 s × 16 clients");
    }

    #[test]
    fn next_on_strictly_advances_even_when_on() {
        let cfg = AvailConfig { duty: 0.5, ..Default::default() };
        let m = AvailModel::new(cfg, 11);
        for cid in 0..8 {
            let t = 1.0;
            assert!(m.next_on(cid, t) > t);
        }
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(AvailConfig::default().validate().is_ok());
        assert!(AvailConfig { duty: 0.0, ..Default::default() }.validate().is_err());
        assert!(AvailConfig { duty: 1.5, ..Default::default() }.validate().is_err());
        assert!(AvailConfig { duty: f64::NAN, ..Default::default() }.validate().is_err());
        assert!(AvailConfig { period_s: 0.0, ..Default::default() }.validate().is_err());
        assert!(AvailConfig { churn_per_s: -0.1, ..Default::default() }.validate().is_err());
        assert!(AvailConfig { outage_s: 0.0, ..Default::default() }.validate().is_err());
    }
}
