//! The synchronous scheduler: the legacy lockstep loop, verbatim.

use super::Scheduler;
use crate::coordinator::Simulation;
use crate::metrics::{RoundRecord, RunReport};
use crate::Result;

/// Lockstep FedAvg. Each round drives [`Simulation::step`] — the exact
/// engine the repository ran before the scheduler plane existed — so
/// `--sched sync` produces bit-identical [`RoundRecord`]s and
/// byte-identical communication-ledger totals to the legacy engine *by
/// construction*: there is no second code path to drift. The virtual
/// clock advances by each round's `sim_time_s` (the slowest surviving
/// participant's link round trip, deadline-capped), exactly as the legacy
/// `NetworkModel::round_time` accounting did.
///
/// `rust/tests/sched.rs` still locks the equivalence in from outside the
/// crate (scheduled run vs `Simulation::run_with_progress`, GradESTC and
/// TopK, with dropout/heterogeneity/deadline enabled), guarding the
/// plumbing between the config, the scheduler registry, and the engine.
pub struct SyncScheduler;

impl Scheduler for SyncScheduler {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn run(
        &mut self,
        sim: &mut Simulation,
        progress: &mut dyn FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        for round in 0..sim.cfg.rounds {
            let rec = sim.step(round)?;
            progress(round, &rec);
        }
        Ok(sim.finish_report())
    }
}
