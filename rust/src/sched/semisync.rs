//! The semi-synchronous scheduler: deadline-bounded rounds with straggler
//! rollover.
//!
//! Each round broadcasts to the sampled survivors that are *free* (not
//! still uploading a previous round's update), fans the client phase
//! across workers exactly like the sync engine, and schedules every
//! upload's arrival on the virtual clock at
//! `dispatch + compute_draw + link round-trip`. The round closes at the
//! straggler deadline (`net.deadline_s`) or at the last participant's
//! arrival, whichever is earlier; **every queued arrival with
//! `time <= round close` is folded into this round's aggregate** — this
//! round's on-time participants *and* stragglers rolled over from earlier
//! rounds. A straggler's update is therefore never discarded (the sync
//! engine's behaviour), it is aggregated by the round that is open when it
//! lands, and its uplink bytes are charged exactly once — in that round,
//! the round they finished crossing the wire. `rust/tests/sched.rs` locks
//! the single-charge ledger invariant in with a byte-counting transport.
//!
//! A straggling client is *busy* until its upload lands: it is skipped by
//! participation until then (it cannot hear a broadcast mid-upload), and a
//! round in which every sampled client is busy fast-forwards the clock to
//! the earliest pending arrival instead of spinning — so rollover can
//! never deadlock the round loop.
//!
//! With no deadline configured (`deadline_s = 0`) the round closes at the
//! last arrival and semi-sync degenerates to sync-with-compute-times
//! (folds happen in arrival order rather than participant order, so float
//! sums may differ in the last bits from the sync engine's
//! participant-order folds).
//!
//! # Availability & churn (plane 10)
//!
//! With the [`AvailModel`] armed, a sampled client that is offline at the
//! round's dispatch instant is skipped (it cannot hear the broadcast), and
//! an arrival whose client departed while its upload was in flight
//! **faults**: zero bytes charged, nothing decoded or folded, the lane
//! discarded (`faults` counter, [`Phase::Fault`] span) so the returning
//! client re-materializes in fingerprint lockstep. A round in which every
//! sampled client is offline and nothing is in flight fast-forwards the
//! clock to the population's earliest
//! [`next_on`](AvailModel::next_on) instead of spinning zero-duration
//! rounds — so mid-round departure can never deadlock the rollover loop,
//! even when the earliest pending arrival belongs to a departed client
//! (that arrival faults, and the clock still advanced to it). Unarmed
//! (the default), none of these branches execute and the loop is the
//! pre-plane-10 control flow verbatim.

use std::sync::Arc;

use anyhow::Context;

use super::{AvailModel, ComputeModel, DispatchedUpload, EventQueue, SchedConfig, Scheduler};
use crate::compress::{Decompressor as _, LayerUpdate};
use crate::coordinator::{ServerAggregator, Simulation, Trainer as _};
use crate::metrics::{RoundRecord, RunReport};
use crate::net::wire;
use crate::telemetry::{ApplyEvent, ArrivalEvent, DispatchEvent, Phase, Telemetry};
use crate::Result;

/// Deadline-bounded rounds; stragglers roll into the round open at their
/// arrival. See the module docs.
pub struct SemiSyncScheduler {
    conf: SchedConfig,
}

impl SemiSyncScheduler {
    /// Build from the scheduler knobs (compute model).
    pub fn new(conf: SchedConfig) -> Self {
        SemiSyncScheduler { conf }
    }
}

impl Scheduler for SemiSyncScheduler {
    fn name(&self) -> &'static str {
        "semisync"
    }

    fn run(
        &mut self,
        sim: &mut Simulation,
        progress: &mut dyn FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        let workers = sim.cfg.resolved_workers();
        let deadline = sim.cfg.net.deadline();
        let compute = ComputeModel::new(&self.conf, sim.cfg.seed);
        let avail = AvailModel::new(self.conf.avail, sim.cfg.seed);
        let armed = avail.armed();
        let n = sim.lanes.len();
        let tel = sim.telemetry.clone();
        let mut queue: EventQueue<DispatchedUpload> = EventQueue::new();
        // Virtual time each client's in-flight upload lands; a client is
        // dispatchable only once free.
        let mut busy_until = vec![0.0f64; n];
        // Per-client dispatch counter feeding the compute-time draw.
        let mut dispatches = vec![0u64; n];
        // Round each client's in-flight upload was dispatched in, so a
        // popped arrival knows whether it rolled over (staleness in
        // rounds).
        let mut dispatch_round = vec![0usize; n];

        for round in 0..sim.cfg.rounds {
            let t_start = sim.vclock;
            let sampled = sim.sampler.sample(round);
            let alive = sim.dropout.filter(round, &sampled);
            let dropped = (sampled.len() - alive.len()) as u64;
            // Free (not mid-upload) and — when availability is armed —
            // actually reachable at the dispatch instant. The `!armed`
            // short-circuit keeps the default path RNG-free and verbatim.
            let participants: Vec<usize> = alive
                .into_iter()
                .filter(|&cid| {
                    busy_until[cid] <= t_start && (!armed || avail.is_on(cid, t_start))
                })
                .collect();
            if let Some(t) = tel.as_deref() {
                t.count("dropouts", dropped);
            }
            if let Some(obs) = sim.observer.as_mut() {
                obs.on_dispatch(&DispatchEvent {
                    round,
                    cids: &participants,
                    vtime: t_start,
                    model_version: round as u64,
                });
            }

            let mut loss_sum = 0.0f64;
            let mut sum_d = 0u64;
            let mut arrivals_this_round: Vec<f64> = Vec::new();
            if !participants.is_empty() {
                // Stages 1–3 (shared with the async scheduler): broadcast
                // (memoized per model version in the shared cache — rounds
                // between applies re-ship one frame), fanned client phase,
                // upload; each drained frame arrives at dispatch + compute
                // draw + link round trip.
                let broadcast: Arc<[u8]> =
                    sim.broadcast_frame(sim.model_version, round as u64);
                let uploads = super::dispatch_uploads(
                    sim, &broadcast, &participants, t_start, workers, &compute,
                    &mut dispatches, round as u64,
                )?;
                for up in uploads {
                    loss_sum += up.mean_loss;
                    sum_d += up.sum_d;
                    busy_until[up.cid] = up.arrival_s;
                    dispatch_round[up.cid] = round;
                    arrivals_this_round.push(up.arrival_s);
                    queue.push(up.arrival_s, up);
                }
            }

            // Round close: the last participant's arrival, capped at the
            // straggler deadline. A round with nothing dispatched (every
            // sampled client busy or dropped) fast-forwards to the
            // earliest pending arrival so rollover cannot deadlock.
            let latest = arrivals_this_round.iter().fold(t_start, |a, &b| a.max(b));
            let t_end = if participants.is_empty() {
                match queue.peek_time() {
                    Some(t) => t.max(t_start),
                    // Every sampled client is offline and nothing is in
                    // flight: fast-forward to the population's earliest
                    // return (strictly after t_start, so the loop always
                    // advances) instead of burning zero-duration rounds.
                    None if armed => (0..n)
                        .map(|cid| avail.next_on(cid, t_start))
                        .fold(f64::INFINITY, f64::min)
                        .max(t_start),
                    None => t_start,
                }
            } else {
                match deadline {
                    Some(d) => latest.min(t_start + d),
                    None => latest,
                }
            };

            // Stages 4+5: everything that arrived by the close — on-time
            // participants and rolled-over stragglers alike — is charged
            // (once: the pop consumes the pending upload), decoded with
            // its lane's paired decompressor, and folded in arrival order.
            let mut folds: Vec<(f64, Vec<LayerUpdate>)> = Vec::new();
            let mut folded_cids: Vec<usize> = Vec::new();
            while queue.peek_time().is_some_and(|t| t <= t_end) {
                let (arrival_t, _, up) = queue.pop().expect("peeked event");
                if armed && !avail.is_on(up.cid, arrival_t) {
                    // The client departed while this upload was in flight:
                    // fault — zero bytes charged, nothing decoded, the
                    // lane discarded so the paired compressor state (which
                    // advanced at dispatch with no decode to match) is
                    // rebuilt from (seed, cid) when the client returns.
                    sim.lanes.discard(up.cid);
                    if let Some(t) = tel.as_deref() {
                        t.count("faults", 1);
                        t.virt_span(
                            Phase::Fault,
                            round as u64,
                            Some(up.cid as u32),
                            arrival_t,
                            arrival_t,
                        );
                    }
                    continue;
                }
                sim.ledger.charge_uplink(up.frame.len() as u64);
                let sp = Telemetry::timer(tel.as_deref());
                let payloads = wire::decode(&up.frame)
                    .with_context(|| format!("decoding client {}'s upload", up.cid))?;
                if let Some(t) = tel.as_deref() {
                    t.count_payloads(&payloads);
                }
                // The dispatched lane was pinned in flight; decoding this
                // arrival is what releases it for eviction.
                let updates = sim.lanes.lane_mut(up.cid).decompressor.decode(payloads);
                sim.lanes.unpin(up.cid);
                if let Some(sp) = sp {
                    sp.end(Phase::ServerDecode, round as u64, Some(up.cid as u32));
                }
                // Staleness: rounds between dispatch and fold (0 for
                // on-time arrivals, ≥1 for rolled-over stragglers).
                let tau = (round - dispatch_round[up.cid]) as u64;
                if let Some(t) = tel.as_deref() {
                    t.observe_staleness(tau);
                    if tau > 0 {
                        t.count("stragglers", 1);
                    }
                }
                if let Some(obs) = sim.observer.as_mut() {
                    obs.on_arrival(&ArrivalEvent {
                        round,
                        cid: up.cid,
                        updates: &updates,
                        meta: &sim.meta,
                        weight: up.weight,
                        staleness: tau,
                        vtime: arrival_t,
                        on_time: tau == 0,
                    });
                }
                folded_cids.push(up.cid);
                folds.push((up.weight, updates));
            }
            if let Some(t) = tel.as_deref() {
                t.gauge("queue.pending", queue.len() as f64);
            }
            let folded = folds.len();
            let wtotal: f64 = folds.iter().map(|(w, _)| *w).sum();
            if wtotal > 0.0 {
                let batch: Vec<(f32, Vec<LayerUpdate>)> = folds
                    .into_iter()
                    .map(|(w, updates)| ((w / wtotal) as f32, updates))
                    .collect();
                let sp = Telemetry::timer(tel.as_deref());
                let mut agg = ServerAggregator::with_backend(&sim.meta, sim.backend);
                agg.fold_batch(workers, batch);
                if let Some(sp) = sp {
                    sp.end(Phase::Fold, round as u64, None);
                }
                let sp = Telemetry::timer(tel.as_deref());
                sim.global.axpy(1.0, &agg.finish(&sim.meta));
                if let Some(sp) = sp {
                    sp.end(Phase::Apply, round as u64, None);
                }
                // The model changed: next round's broadcast re-encodes.
                sim.model_version += 1;
                if let Some(t) = tel.as_deref() {
                    t.count("folds", folded as u64);
                    t.count("applies", 1);
                }
                if let Some(obs) = sim.observer.as_mut() {
                    obs.on_apply(&ApplyEvent { round, vtime: t_end, folded, wtotal });
                }
            }

            // Stage 6: evaluate, record, advance the clock.
            let sp = Telemetry::timer(tel.as_deref());
            let (test_loss, test_acc) = if round % sim.cfg.eval_every == 0
                || round + 1 == sim.cfg.rounds
            {
                sim.trainer.evaluate(&sim.global, &sim.test_data)?
            } else {
                (f64::NAN, f64::NAN)
            };
            if let Some(sp) = sp {
                sp.end(Phase::Eval, round as u64, None);
            }
            let (up_b, down_b) = sim.ledger.end_round();
            sim.vclock = t_end;
            folded_cids.sort_unstable();
            let mut record = RoundRecord {
                round,
                // Mean loss over this round's *dispatched* participants
                // (they trained this round); `survivors` below instead
                // lists the clients whose updates this round aggregated,
                // which under rollover can differ.
                train_loss: loss_sum / participants.len().max(1) as f64,
                test_accuracy: test_acc,
                test_loss,
                uplink_bytes: up_b,
                downlink_bytes: down_b,
                sim_time_s: t_end - t_start,
                sim_clock_s: t_end,
                sum_d,
                survivors: folded_cids,
                ext: None,
            };
            sim.telemetry_round_end(&mut record);
            sim.recorder.push(record.clone());
            if let Some(obs) = sim.observer.as_mut() {
                obs.on_round(round, &record);
            }
            progress(round, &record);
        }

        // Uploads still in flight when the run ends: charged + decoded so
        // lane state stays in lockstep (shared shutdown-drain helper) —
        // unless the client departed mid-flight, in which case the frame
        // faults here too (zero bytes, no decode, lane discarded).
        while let Some((te, _, up)) = queue.pop() {
            if armed && !avail.is_on(up.cid, te) {
                sim.lanes.discard(up.cid);
                if let Some(t) = tel.as_deref() {
                    t.count("faults", 1);
                    t.virt_span(
                        Phase::Fault,
                        sim.cfg.rounds as u64,
                        Some(up.cid as u32),
                        te,
                        te,
                    );
                }
                continue;
            }
            super::absorb_trailing_upload(sim, up.cid, &up.frame)?;
        }
        Ok(sim.finish_report())
    }
}
