//! The asynchronous buffered scheduler (FedBuff-style).
//!
//! Clients run continuously: a dispatched client trains on the model
//! version it was handed, uploads, and frees its concurrency slot when
//! its arrival is processed. The server folds each arriving update into
//! the [`ServerAggregator`](crate::coordinator::ServerAggregator) **as it
//! lands** — the streaming compressed-domain fold, `O(model)` memory —
//! and applies the buffered aggregate after every `k` arrivals, then bumps
//! the model version.
//!
//! # Participation sampling
//!
//! `ExperimentConfig::participation` bounds how many clients are in
//! flight at once: the concurrency target is
//! `clamp(round(participation · n), 1, n)`. At `participation = 1.0`
//! (the default) every client is always training, uploading, or about to
//! be re-dispatched — the original FedBuff regime, preserved bit-exactly
//! (no sampling RNG is consumed). Below `1.0`, each freed slot is refilled
//! by drawing uniformly from the *idle* clients on a dedicated seed
//! stream, so a 10⁴–10⁶-client population is meaningful with only a few
//! hundred concurrent trainers: the population defines the sampling pool
//! (and the data distribution), not the steady-state compute. Draws
//! happen in event order on the single-threaded event loop, so records
//! stay bit-identical at any worker count.
//!
//! # Availability, churn, and faults (plane 10)
//!
//! With [`AvailModel`](super::AvailModel) armed (`--avail < 1` or
//! `--churn > 0`) the sampler is always on and its draws are
//! **availability-weighted**: an offline client is never dispatched (it
//! stays in the idle pool until a draw finds it on). When every idle
//! client is offline the unfilled slots park and a `Wake` event is
//! scheduled at the earliest `next_on` across the idle pool, so the loop
//! never spins and never deadlocks. A dispatch whose client departs
//! mid-flight becomes a typed [`Event::Fault`] at its arrival instant:
//! the slot is released, **zero bytes are charged**, nothing is decoded,
//! the fault is counted (`faults` counter, [`Phase::Fault`] virtual
//! span), and the lane is *discarded* — its client-side compressor
//! advanced at dispatch with no decode to match, so the only way a
//! returning client stays in fingerprint lockstep is a fresh
//! re-materialization from `(seed, cid)` through the lane factory and
//! basis pool.
//!
//! # Per-client concurrency
//!
//! `--concurrency c > 1` keeps up to `c` dispatches of the same client in
//! flight (train while the previous upload is still uploading). Arrivals
//! are version-stamped twice: with the model version they trained on (for
//! the staleness τ) and with the lane's *epoch* (bumped on every fault
//! discard) so a frame encoded by a discarded compressor can never be
//! decoded by its re-materialized successor — it faults instead. A
//! client's uploads traverse its own uplink as a FIFO pipe: each arrival
//! time is clamped to be no earlier than the client's previously
//! scheduled arrival, so same-lane frames decode in dispatch order and
//! the compress → decode alternation (the temporal-correlation contract)
//! is preserved.
//!
//! # Staleness discount and the adaptive server
//!
//! An update dispatched at model version `v` and folded at version `V`
//! is `τ = V − v` versions stale; its FedAvg weight (the client's shard
//! size) is discounted to
//!
//! ```text
//! w = shard_size / (1 + τ)^p
//! ```
//!
//! with `p` the `staleness` knob (`0` disables the discount; the paper's
//! temporal-correlation machinery — basis reuse across a lane's adjacent
//! uploads — is untouched either way, because each lane still alternates
//! compress → decode in its own order). The apply normalizes by the sum of
//! discounted weights, so an all-fresh buffer reproduces plain FedAvg
//! weighting. Two further FedAsync-style knobs, both inert by default:
//!
//! * `lr_tau > 0` additionally scales each apply by `1/(1 + τ̄)^lr_tau`,
//!   with `τ̄` the buffer's mean observed staleness — a stale buffer
//!   steps the server model more cautiously.
//! * `adaptive_k` re-targets the apply threshold after every apply from
//!   an arrival-rate estimate (EWMA of arrivals per virtual second) so
//!   the apply *cadence* stays near the first apply's: when churn thins
//!   the arrival stream `k` shrinks (clamped to `[1, 4k₀]`), when
//!   arrivals outpace it `k` grows.
//!
//! # Virtual time and records
//!
//! Each apply closes one [`RoundRecord`]: `round` is the apply index,
//! `survivors` the (sorted, possibly repeating) client ids folded into
//! that apply, `sim_time_s` the virtual time since the previous apply and
//! `sim_clock_s` the clock at the apply. Under heterogeneous links the
//! clock advances at the pace of the `k` fastest arrivals instead of the
//! slowest participant — the time-to-accuracy win `gradestc exp async1`
//! measures.
//!
//! # Event-loop micro-batching
//!
//! Events scheduled at the *same* virtual instant (co-temporal arrivals
//! are the norm under a homogeneous network, where every client's round
//! trip is identical) are processed as one group: folds, applies, and
//! sampler draws still happen strictly in event order, but the freed
//! slots are coalesced into **one** batched re-dispatch at the group's
//! end — fanning the client phase across workers — instead of one
//! sequential single-lane dispatch per event. Two deliberate consequences
//! of batching: every re-dispatch in a group trains on the *post-group*
//! model version (the pre-batching loop handed versions out mid-group as
//! applies landed), and a final apply mid-group leaves the instant's
//! remaining events to the shutdown drain without re-dispatching freed
//! slots (the pre-batching loop burned one more training pass per slot
//! whose arrival nothing would ever fold). A fault detected on an
//! arrival is re-queued as a typed [`Event::Fault`] at the same instant,
//! so it is handled inside the same group, in event order.
//!
//! # Determinism
//!
//! Arrival, retry, fault, and wake events live on the `(time, seq)`-keyed
//! [`EventQueue`]; event *handling* fans work across threads (the initial
//! cohort dispatch and the batched group re-dispatches use the same
//! parallel client phase as the sync engine) but event *order* never
//! depends on the worker count, dropout/compute/availability draws are
//! pure per `(seed, attempt|vtime, cid)`, participation draws happen in
//! event order on a dedicated stream, and folds happen in arrival order —
//! so `workers = 1` and `workers = N` produce bit-identical records,
//! apply sequences, and lane fingerprints (asserted in
//! `rust/tests/sched.rs` and `rust/tests/churn.rs`, including
//! co-temporal-arrival and churn-armed cases). With availability,
//! concurrency, and the adaptive knobs at their defaults the legacy
//! per-event draw sequence runs verbatim, so pre-plane-10 runs reproduce
//! bit-identically.

use std::sync::Arc;

use anyhow::{bail, Context};

use super::{AvailModel, ComputeModel, DispatchedUpload, EventQueue, SchedConfig, Scheduler};
use crate::compress::Decompressor as _;
use crate::coordinator::{ServerAggregator, Simulation, Trainer as _};
use crate::metrics::{RoundRecord, RunReport};
use crate::net::wire;
use crate::telemetry::{ApplyEvent, ArrivalEvent, DispatchEvent, Phase, Telemetry};
use crate::util::rng::Pcg64;
use crate::Result;

/// A scheduled occurrence on the virtual clock.
enum Event {
    /// A client's upload finishes crossing the wire.
    Arrival {
        /// The dispatched upload (frame, weight, loss, Σd, arrival time).
        up: DispatchedUpload,
        /// Model version the client trained on (for the staleness τ).
        version: u64,
        /// The lane's epoch at dispatch (bumped on every fault discard);
        /// a stale epoch means the encoding compressor no longer exists.
        epoch: u64,
    },
    /// A dropped-out dispatch attempt wakes up and tries again.
    Retry { cid: usize },
    /// An arrival whose client departed mid-flight (or whose lane was
    /// discarded): release the slot, charge nothing, discard the lane.
    Fault { cid: usize, epoch: u64 },
    /// Re-try filling parked slots: the earliest offline idle client is
    /// due back. Carries no state — the group-end refill re-checks
    /// availability.
    Wake,
}

/// FedBuff-style buffered asynchrony; see the module docs.
pub struct AsyncBufferedScheduler {
    k: usize,
    p: f64,
    conf: SchedConfig,
}

/// Mutable plane-10 loop state: the availability oracle plus the
/// epoch/FIFO/slot bookkeeping the fault and concurrency paths share.
struct ChurnState {
    avail: AvailModel,
    /// Per-client concurrent dispatch cap (`SchedConfig::concurrency`).
    conc: usize,
    /// Bumped whenever a fault discards the lane; arrivals stamped with
    /// an older epoch fault instead of decoding.
    lane_epoch: Vec<u64>,
    /// Latest scheduled arrival per client (FIFO uplink clamp under
    /// `conc > 1`).
    last_arrival: Vec<f64>,
    /// Freed slots waiting for an online client.
    pending: usize,
    /// A `Wake` event is already queued.
    wake_pending: bool,
    /// Consecutive faults since the last successful fold (livelock
    /// guard: a config where no upload can ever land must error out, not
    /// spin the event loop forever).
    faults_since_fold: u64,
}

/// Idle-client pool for participation-sampled dispatch
/// (`participation < 1.0`) and for the availability/concurrency modes:
/// uniform draws from the idle set on a dedicated seed stream, consumed
/// in event order on the single-threaded event loop — so the dispatch
/// sequence is bit-identical at any worker count and never perturbs the
/// data/model/link RNG streams.
struct SlotSampler {
    /// Clients currently drawable (remaining capacity > 0). Order is
    /// arbitrary (swap_remove churn) but deterministic: mutated only from
    /// the single-threaded event loop, so draws replay bit-identically at
    /// any worker count.
    idle: Vec<usize>,
    /// `pos[cid]` = cid's index in `idle`, or `IN_FLIGHT`. Keeps release
    /// and draw O(1) per slot at 10⁴–10⁶-client populations — the event
    /// loop processes one of each per arrival.
    pos: Vec<usize>,
    /// Remaining dispatch capacity per client (`conc` minus in-flight).
    cap: Vec<u32>,
    /// Per-client capacity bound.
    conc: u32,
    /// Total in-flight dispatches (`Σ (conc − cap)`), tracked
    /// incrementally for the occupancy gauge.
    busy: usize,
    rng: Pcg64,
}

const IN_FLIGHT: usize = usize::MAX;

impl SlotSampler {
    fn new(n: usize, seed: u64, conc: u32) -> Self {
        SlotSampler {
            idle: (0..n).collect(),
            pos: (0..n).collect(),
            cap: vec![conc; n],
            conc,
            busy: 0,
            rng: Pcg64::new(seed, 0xA51C_0DE5),
        }
    }

    /// Return one of a client's slots to the pool (its arrival, fault, or
    /// retry was just processed).
    fn release(&mut self, cid: usize) {
        debug_assert!(self.cap[cid] < self.conc, "client {cid} released while already idle");
        self.cap[cid] += 1;
        self.busy -= 1;
        if self.pos[cid] == IN_FLIGHT {
            self.pos[cid] = self.idle.len();
            self.idle.push(cid);
        }
    }

    /// Drop `cid` from the idle list (its `pos` entry becomes
    /// `IN_FLIGHT`), keeping the swap_remove bookkeeping O(1).
    fn remove_idle(&mut self, cid: usize) {
        let i = self.pos[cid];
        debug_assert!(i != IN_FLIGHT, "client {cid} drawn while in flight");
        self.pos[cid] = IN_FLIGHT;
        self.idle.swap_remove(i);
        if let Some(&moved) = self.idle.get(i) {
            self.pos[moved] = i;
        }
    }

    /// Draw up to `k` distinct idle clients, uniformly, returned sorted.
    /// The legacy path (`conc == 1`, no availability): the RNG op
    /// sequence is exactly the pre-plane-10 one, preserving bit-identity
    /// of participation-sampled runs.
    fn draw(&mut self, k: usize) -> Vec<usize> {
        let k = k.min(self.idle.len());
        let mut picked: Vec<usize> = (0..k)
            .map(|_| {
                let i = self.rng.index(self.idle.len());
                let cid = self.idle.swap_remove(i);
                self.pos[cid] = IN_FLIGHT;
                if let Some(&moved) = self.idle.get(i) {
                    self.pos[moved] = i;
                }
                self.cap[cid] -= 1;
                self.busy += 1;
                cid
            })
            .collect();
        picked.sort_unstable();
        picked
    }

    /// Availability/concurrency-aware draw: up to `k` distinct clients
    /// drawn uniformly from the idle clients for which `online` holds.
    /// A picked client with remaining capacity (`conc > 1`) becomes
    /// drawable again for the *next* batch — same-batch picks stay
    /// distinct so the fanned dispatch loans each lane exactly once.
    fn draw_avail(&mut self, k: usize, online: impl Fn(usize) -> bool) -> Vec<usize> {
        let mut cands: Vec<usize> = self.idle.iter().copied().filter(|&c| online(c)).collect();
        let k = k.min(cands.len());
        let mut picked: Vec<usize> = Vec::with_capacity(k);
        for _ in 0..k {
            let i = self.rng.index(cands.len());
            let cid = cands.swap_remove(i);
            self.remove_idle(cid);
            self.cap[cid] -= 1;
            self.busy += 1;
            picked.push(cid);
        }
        for &cid in &picked {
            if self.cap[cid] > 0 {
                self.pos[cid] = self.idle.len();
                self.idle.push(cid);
            }
        }
        picked.sort_unstable();
        picked
    }

    /// Clients currently drawable (for the wake-time scan).
    fn idle_clients(&self) -> &[usize] {
        &self.idle
    }

    /// Total in-flight dispatches.
    fn busy(&self) -> usize {
        self.busy
    }
}

impl AsyncBufferedScheduler {
    /// `k` arrivals per apply, staleness exponent `p`.
    pub fn new(k: usize, p: f64, conf: SchedConfig) -> Self {
        assert!(k >= 1, "async k must be >= 1");
        AsyncBufferedScheduler { k, p, conf }
    }

    /// Dispatch `cids` at virtual time `now` on model `version`: dropout
    /// check per attempt, broadcast (charged), fanned local training,
    /// upload, and one arrival event per surviving client. Dropped
    /// attempts wake as [`Event::Retry`] after the latency the attempt
    /// would have cost. Arrivals are stamped with the lane's current
    /// epoch; under `conc > 1` a client's arrival times are clamped to
    /// dispatch order (FIFO uplink).
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        sim: &mut Simulation,
        compute: &ComputeModel,
        queue: &mut EventQueue<Event>,
        dispatches: &mut [u64],
        st: &mut ChurnState,
        version: u64,
        cids: &[usize],
        now: f64,
        workers: usize,
    ) -> Result<()> {
        let tel = sim.telemetry.clone();
        let mut alive: Vec<usize> = Vec::with_capacity(cids.len());
        for &cid in cids {
            let attempt = dispatches[cid];
            if sim.dropout.survives(attempt as usize, cid) {
                alive.push(cid);
            } else {
                // No broadcast received, no upload sent, no bytes charged;
                // the client reappears after its message latencies (plus
                // compute, mirroring a crash-and-restart of the attempt).
                let wake =
                    now + compute.draw(attempt, cid) + sim.network.link(cid).round_trip_time(0, 0);
                dispatches[cid] += 1;
                if let Some(t) = tel.as_deref() {
                    t.count("dropouts", 1);
                }
                queue.push(wake, Event::Retry { cid });
            }
        }
        if alive.is_empty() {
            return Ok(());
        }
        if let Some(obs) = sim.observer.as_mut() {
            obs.on_dispatch(&DispatchEvent {
                round: version as usize,
                cids: &alive,
                vtime: now,
                model_version: version,
            });
        }

        // One encoded broadcast per model version — now the shared
        // simulation-level cache ([`crate::net::BroadcastCache`]), which
        // every scheduler consults; only the cache miss pays (and traces)
        // the encode.
        let frame: Arc<[u8]> = sim.broadcast_frame(version, version);
        // Stages 1–3 (shared with the semi-sync scheduler): broadcast,
        // fanned client phase, upload, arrival stamping. The initial
        // cohort dispatch is the parallel case; steady-state re-dispatches
        // are single lanes.
        for mut up in super::dispatch_uploads(
            sim, &frame, &alive, now, workers, compute, dispatches, version,
        )? {
            let cid = up.cid;
            if st.conc > 1 {
                // FIFO per-client uplink: a client's frames land in
                // dispatch order, preserving the lane's compress → decode
                // alternation under concurrent dispatches. With conc == 1
                // the clamp can never bind (the previous arrival was
                // processed before this re-dispatch), so it is skipped
                // and the legacy arrival times are byte-identical.
                up.arrival_s = up.arrival_s.max(st.last_arrival[cid]);
                st.last_arrival[cid] = up.arrival_s;
            }
            let epoch = st.lane_epoch[cid];
            queue.push(up.arrival_s, Event::Arrival { up, version, epoch });
        }
        Ok(())
    }

    /// Fill as many parked slots as the idle pool's *online* clients
    /// allow (plane-10 mode only), then — if slots remain and every idle
    /// client is offline — schedule a single `Wake` at the pool's
    /// earliest `next_on`, so a starved loop sleeps instead of spinning.
    #[allow(clippy::too_many_arguments)]
    fn refill(
        &self,
        sim: &mut Simulation,
        compute: &ComputeModel,
        queue: &mut EventQueue<Event>,
        dispatches: &mut [u64],
        sampler: &mut SlotSampler,
        st: &mut ChurnState,
        now: f64,
        workers: usize,
    ) -> Result<()> {
        let armed = st.avail.armed();
        let avail = st.avail;
        while st.pending > 0 {
            let want = st.pending;
            let batch = if armed {
                sampler.draw_avail(want, |cid| avail.is_on(cid, now))
            } else {
                sampler.draw_avail(want, |_| true)
            };
            if batch.is_empty() {
                break;
            }
            st.pending -= batch.len();
            let v = sim.model_version;
            self.dispatch(sim, compute, queue, dispatches, st, v, &batch, now, workers)?;
        }
        if st.pending > 0 && armed && !st.wake_pending {
            let mut wake: Option<f64> = None;
            for &cid in sampler.idle_clients() {
                if !avail.is_on(cid, now) {
                    let w = avail.next_on(cid, now);
                    wake = Some(wake.map_or(w, |b: f64| b.min(w)));
                }
            }
            if let Some(w) = wake {
                queue.push(w, Event::Wake);
                st.wake_pending = true;
            }
        }
        if armed {
            if let Some(t) = sim.telemetry.as_deref() {
                t.gauge("slots.pending", st.pending as f64);
            }
        }
        Ok(())
    }
}

impl Scheduler for AsyncBufferedScheduler {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run(
        &mut self,
        sim: &mut Simulation,
        progress: &mut dyn FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        let workers = sim.cfg.resolved_workers();
        let compute = ComputeModel::new(&self.conf, sim.cfg.seed);
        let n = sim.lanes.len();
        let tel = sim.telemetry.clone();
        let mut queue: EventQueue<Event> = EventQueue::new();
        let mut dispatches = vec![0u64; n];

        let conc = self.conf.concurrency.max(1);
        let avail = AvailModel::new(self.conf.avail, sim.cfg.seed);
        let armed = avail.armed();
        // Plane-10 mode: availability or per-client concurrency armed.
        // Off (the default), the loop below is the pre-plane-10 control
        // flow verbatim — same draws, same RNG streams, bit-identical.
        let plane10 = armed || conc > 1;
        let mut st = ChurnState {
            avail,
            conc,
            lane_epoch: vec![0u64; n],
            last_arrival: vec![0.0f64; n],
            pending: 0,
            wake_pending: false,
            faults_since_fold: 0,
        };
        // A run where every upload faults forever would spin the event
        // loop without ever applying; bail out with a config hint instead.
        let fault_guard = 100_000u64 + 1_000 * self.k as u64;

        // Concurrency target: `participation` bounds how many clients are
        // in flight at once. At 1.0 (default) the sampler is disabled and
        // the original all-clients-always-running FedBuff regime runs
        // bit-exactly (no sampling RNG is consumed). Plane-10 mode always
        // uses the sampler (availability filtering and per-client slot
        // capacity need its bookkeeping).
        let target = ((n as f64 * sim.cfg.participation).round() as usize).clamp(1, n);
        let slots_total = target * conc;
        let mut sampler =
            (target < n || plane10).then(|| SlotSampler::new(n, sim.cfg.seed, conc as u32));

        // Kick-off: the initial cohort starts on the initial model at
        // once — everyone without sampling, a uniform draw of `target`
        // clients with it, an availability-filtered fill in plane-10 mode.
        let t0 = sim.vclock;
        let v0 = sim.model_version;
        if plane10 {
            st.pending = slots_total;
            let s = sampler.as_mut().expect("plane-10 mode always samples");
            self.refill(sim, &compute, &mut queue, &mut dispatches, s, &mut st, t0, workers)?;
        } else {
            let initial: Vec<usize> = match sampler.as_mut() {
                None => (0..n).collect(),
                Some(s) => s.draw(target),
            };
            self.dispatch(
                sim, &compute, &mut queue, &mut dispatches, &mut st, v0, &initial, t0, workers,
            )?;
        }

        let mut applies = 0usize;
        let mut agg = ServerAggregator::with_backend(&sim.meta, sim.backend);
        let mut wsum = 0.0f64;
        let mut buffered = 0usize;
        let mut folded_cids: Vec<usize> = Vec::new();
        let mut loss_sum = 0.0f64;
        let mut sum_d = 0u64;
        let mut tau_sum = 0u64;
        let mut t_last_apply = t0;
        // Adaptive-k state: the apply threshold actually in force, the
        // EWMA arrival-rate estimate, and the cadence target (the first
        // apply's duration).
        let mut k_cur = self.k;
        let mut rate_hat: Option<f64> = None;
        let mut cadence: Option<f64> = None;

        while applies < sim.cfg.rounds {
            let Some((t, _seq, first)) = queue.pop() else {
                bail!(
                    "async scheduler event queue drained after {applies} of {} applies",
                    sim.cfg.rounds
                );
            };
            sim.vclock = t;
            // Micro-batched event group: handle this event and every other
            // event scheduled at exactly `t`, strictly in event order, but
            // defer the freed slots into `redispatch` so the group ends in
            // one parallel dispatch instead of per-event single-lane
            // dispatches (see the module docs). Nothing dispatched here
            // can land at time `t` again (latencies are positive), so the
            // deferral never reorders the group (a same-instant `Fault`
            // requeue is the deliberate exception: it stays inside this
            // group).
            let mut redispatch: Vec<usize> = Vec::new();
            let mut ev = Some(first);
            while let Some(e) = ev.take() {
                match e {
                    Event::Retry { cid } => {
                        // The dropped attempt's slot frees; without
                        // sampling the same client retries, with sampling
                        // the slot is refilled by a fresh uniform draw
                        // over the idle pool (which includes the dropped
                        // client).
                        match sampler.as_mut() {
                            None => redispatch.push(cid),
                            Some(s) => {
                                s.release(cid);
                                if plane10 {
                                    st.pending += 1;
                                } else {
                                    redispatch.extend(s.draw(1));
                                }
                            }
                        }
                    }
                    Event::Wake => {
                        // The earliest offline idle client is due back;
                        // the group-end refill below re-draws.
                        st.wake_pending = false;
                    }
                    Event::Fault { cid, epoch } => {
                        // Mid-flight departure (or a frame from a lane
                        // that a previous fault already discarded): zero
                        // bytes charged, nothing decoded. Discard the
                        // lane — its compressor advanced at dispatch with
                        // no decode to match — so a returning client
                        // re-materializes the `(seed, cid)` pair in
                        // lockstep via the factory and basis pool.
                        if epoch == st.lane_epoch[cid] {
                            sim.lanes.discard(cid);
                            st.lane_epoch[cid] += 1;
                        }
                        st.faults_since_fold += 1;
                        if st.faults_since_fold > fault_guard {
                            bail!(
                                "availability/churn starved the async scheduler: \
                                 {} consecutive faults without a fold — raise --avail, \
                                 widen --avail-period, or lower --churn",
                                st.faults_since_fold
                            );
                        }
                        if let Some(tl) = tel.as_deref() {
                            tl.count("faults", 1);
                            tl.virt_span(
                                Phase::Fault,
                                sim.model_version,
                                Some(cid as u32),
                                t,
                                t,
                            );
                            if let Some(s) = sampler.as_ref() {
                                tl.gauge("slots.in_flight", s.busy() as f64);
                            }
                        }
                        match sampler.as_mut() {
                            None => redispatch.push(cid),
                            Some(s) => {
                                s.release(cid);
                                if plane10 {
                                    st.pending += 1;
                                } else {
                                    redispatch.extend(s.draw(1));
                                }
                            }
                        }
                    }
                    Event::Arrival { up, version: v, epoch } => {
                        let cid = up.cid;
                        if armed && (epoch != st.lane_epoch[cid] || !st.avail.is_on(cid, t)) {
                            // The client departed while this upload was in
                            // flight (or its lane was already discarded):
                            // requeue as a typed fault at this instant —
                            // it is handled inside this same co-temporal
                            // group, in event order.
                            queue.push(t, Event::Fault { cid, epoch });
                        } else {
                            // The fold-as-it-lands path: charge, decode
                            // with the lane's paired decompressor
                            // (lockstep), fold with the staleness-
                            // discounted weight.
                            sim.ledger.charge_uplink(up.frame.len() as u64);
                            let sp = Telemetry::timer(tel.as_deref());
                            let payloads = wire::decode(&up.frame)
                                .with_context(|| format!("decoding client {cid}'s upload"))?;
                            if let Some(tl) = tel.as_deref() {
                                tl.count_payloads(&payloads);
                            }
                            // The dispatched lane was pinned in flight;
                            // decoding its arrival releases it for
                            // eviction.
                            let updates = sim.lanes.lane_mut(cid).decompressor.decode(payloads);
                            sim.lanes.unpin(cid);
                            if let Some(sp) = sp {
                                sp.end(Phase::ServerDecode, v, Some(cid as u32));
                            }
                            let tau = sim.model_version - v;
                            let w = up.weight / (1.0 + tau as f64).powf(self.p);
                            if let Some(tl) = tel.as_deref() {
                                tl.observe_staleness(tau);
                                if tau > 0 {
                                    tl.count("stragglers", 1);
                                }
                                tl.count("folds", 1);
                            }
                            // The observer sees exactly the arrivals that
                            // fold (the shutdown drain below stays
                            // silent), so an arrival count equals the fold
                            // count.
                            if let Some(obs) = sim.observer.as_mut() {
                                obs.on_arrival(&ArrivalEvent {
                                    round: applies,
                                    cid,
                                    updates: &updates,
                                    meta: &sim.meta,
                                    weight: w,
                                    staleness: tau,
                                    vtime: t,
                                    on_time: tau == 0,
                                });
                            }
                            let sp = Telemetry::timer(tel.as_deref());
                            agg.fold(w as f32, updates);
                            if let Some(sp) = sp {
                                sp.end(Phase::Fold, applies as u64, Some(cid as u32));
                            }
                            wsum += w;
                            buffered += 1;
                            folded_cids.push(cid);
                            loss_sum += up.mean_loss;
                            sum_d += up.sum_d;
                            tau_sum += tau;
                            st.faults_since_fold = 0;

                            if buffered >= k_cur {
                                // Apply: normalize the buffered aggregate
                                // by the discounted weight sum, bump the
                                // version.
                                let full = std::mem::replace(
                                    &mut agg,
                                    ServerAggregator::with_backend(&sim.meta, sim.backend),
                                );
                                let sp = Telemetry::timer(tel.as_deref());
                                if wsum > 0.0 {
                                    let scale = if self.conf.lr_tau > 0.0 {
                                        // FedAsync-style server LR: a stale
                                        // buffer steps the model more
                                        // cautiously.
                                        let tau_bar = tau_sum as f64 / buffered as f64;
                                        (1.0 / wsum) * (1.0 + tau_bar).powf(-self.conf.lr_tau)
                                    } else {
                                        1.0 / wsum
                                    };
                                    sim.global.axpy(scale as f32, &full.finish(&sim.meta));
                                }
                                if let Some(sp) = sp {
                                    sp.end(Phase::Apply, applies as u64, None);
                                }
                                sim.model_version += 1;
                                if let Some(tl) = tel.as_deref() {
                                    tl.count("applies", 1);
                                    tl.gauge(
                                        "slots.in_flight",
                                        sampler.as_ref().map_or(n, |s| s.busy()) as f64,
                                    );
                                }
                                if let Some(obs) = sim.observer.as_mut() {
                                    obs.on_apply(&ApplyEvent {
                                        round: applies,
                                        vtime: t,
                                        folded: buffered,
                                        wtotal: wsum,
                                    });
                                }
                                let sp = Telemetry::timer(tel.as_deref());
                                let (test_loss, test_acc) = if applies % sim.cfg.eval_every == 0
                                    || applies + 1 == sim.cfg.rounds
                                {
                                    sim.trainer.evaluate(&sim.global, &sim.test_data)?
                                } else {
                                    (f64::NAN, f64::NAN)
                                };
                                if let Some(sp) = sp {
                                    sp.end(Phase::Eval, applies as u64, None);
                                }
                                let (up_b, down_b) = sim.ledger.end_round();
                                folded_cids.sort_unstable();
                                let mut record = RoundRecord {
                                    round: applies,
                                    train_loss: loss_sum / buffered as f64,
                                    test_accuracy: test_acc,
                                    test_loss,
                                    uplink_bytes: up_b,
                                    downlink_bytes: down_b,
                                    sim_time_s: t - t_last_apply,
                                    sim_clock_s: t,
                                    sum_d,
                                    survivors: std::mem::take(&mut folded_cids),
                                    ext: None,
                                };
                                sim.telemetry_round_end(&mut record);
                                sim.recorder.push(record.clone());
                                if let Some(obs) = sim.observer.as_mut() {
                                    obs.on_round(applies, &record);
                                }
                                progress(applies, &record);
                                if self.conf.adaptive_k {
                                    // Re-target the apply threshold so the
                                    // apply cadence tracks the first
                                    // apply's: k ← clamp(rate · cadence).
                                    let dt = (t - t_last_apply).max(1e-9);
                                    let rate = buffered as f64 / dt;
                                    let r = match rate_hat {
                                        None => rate,
                                        Some(r) => 0.5 * r + 0.5 * rate,
                                    };
                                    rate_hat = Some(r);
                                    let c = *cadence.get_or_insert(dt);
                                    let k_target = (r * c).round().max(1.0) as usize;
                                    k_cur = k_target.clamp(1, self.k.saturating_mul(4));
                                }
                                t_last_apply = t;
                                applies += 1;
                                wsum = 0.0;
                                buffered = 0;
                                loss_sum = 0.0;
                                sum_d = 0;
                                tau_sum = 0;
                            }

                            // Queue the freed slot for the group's batched
                            // re-dispatch on the newest model. Without
                            // sampling the same client goes back out; with
                            // it the slot goes to a fresh uniform draw
                            // over the idle pool (availability-filtered in
                            // plane-10 mode, at the group end).
                            match sampler.as_mut() {
                                None => redispatch.push(cid),
                                Some(s) => {
                                    s.release(cid);
                                    if plane10 {
                                        st.pending += 1;
                                    } else {
                                        redispatch.extend(s.draw(1));
                                    }
                                }
                            }
                        }
                    }
                }
                // A final apply mid-group ends the run: the instant's
                // remaining events go to the shutdown drain below, and no
                // slot is re-dispatched (a training pass whose arrival
                // nothing would fold).
                if applies >= sim.cfg.rounds {
                    redispatch.clear();
                    st.pending = 0;
                    break;
                }
                if queue.peek_time().is_some_and(|pt| pt.total_cmp(&t).is_eq()) {
                    ev = queue.pop().map(|(_, _, e)| e);
                }
            }
            if plane10 {
                if applies < sim.cfg.rounds && st.pending > 0 {
                    let s = sampler.as_mut().expect("plane-10 mode always samples");
                    self.refill(sim, &compute, &mut queue, &mut dispatches, s, &mut st, t, workers)?;
                }
            } else if !redispatch.is_empty() {
                let v = sim.model_version;
                self.dispatch(
                    sim, &compute, &mut queue, &mut dispatches, &mut st, v, &redispatch, t,
                    workers,
                )?;
            }
        }

        // In-flight uploads at shutdown: charged + decoded so lane state
        // stays in lockstep (shared shutdown-drain helper) — unless the
        // client departed mid-flight or its lane was discarded, in which
        // case the frame faults here too: zero bytes, no decode, lane
        // dropped.
        while let Some((te, _, ev)) = queue.pop() {
            if let Event::Arrival { up, epoch, .. } = ev {
                let cid = up.cid;
                if armed && (epoch != st.lane_epoch[cid] || !st.avail.is_on(cid, te)) {
                    if epoch == st.lane_epoch[cid] {
                        sim.lanes.discard(cid);
                        st.lane_epoch[cid] += 1;
                    }
                    if let Some(tl) = tel.as_deref() {
                        tl.count("faults", 1);
                        tl.virt_span(Phase::Fault, sim.model_version, Some(cid as u32), te, te);
                    }
                    continue;
                }
                super::absorb_trailing_upload(sim, cid, &up.frame)?;
            }
        }
        Ok(sim.finish_report())
    }
}
