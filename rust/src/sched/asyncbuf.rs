//! The asynchronous buffered scheduler (FedBuff-style).
//!
//! Clients run continuously: a dispatched client trains on the model
//! version it was handed, uploads, and frees its concurrency slot when
//! its arrival is processed. The server folds each arriving update into
//! the [`ServerAggregator`](crate::coordinator::ServerAggregator) **as it
//! lands** — the streaming compressed-domain fold, `O(model)` memory —
//! and applies the buffered aggregate after every `k` arrivals, then bumps
//! the model version.
//!
//! # Participation sampling
//!
//! `ExperimentConfig::participation` bounds how many clients are in
//! flight at once: the concurrency target is
//! `clamp(round(participation · n), 1, n)`. At `participation = 1.0`
//! (the default) every client is always training, uploading, or about to
//! be re-dispatched — the original FedBuff regime, preserved bit-exactly
//! (no sampling RNG is consumed). Below `1.0`, each freed slot is refilled
//! by drawing uniformly from the *idle* clients on a dedicated seed
//! stream, so a 10⁴–10⁶-client population is meaningful with only a few
//! hundred concurrent trainers: the population defines the sampling pool
//! (and the data distribution), not the steady-state compute. Draws
//! happen in event order on the single-threaded event loop, so records
//! stay bit-identical at any worker count.
//!
//! # Staleness discount
//!
//! An update dispatched at model version `v` and folded at version `V`
//! is `τ = V − v` versions stale; its FedAvg weight (the client's shard
//! size) is discounted to
//!
//! ```text
//! w = shard_size / (1 + τ)^p
//! ```
//!
//! with `p` the `staleness` knob (`0` disables the discount; the paper's
//! temporal-correlation machinery — basis reuse across a lane's adjacent
//! uploads — is untouched either way, because each lane still alternates
//! compress → decode in its own order). The apply normalizes by the sum of
//! discounted weights, so an all-fresh buffer reproduces plain FedAvg
//! weighting.
//!
//! # Virtual time and records
//!
//! Each apply closes one [`RoundRecord`]: `round` is the apply index,
//! `survivors` the (sorted, possibly repeating) client ids folded into
//! that apply, `sim_time_s` the virtual time since the previous apply and
//! `sim_clock_s` the clock at the apply. Under heterogeneous links the
//! clock advances at the pace of the `k` fastest arrivals instead of the
//! slowest participant — the time-to-accuracy win `gradestc exp async1`
//! measures.
//!
//! # Event-loop micro-batching
//!
//! Events scheduled at the *same* virtual instant (co-temporal arrivals
//! are the norm under a homogeneous network, where every client's round
//! trip is identical) are processed as one group: folds, applies, and
//! sampler draws still happen strictly in event order, but the freed
//! slots are coalesced into **one** batched re-dispatch at the group's
//! end — fanning the client phase across workers — instead of one
//! sequential single-lane dispatch per event. Two deliberate consequences
//! of batching: every re-dispatch in a group trains on the *post-group*
//! model version (the pre-batching loop handed versions out mid-group as
//! applies landed), and a final apply mid-group leaves the instant's
//! remaining events to the shutdown drain without re-dispatching freed
//! slots (the pre-batching loop burned one more training pass per slot
//! whose arrival nothing would ever fold).
//!
//! # Determinism
//!
//! Arrival and retry events live on the `(time, seq)`-keyed
//! [`EventQueue`]; event *handling* fans work across threads (the initial
//! cohort dispatch and the batched group re-dispatches use the same
//! parallel client phase as the sync engine) but event *order* never
//! depends on the worker count, dropout and compute draws are pure per
//! `(seed, attempt, cid)`, participation draws happen in event order on a
//! dedicated stream, and folds happen in arrival order — so `workers = 1`
//! and `workers = N` produce bit-identical records, apply sequences, and
//! lane fingerprints (asserted in `rust/tests/sched.rs`, including a
//! co-temporal-arrival case that exercises the batched dispatch).

use std::sync::Arc;

use anyhow::{bail, Context};

use super::{ComputeModel, DispatchedUpload, EventQueue, SchedConfig, Scheduler};
use crate::compress::Decompressor as _;
use crate::coordinator::{ServerAggregator, Simulation, Trainer as _};
use crate::metrics::{RoundRecord, RunReport};
use crate::net::wire;
use crate::telemetry::{ApplyEvent, ArrivalEvent, DispatchEvent, Phase, Telemetry};
use crate::util::rng::Pcg64;
use crate::Result;

/// A scheduled occurrence on the virtual clock.
enum Event {
    /// A client's upload finishes crossing the wire.
    Arrival {
        /// The dispatched upload (frame, weight, loss, Σd, arrival time).
        up: DispatchedUpload,
        /// Model version the client trained on (for the staleness τ).
        version: u64,
    },
    /// A dropped-out dispatch attempt wakes up and tries again.
    Retry { cid: usize },
}

/// FedBuff-style buffered asynchrony; see the module docs.
pub struct AsyncBufferedScheduler {
    k: usize,
    p: f64,
    conf: SchedConfig,
}

/// Idle-client pool for participation-sampled dispatch
/// (`participation < 1.0`): uniform draws from the sorted idle set on a
/// dedicated seed stream, consumed in event order on the single-threaded
/// event loop — so the dispatch sequence is bit-identical at any worker
/// count and never perturbs the data/model/link RNG streams.
struct SlotSampler {
    /// Clients not currently in flight. Order is arbitrary (swap_remove
    /// churn) but deterministic: mutated only from the single-threaded
    /// event loop, so draws replay bit-identically at any worker count.
    idle: Vec<usize>,
    /// `pos[cid]` = cid's index in `idle`, or `IN_FLIGHT`. Keeps release
    /// and draw O(1) per slot at 10⁴–10⁶-client populations — the event
    /// loop processes one of each per arrival.
    pos: Vec<usize>,
    rng: Pcg64,
}

const IN_FLIGHT: usize = usize::MAX;

impl SlotSampler {
    fn new(n: usize, seed: u64) -> Self {
        SlotSampler {
            idle: (0..n).collect(),
            pos: (0..n).collect(),
            rng: Pcg64::new(seed, 0xA51C_0DE5),
        }
    }

    /// Return a client's slot to the idle pool (its arrival or retry was
    /// just processed).
    fn release(&mut self, cid: usize) {
        debug_assert!(self.pos[cid] == IN_FLIGHT, "client {cid} released while already idle");
        self.pos[cid] = self.idle.len();
        self.idle.push(cid);
    }

    /// Draw up to `k` distinct idle clients, uniformly, returned sorted.
    fn draw(&mut self, k: usize) -> Vec<usize> {
        let k = k.min(self.idle.len());
        let mut picked: Vec<usize> = (0..k)
            .map(|_| {
                let i = self.rng.index(self.idle.len());
                let cid = self.idle.swap_remove(i);
                self.pos[cid] = IN_FLIGHT;
                if let Some(&moved) = self.idle.get(i) {
                    self.pos[moved] = i;
                }
                cid
            })
            .collect();
        picked.sort_unstable();
        picked
    }
}

impl AsyncBufferedScheduler {
    /// `k` arrivals per apply, staleness exponent `p`.
    pub fn new(k: usize, p: f64, conf: SchedConfig) -> Self {
        assert!(k >= 1, "async k must be >= 1");
        AsyncBufferedScheduler { k, p, conf }
    }

    /// Dispatch `cids` at virtual time `now` on model `version`: dropout
    /// check per attempt, broadcast (charged), fanned local training,
    /// upload, and one arrival event per surviving client. Dropped
    /// attempts wake as [`Event::Retry`] after the latency the attempt
    /// would have cost.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        sim: &mut Simulation,
        compute: &ComputeModel,
        queue: &mut EventQueue<Event>,
        dispatches: &mut [u64],
        version: u64,
        cids: &[usize],
        now: f64,
        workers: usize,
    ) -> Result<()> {
        let tel = sim.telemetry.clone();
        let mut alive: Vec<usize> = Vec::with_capacity(cids.len());
        for &cid in cids {
            let attempt = dispatches[cid];
            if sim.dropout.survives(attempt as usize, cid) {
                alive.push(cid);
            } else {
                // No broadcast received, no upload sent, no bytes charged;
                // the client reappears after its message latencies (plus
                // compute, mirroring a crash-and-restart of the attempt).
                let wake =
                    now + compute.draw(attempt, cid) + sim.network.link(cid).round_trip_time(0, 0);
                dispatches[cid] += 1;
                if let Some(t) = tel.as_deref() {
                    t.count("dropouts", 1);
                }
                queue.push(wake, Event::Retry { cid });
            }
        }
        if alive.is_empty() {
            return Ok(());
        }
        if let Some(obs) = sim.observer.as_mut() {
            obs.on_dispatch(&DispatchEvent {
                round: version as usize,
                cids: &alive,
                vtime: now,
                model_version: version,
            });
        }

        // One encoded broadcast per model version — now the shared
        // simulation-level cache ([`crate::net::BroadcastCache`]), which
        // every scheduler consults; only the cache miss pays (and traces)
        // the encode.
        let frame: Arc<[u8]> = sim.broadcast_frame(version, version);
        // Stages 1–3 (shared with the semi-sync scheduler): broadcast,
        // fanned client phase, upload, arrival stamping. The initial
        // cohort dispatch is the parallel case; steady-state re-dispatches
        // are single lanes.
        for up in super::dispatch_uploads(
            sim, &frame, &alive, now, workers, compute, dispatches, version,
        )? {
            queue.push(up.arrival_s, Event::Arrival { up, version });
        }
        Ok(())
    }
}

impl Scheduler for AsyncBufferedScheduler {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run(
        &mut self,
        sim: &mut Simulation,
        progress: &mut dyn FnMut(usize, &RoundRecord),
    ) -> Result<RunReport> {
        let workers = sim.cfg.resolved_workers();
        let compute = ComputeModel::new(&self.conf, sim.cfg.seed);
        let n = sim.lanes.len();
        let tel = sim.telemetry.clone();
        let mut queue: EventQueue<Event> = EventQueue::new();
        let mut dispatches = vec![0u64; n];

        // Concurrency target: `participation` bounds how many clients are
        // in flight at once. At 1.0 (default) the sampler is disabled and
        // the original all-clients-always-running FedBuff regime runs
        // bit-exactly (no sampling RNG is consumed).
        let target = ((n as f64 * sim.cfg.participation).round() as usize).clamp(1, n);
        let mut sampler = (target < n).then(|| SlotSampler::new(n, sim.cfg.seed));

        // Kick-off: the initial cohort starts on the initial model at
        // once — everyone without sampling, a uniform draw of `target`
        // clients with it.
        let initial: Vec<usize> = match sampler.as_mut() {
            None => (0..n).collect(),
            Some(s) => s.draw(target),
        };
        let t0 = sim.vclock;
        let v0 = sim.model_version;
        self.dispatch(sim, &compute, &mut queue, &mut dispatches, v0, &initial, t0, workers)?;

        let mut applies = 0usize;
        let mut agg = ServerAggregator::with_backend(&sim.meta, sim.backend);
        let mut wsum = 0.0f64;
        let mut buffered = 0usize;
        let mut folded_cids: Vec<usize> = Vec::new();
        let mut loss_sum = 0.0f64;
        let mut sum_d = 0u64;
        let mut t_last_apply = t0;

        while applies < sim.cfg.rounds {
            let Some((t, _seq, first)) = queue.pop() else {
                bail!(
                    "async scheduler event queue drained after {applies} of {} applies",
                    sim.cfg.rounds
                );
            };
            sim.vclock = t;
            // Micro-batched event group: handle this event and every other
            // event scheduled at exactly `t`, strictly in event order, but
            // defer the freed slots into `redispatch` so the group ends in
            // one parallel dispatch instead of per-event single-lane
            // dispatches (see the module docs). Nothing dispatched here
            // can land at time `t` again (latencies are positive), so the
            // deferral never reorders the group.
            let mut redispatch: Vec<usize> = Vec::new();
            let mut ev = Some(first);
            while let Some(e) = ev.take() {
                match e {
                    Event::Retry { cid } => {
                        // The dropped attempt's slot frees; without
                        // sampling the same client retries, with sampling
                        // the slot is refilled by a fresh uniform draw
                        // over the idle pool (which includes the dropped
                        // client).
                        match sampler.as_mut() {
                            None => redispatch.push(cid),
                            Some(s) => {
                                s.release(cid);
                                redispatch.extend(s.draw(1));
                            }
                        }
                    }
                    Event::Arrival { up, version: v } => {
                        let cid = up.cid;
                        // The fold-as-it-lands path: charge, decode with
                        // the lane's paired decompressor (lockstep), fold
                        // with the staleness-discounted weight.
                        sim.ledger.charge_uplink(up.frame.len() as u64);
                        let sp = Telemetry::timer(tel.as_deref());
                        let payloads = wire::decode(&up.frame)
                            .with_context(|| format!("decoding client {cid}'s upload"))?;
                        if let Some(tl) = tel.as_deref() {
                            tl.count_payloads(&payloads);
                        }
                        // The dispatched lane was pinned in flight;
                        // decoding its arrival releases it for eviction.
                        let updates = sim.lanes.lane_mut(cid).decompressor.decode(payloads);
                        sim.lanes.unpin(cid);
                        if let Some(sp) = sp {
                            sp.end(Phase::ServerDecode, v, Some(cid as u32));
                        }
                        let tau = sim.model_version - v;
                        let w = up.weight / (1.0 + tau as f64).powf(self.p);
                        if let Some(tl) = tel.as_deref() {
                            tl.observe_staleness(tau);
                            if tau > 0 {
                                tl.count("stragglers", 1);
                            }
                            tl.count("folds", 1);
                        }
                        // The observer sees exactly the arrivals that fold
                        // (the shutdown drain below stays silent), so an
                        // arrival count equals the fold count.
                        if let Some(obs) = sim.observer.as_mut() {
                            obs.on_arrival(&ArrivalEvent {
                                round: applies,
                                cid,
                                updates: &updates,
                                meta: &sim.meta,
                                weight: w,
                                staleness: tau,
                                vtime: t,
                                on_time: tau == 0,
                            });
                        }
                        let sp = Telemetry::timer(tel.as_deref());
                        agg.fold(w as f32, updates);
                        if let Some(sp) = sp {
                            sp.end(Phase::Fold, applies as u64, Some(cid as u32));
                        }
                        wsum += w;
                        buffered += 1;
                        folded_cids.push(cid);
                        loss_sum += up.mean_loss;
                        sum_d += up.sum_d;

                        if buffered == self.k {
                            // Apply: normalize the buffered aggregate by
                            // the discounted weight sum, bump the version.
                            let full = std::mem::replace(
                                &mut agg,
                                ServerAggregator::with_backend(&sim.meta, sim.backend),
                            );
                            let sp = Telemetry::timer(tel.as_deref());
                            if wsum > 0.0 {
                                sim.global
                                    .axpy((1.0 / wsum) as f32, &full.finish(&sim.meta));
                            }
                            if let Some(sp) = sp {
                                sp.end(Phase::Apply, applies as u64, None);
                            }
                            sim.model_version += 1;
                            if let Some(tl) = tel.as_deref() {
                                tl.count("applies", 1);
                                tl.gauge(
                                    "slots.in_flight",
                                    sampler.as_ref().map_or(n, |s| n - s.idle.len()) as f64,
                                );
                            }
                            if let Some(obs) = sim.observer.as_mut() {
                                obs.on_apply(&ApplyEvent {
                                    round: applies,
                                    vtime: t,
                                    folded: self.k,
                                    wtotal: wsum,
                                });
                            }
                            let sp = Telemetry::timer(tel.as_deref());
                            let (test_loss, test_acc) = if applies % sim.cfg.eval_every == 0
                                || applies + 1 == sim.cfg.rounds
                            {
                                sim.trainer.evaluate(&sim.global, &sim.test_data)?
                            } else {
                                (f64::NAN, f64::NAN)
                            };
                            if let Some(sp) = sp {
                                sp.end(Phase::Eval, applies as u64, None);
                            }
                            let (up_b, down_b) = sim.ledger.end_round();
                            folded_cids.sort_unstable();
                            let mut record = RoundRecord {
                                round: applies,
                                train_loss: loss_sum / self.k as f64,
                                test_accuracy: test_acc,
                                test_loss,
                                uplink_bytes: up_b,
                                downlink_bytes: down_b,
                                sim_time_s: t - t_last_apply,
                                sim_clock_s: t,
                                sum_d,
                                survivors: std::mem::take(&mut folded_cids),
                                ext: None,
                            };
                            sim.telemetry_round_end(&mut record);
                            sim.recorder.push(record.clone());
                            if let Some(obs) = sim.observer.as_mut() {
                                obs.on_round(applies, &record);
                            }
                            progress(applies, &record);
                            t_last_apply = t;
                            applies += 1;
                            wsum = 0.0;
                            buffered = 0;
                            loss_sum = 0.0;
                            sum_d = 0;
                        }

                        // Queue the freed slot for the group's batched
                        // re-dispatch on the newest model. Without
                        // sampling the same client goes back out; with it
                        // the slot goes to a fresh uniform draw over the
                        // idle pool.
                        match sampler.as_mut() {
                            None => redispatch.push(cid),
                            Some(s) => {
                                s.release(cid);
                                redispatch.extend(s.draw(1));
                            }
                        }
                    }
                }
                // A final apply mid-group ends the run: the instant's
                // remaining events go to the shutdown drain below, and no
                // slot is re-dispatched (a training pass whose arrival
                // nothing would fold).
                if applies >= sim.cfg.rounds {
                    redispatch.clear();
                    break;
                }
                if queue.peek_time().is_some_and(|pt| pt.total_cmp(&t).is_eq()) {
                    ev = queue.pop().map(|(_, _, e)| e);
                }
            }
            if !redispatch.is_empty() {
                let v = sim.model_version;
                self.dispatch(
                    sim, &compute, &mut queue, &mut dispatches, v, &redispatch, t, workers,
                )?;
            }
        }

        // In-flight uploads at shutdown: charged + decoded so lane state
        // stays in lockstep (shared shutdown-drain helper).
        while let Some((_, _, ev)) = queue.pop() {
            if let Event::Arrival { up, .. } = ev {
                super::absorb_trailing_upload(sim, up.cid, &up.frame)?;
            }
        }
        Ok(sim.finish_report())
    }
}
