//! Live communication-efficiency estimator: uplink bytes per unit of
//! training-loss decrease.
//!
//! The paper's headline claim is a comms-vs-quality trade: GradESTC
//! should reach a given loss for fewer uplink bytes than SVDFed or dense
//! FedAvg. This estimator turns that into a per-round running number —
//! cumulative uplink bytes divided by how far the training loss has
//! fallen from its first observed value. Lower is better; `None` until
//! the loss has actually decreased (a ratio against a zero or negative
//! drop would be noise, not signal).
//!
//! Memory: O(1) — a byte counter and the first finite loss.

/// One round's communication-efficiency reading.
#[derive(Clone, Copy, Debug)]
pub struct CommsSample {
    /// Running uplink total after this round (monotone by construction).
    pub cum_uplink_bytes: u64,
    /// First-round train loss minus this round's; `None` until a finite
    /// baseline loss exists.
    pub loss_drop: Option<f64>,
    /// `cum_uplink_bytes / loss_drop`, defined only once the loss has
    /// decreased (`loss_drop > 0`).
    pub bytes_per_loss: Option<f64>,
}

/// Streaming bytes-per-loss tracker.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommsEfficiency {
    cum_bytes: u64,
    first_loss: Option<f64>,
}

impl CommsEfficiency {
    /// Fresh tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one finished round's uplink bytes and train loss.
    pub fn observe_round(&mut self, uplink_bytes: u64, train_loss: f64) -> CommsSample {
        self.cum_bytes += uplink_bytes;
        if self.first_loss.is_none() && train_loss.is_finite() {
            self.first_loss = Some(train_loss);
        }
        let loss_drop = self.first_loss.map(|f| f - train_loss);
        let bytes_per_loss = loss_drop
            .filter(|&d| d > 0.0)
            .map(|d| self.cum_bytes as f64 / d);
        CommsSample { cum_uplink_bytes: self.cum_bytes, loss_drop, bytes_per_loss }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cumulative_bytes_are_monotone() {
        let mut c = CommsEfficiency::new();
        let mut last = 0;
        for (bytes, loss) in [(100, 2.0), (50, 1.5), (0, 1.2), (75, 1.1)] {
            let s = c.observe_round(bytes, loss);
            assert!(s.cum_uplink_bytes >= last);
            last = s.cum_uplink_bytes;
        }
        assert_eq!(last, 225);
    }

    #[test]
    fn ratio_waits_for_improvement() {
        let mut c = CommsEfficiency::new();
        let s = c.observe_round(100, 2.0);
        assert_eq!(s.loss_drop, Some(0.0), "baseline round: zero drop");
        assert!(s.bytes_per_loss.is_none(), "no decrease yet");
        let s = c.observe_round(100, 2.5);
        assert!(s.bytes_per_loss.is_none(), "loss went up: still undefined");
        let s = c.observe_round(100, 1.0);
        assert_eq!(s.loss_drop, Some(1.0));
        assert!((s.bytes_per_loss.unwrap() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn nan_loss_never_becomes_the_baseline() {
        let mut c = CommsEfficiency::new();
        let s = c.observe_round(10, f64::NAN);
        assert!(s.loss_drop.is_none());
        let s = c.observe_round(10, 3.0);
        assert_eq!(s.loss_drop, Some(0.0), "first finite loss is the baseline");
    }
}
