//! Streaming temporal-correlation estimator.
//!
//! The Fig. 1 [`SimilarityProbe`](crate::metrics::SimilarityProbe) keeps
//! every round's dense gradient for its client — O(rounds × model)
//! memory, fine for a 40-round figure, fatal at `exp scale2`
//! populations. This estimator keeps only the *previous* arrival per
//! sampled client and folds each adjacent-pair cosine into running sums
//! as arrivals stream in: O(sample × model) memory, O(model) work per
//! sampled arrival.
//!
//! Equivalence contract: with a single-client sample and one arrival per
//! round, the run-level mean per layer is bitwise-equal to
//! `SimilarityProbe::adjacent_similarity` on the same gradient stream —
//! same [`cosine`] kernel, same f64 summation order (increasing round),
//! same divisor. `rust/tests/diag.rs` locks this in end to end.

use std::collections::BTreeMap;

use crate::metrics::cosine;

/// Per-client previous-arrival store + adjacent-cosine computation.
pub struct StreamingCosine {
    /// Sorted sampled client ids; arrivals from anyone else are ignored.
    sample: Vec<usize>,
    /// `cid ->` the previous arrival's dense per-layer update.
    prev: BTreeMap<usize, Vec<Vec<f32>>>,
}

impl StreamingCosine {
    /// Estimator over a sorted sampled-client subset.
    pub fn new(sample: Vec<usize>) -> Self {
        debug_assert!(sample.windows(2).all(|w| w[0] < w[1]));
        StreamingCosine { sample, prev: BTreeMap::new() }
    }

    /// Is `cid` in the sampled subset?
    pub fn is_sampled(&self, cid: usize) -> bool {
        self.sample.binary_search(&cid).is_ok()
    }

    /// The sampled subset.
    pub fn sample(&self) -> &[usize] {
        &self.sample
    }

    /// Observe one sampled client's dense update. Returns the per-layer
    /// cosines against that client's previous arrival (`None` on its
    /// first arrival). The dense buffers are retained as the new
    /// previous-round state, replacing the old ones — memory stays at
    /// one model per sampled client.
    pub fn observe(&mut self, cid: usize, dense: Vec<Vec<f32>>) -> Option<Vec<f64>> {
        debug_assert!(self.is_sampled(cid));
        let prev = self.prev.insert(cid, dense);
        let prev = prev?;
        let cur = &self.prev[&cid];
        if prev.len() != cur.len() {
            return None;
        }
        Some(prev.iter().zip(cur.iter()).map(|(a, b)| cosine(a, b)).collect())
    }

    /// Bytes currently held (the O(prev-round) bound the docs promise).
    pub fn resident_bytes(&self) -> usize {
        self.prev
            .values()
            .map(|layers| layers.iter().map(|v| 4 * v.len()).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_arrival_primes_then_pairs() {
        let mut s = StreamingCosine::new(vec![0, 3]);
        assert!(s.is_sampled(0) && s.is_sampled(3) && !s.is_sampled(1));
        assert!(s.observe(0, vec![vec![1.0, 0.0]]).is_none());
        let c = s.observe(0, vec![vec![2.0, 0.0]]).unwrap();
        assert!((c[0] - 1.0).abs() < 1e-12, "parallel vectors: {c:?}");
        let c = s.observe(0, vec![vec![0.0, 5.0]]).unwrap();
        assert!(c[0].abs() < 1e-12, "orthogonal vectors: {c:?}");
    }

    #[test]
    fn memory_stays_one_model_per_client() {
        let mut s = StreamingCosine::new(vec![1]);
        for r in 0..50 {
            s.observe(1, vec![vec![r as f32; 128], vec![1.0; 64]]);
            assert_eq!(s.resident_bytes(), 4 * (128 + 64));
        }
    }

    #[test]
    fn matches_lazy_adjacent_similarity_bitwise() {
        use crate::metrics::SimilarityProbe;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(9);
        let mut probe = SimilarityProbe::new(vec!["a".into(), "b".into()]);
        let mut stream = StreamingCosine::new(vec![0]);
        let mut sum = [0.0f64; 2];
        let mut pairs = 0u64;
        for _ in 0..12 {
            let grads = vec![rng.normal_vec(96), rng.normal_vec(33)];
            probe.record_round(grads.clone());
            if let Some(c) = stream.observe(0, grads) {
                sum[0] += c[0];
                sum[1] += c[1];
                pairs += 1;
            }
        }
        let lazy = probe.adjacent_similarity();
        assert_eq!(pairs, 11);
        for l in 0..2 {
            let mean = sum[l] / pairs as f64;
            assert_eq!(mean.to_bits(), lazy[l].to_bits(), "layer {l} diverged");
        }
    }
}
