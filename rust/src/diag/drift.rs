//! Subspace-drift estimator: how fast does the compression basis move?
//!
//! GradESTC replaces a few basis columns per round (`d_r ≪ k`); SVDFed
//! keeps a frozen basis between wholesale refits. Both behaviours show up
//! directly in the principal angles between a layer's consecutive
//! server-side bases: near-zero angles mean the subspace is temporally
//! stable (the reuse premise holds), angles near π/2 mean the mined
//! directions are orthogonal to everything the basis knew (the premise is
//! breaking — e.g. under staleness or heterogeneity).
//!
//! The estimator keeps one pool-shared `Arc<Mat>` per tracked layer (the
//! previous snapshot) and compares on change: an unchanged `Arc` (the
//! `d_r = 0` steady state, or SVDFed between refits) is recognized by
//! pointer identity and reported as exact-zero drift without touching the
//! linalg plane at all.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::linalg::{chordal_distance, principal_angles_in, Backend, Mat};

/// One layer's drift measurement between consecutive basis snapshots.
#[derive(Clone, Debug)]
pub struct DriftSample {
    /// Tensor index the basis belongs to.
    pub tensor: usize,
    /// Mean principal angle, radians, in `[0, π/2]`.
    pub mean_angle: f64,
    /// Largest principal angle, radians.
    pub max_angle: f64,
    /// Chordal distance `sqrt(Σ sin²θᵢ)`.
    pub chordal: f64,
    /// Columns whose bits changed — the observed `d_r` (includes re-ortho
    /// repairs, which the wire-level `sum_d` does not count).
    pub churn: u64,
}

/// Streaming basis-drift tracker for one reference lane.
pub struct SubspaceDrift {
    backend: &'static dyn Backend,
    prev: BTreeMap<usize, Arc<Mat>>,
}

impl SubspaceDrift {
    /// Tracker running its small SVDs through `backend`.
    pub fn new(backend: &'static dyn Backend) -> Self {
        SubspaceDrift { backend, prev: BTreeMap::new() }
    }

    /// Observe the basis that arrived for `tensor`. Returns `None` on the
    /// first sighting (nothing to diff against) or on a geometry change;
    /// afterwards, one [`DriftSample`] per call.
    pub fn observe(&mut self, tensor: usize, basis: &Arc<Mat>) -> Option<DriftSample> {
        let prev = self.prev.insert(tensor, Arc::clone(basis))?;
        if Arc::ptr_eq(&prev, basis) {
            // Steady state: the lane kept its pool entry, so the subspace
            // is bit-identical — exact zero, no linalg.
            return Some(DriftSample {
                tensor,
                mean_angle: 0.0,
                max_angle: 0.0,
                chordal: 0.0,
                churn: 0,
            });
        }
        if prev.rows() != basis.rows() || prev.cols() != basis.cols() {
            return None;
        }
        let k = basis.cols();
        let mut churn = 0u64;
        for j in 0..k {
            let same = prev
                .col(j)
                .iter()
                .zip(basis.col(j).iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if !same {
                churn += 1;
            }
        }
        let angles = principal_angles_in(self.backend, &prev, basis);
        if angles.is_empty() {
            return None;
        }
        let mean = angles.iter().sum::<f64>() / angles.len() as f64;
        let max = angles.iter().fold(0.0f64, |m, &a| m.max(a));
        Some(DriftSample {
            tensor,
            mean_angle: mean,
            max_angle: max,
            chordal: chordal_distance(&angles),
            churn,
        })
    }

    /// Number of layers currently tracked.
    pub fn tracked(&self) -> usize {
        self.prev.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{default_backend, mgs_orthonormalize};
    use crate::util::rng::Pcg64;

    fn ortho(seed: u64, l: usize, k: usize) -> Arc<Mat> {
        let mut rng = Pcg64::seeded(seed);
        Arc::new(mgs_orthonormalize(&Mat::randn(l, k, &mut rng)))
    }

    #[test]
    fn first_sighting_yields_nothing_then_tracks() {
        let mut d = SubspaceDrift::new(default_backend());
        let b = ortho(1, 20, 4);
        assert!(d.observe(0, &b).is_none());
        assert_eq!(d.tracked(), 1);
        let s = d.observe(0, &b).expect("second sighting measures");
        assert_eq!(s.churn, 0);
        assert_eq!(s.mean_angle, 0.0);
        assert_eq!(s.chordal, 0.0);
    }

    #[test]
    fn identical_content_distinct_arcs_show_zero_angles() {
        let mut d = SubspaceDrift::new(default_backend());
        let b = ortho(2, 24, 4);
        let b2 = Arc::new((*b).clone());
        d.observe(3, &b);
        let s = d.observe(3, &b2).unwrap();
        assert_eq!(s.churn, 0, "identical bits, no churn");
        assert!(s.mean_angle < 1e-3, "angles ~0, got {}", s.mean_angle);
    }

    #[test]
    fn column_swap_is_counted_and_measured() {
        let mut d = SubspaceDrift::new(default_backend());
        let b = ortho(3, 30, 4);
        d.observe(0, &b);
        // Replace one column with a fresh orthogonal-ish direction.
        let mut m = (*b).clone();
        let repl = ortho(4, 30, 4);
        for i in 0..30 {
            m[(i, 2)] = repl[(i, 2)];
        }
        let s = d.observe(0, &Arc::new(m)).unwrap();
        assert_eq!(s.churn, 1, "exactly one column changed");
        assert!(s.max_angle > 0.1, "a replaced column must move an angle");
        assert!(s.chordal > 0.0);
    }

    #[test]
    fn geometry_change_resets_cleanly() {
        let mut d = SubspaceDrift::new(default_backend());
        d.observe(0, &ortho(5, 20, 4));
        assert!(d.observe(0, &ortho(6, 20, 6)).is_none(), "k changed: no sample");
    }
}
