//! Diagnostics plane (plane 9): streaming estimators of the gradient
//! structure GradESTC *assumes*.
//!
//! The paper's premise is empirical — gradients are low-rank in space
//! and correlated in time, so a mostly-reused basis plus fresh
//! coefficients suffices. This module measures that premise continuously
//! while a run executes, instead of asserting it offline:
//!
//! * [`SubspaceDrift`] — principal angles and chordal distance between a
//!   layer's consecutive server-side bases (GradESTC/SVDFed), plus the
//!   observed basis churn `d_r`. Memory: one `Arc<Mat>` per tracked
//!   layer (a pool-shared pointer, never a copy).
//! * [`StreamingCosine`] — adjacent-round cosine similarity per layer
//!   for a small deterministically-sampled client subset. Memory:
//!   O(sample × model) — the previous round's dense update per sampled
//!   client, never the full history the Fig. 1
//!   [`SimilarityProbe`](crate::metrics::SimilarityProbe) keeps, so it
//!   runs at `exp scale2` populations.
//! * [`Fidelity`] — per sampled arrival: reconstruction NRMSE of the
//!   update under the *previous* basis (the streaming-measurable form of
//!   `‖G − Ĝ‖/‖G‖`: the server never sees the pre-compression gradient,
//!   so fidelity is measured against the reused basis — exactly the
//!   quantity GradESTC's temporal-reuse bet rides on; exactly 0 for
//!   lossless dense decodes), the energy-coverage ratio (its square
//!   complement), the stable rank of the update's coefficient matrix,
//!   and bytes per unit of gradient energy. Memory: one `Arc<Mat>` per
//!   (sampled client, layer).
//! * [`CommsEfficiency`] — cumulative uplink bytes per unit of training
//!   loss decrease. Memory: O(1).
//!
//! Estimator outputs accumulate into a [`DiagState`] of per-round,
//! per-layer [`DiagRow`]s, exported as `diag.csv` and a metrics-JSON
//! section by [`crate::telemetry::export`]. The driver is
//! [`DiagProbe`](crate::telemetry::DiagProbe), an
//! [`Observer`](crate::telemetry::Observer) — so the same estimators
//! stream from the sync, semi-sync, and async schedulers.
//!
//! **Observation, never result:** estimators only read decoded updates
//! and pool-shared basis snapshots handed to the observer; all sampling
//! draws from a dedicated seed stream at construction, and every
//! computation happens on copies — a diag-on run is bit-identical to a
//! diag-off run at any worker count (`rust/tests/diag.rs`).

mod comms;
mod drift;
mod fidelity;
mod stream;

pub use comms::{CommsEfficiency, CommsSample};
pub use drift::{DriftSample, SubspaceDrift};
pub use fidelity::{Fidelity, FidelitySample};
pub use stream::StreamingCosine;

use crate::util::rng::Pcg64;

/// Dedicated seed-stream tag for the diagnostics plane's client sampling
/// (never shared with simulation streams, so arming diag perturbs no
/// simulation draw).
const DIAG_STREAM: u64 = 0xD1A6;

/// Knobs for the diagnostics plane.
#[derive(Clone, Copy, Debug)]
pub struct DiagConfig {
    /// Sampled-client subset size for the streaming-correlation and
    /// fidelity estimators (clamped to the population).
    pub sample: usize,
}

impl Default for DiagConfig {
    fn default() -> Self {
        DiagConfig { sample: 4 }
    }
}

/// Deterministically sample `want` distinct client ids from `0..n` on a
/// dedicated `(seed, DIAG_STREAM)` Pcg64 stream, returned sorted. Draws
/// happen once, at probe construction, in a fixed order — never during
/// the event loop — so the subset is a pure function of `(seed, n, want)`.
pub fn sample_clients(seed: u64, n: usize, want: usize) -> Vec<usize> {
    let want = want.min(n);
    if want == 0 || n == 0 {
        return Vec::new();
    }
    // Dense request: take the prefix (rejection sampling would thrash).
    if want * 2 >= n {
        return (0..want).collect();
    }
    let mut rng = Pcg64::new(seed, DIAG_STREAM);
    let mut picked = Vec::with_capacity(want);
    while picked.len() < want {
        let c = rng.below(n as u64) as usize;
        if !picked.contains(&c) {
            picked.push(c);
        }
    }
    picked.sort_unstable();
    picked
}

/// One diagnostics observation: a `(round, layer)` cell, or the round
/// aggregate when `layer == "*"`. Absent metrics (`None`) mean the
/// estimator had nothing to measure there (e.g. no basis on a TopK run,
/// no previous arrival yet) and serialize as empty CSV cells.
#[derive(Clone, Debug, Default)]
pub struct DiagRow {
    /// Round index (async: apply index), matching the run's `RoundRecord`s.
    pub round: usize,
    /// Layer name from the model's layer table, or `"*"` for the
    /// round-aggregate row.
    pub layer: String,
    /// Mean principal angle (radians) between this round's and the
    /// previous round's basis for the reference client's lane.
    pub drift_mean_angle: Option<f64>,
    /// Largest principal angle (radians).
    pub drift_max_angle: Option<f64>,
    /// Chordal distance `sqrt(Σ sin²θᵢ)` between consecutive bases.
    pub drift_chordal: Option<f64>,
    /// Observed basis churn: columns whose bits changed since the
    /// previous basis snapshot (the streaming view of the paper's `d_r`).
    pub churn_dr: Option<u64>,
    /// `‖M_prevᵀĜ‖²/‖Ĝ‖²` — fraction of update energy the previous
    /// basis still captures (1 − NRMSE²).
    pub energy_coverage: Option<f64>,
    /// Mean adjacent-arrival cosine similarity over the sampled clients.
    pub cosine: Option<f64>,
    /// Reconstruction NRMSE under the previous basis (0 for lossless
    /// dense decodes; see [`Fidelity`]).
    pub nrmse: Option<f64>,
    /// Stable rank `Σσᵢ²/σ₁²` of the update's coefficient matrix.
    pub stable_rank: Option<f64>,
    /// Stored-float bytes per unit of update energy (`Σ‖·‖²`), over the
    /// sampled arrivals.
    pub bytes_per_unit_energy: Option<f64>,
    /// Running uplink total after this round (aggregate row only).
    pub cum_uplink_bytes: Option<u64>,
    /// First-round train loss minus this round's (aggregate row only).
    pub loss_drop: Option<f64>,
    /// `cum_uplink_bytes / loss_drop` when the loss has decreased
    /// (aggregate row only).
    pub bytes_per_loss: Option<f64>,
}

/// Everything the diagnostics plane accumulated over one run. Shared
/// `Rc<RefCell<_>>` between the installed
/// [`DiagProbe`](crate::telemetry::DiagProbe) and the caller that
/// exports it after the run.
#[derive(Clone, Debug, Default)]
pub struct DiagState {
    /// Per-round rows, layer rows first, then the `"*"` aggregate, in
    /// round order.
    pub rows: Vec<DiagRow>,
    /// The sampled client subset (sorted).
    pub sample: Vec<usize>,
    /// Layer names in tensor order (filled on the first arrival).
    pub layer_names: Vec<String>,
    /// Run-level adjacent-cosine sums per layer (summed in arrival
    /// order) and the number of adjacent pairs observed — the streaming
    /// equivalent of
    /// [`SimilarityProbe::adjacent_similarity`](crate::metrics::SimilarityProbe::adjacent_similarity)
    /// (bitwise-equal on a single-client sample).
    pub run_adj_sum: Vec<f64>,
    /// Adjacent pairs behind `run_adj_sum`.
    pub run_adj_pairs: u64,
}

impl DiagState {
    /// Mean adjacent-arrival cosine per layer over the whole run
    /// (`NaN`-free: zeros when no pair was ever observed).
    pub fn adjacent_mean_per_layer(&self) -> Vec<f64> {
        if self.run_adj_pairs == 0 {
            return vec![0.0; self.run_adj_sum.len()];
        }
        self.run_adj_sum.iter().map(|s| s / self.run_adj_pairs as f64).collect()
    }

    /// Rows for one round, aggregate row last.
    pub fn rows_for_round(&self, round: usize) -> Vec<&DiagRow> {
        self.rows.iter().filter(|r| r.round == round).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_sorted_distinct() {
        let a = sample_clients(7, 1000, 4);
        let b = sample_clients(7, 1000, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted distinct: {a:?}");
        assert!(a.iter().all(|&c| c < 1000));
        let c = sample_clients(8, 1000, 4);
        assert_ne!(a, c, "different seeds should (generically) differ");
    }

    #[test]
    fn sampling_clamps_and_degenerates() {
        assert_eq!(sample_clients(1, 4, 8), vec![0, 1, 2, 3]);
        assert_eq!(sample_clients(1, 5, 3), vec![0, 1, 2], "dense request takes the prefix");
        assert!(sample_clients(1, 0, 3).is_empty());
        assert!(sample_clients(1, 10, 0).is_empty());
    }

    #[test]
    fn adjacent_mean_handles_empty() {
        let st = DiagState::default();
        assert!(st.adjacent_mean_per_layer().is_empty());
        let st = DiagState { run_adj_sum: vec![1.5, 3.0], run_adj_pairs: 3, ..Default::default() };
        let m = st.adjacent_mean_per_layer();
        assert!((m[0] - 0.5).abs() < 1e-12);
        assert!((m[1] - 1.0).abs() < 1e-12);
    }
}
