//! Compression-fidelity estimator for sampled arrivals.
//!
//! What "reconstruction NRMSE `‖G − Ĝ‖/‖G‖`" can honestly mean on the
//! server: the decoded update `Ĝ` is all the server ever has — the
//! pre-compression gradient `G` never crosses the wire for a lossy
//! compressor. Two cases are exactly measurable:
//!
//! * **Lossless dense decodes** (the Raw/FedAvg baseline): `Ĝ = G` by
//!   construction, so NRMSE is exactly 0 — reported as such (the
//!   `scripts/check_diag.py` gate pins this).
//! * **Low-rank decodes** (GradESTC/SVDFed): the update is measured
//!   against the *previous* round's basis for the same lane —
//!   `‖Ĝ − M_prev M_prevᵀ Ĝ‖ / ‖Ĝ‖`. That is the reconstruction error
//!   the scheme would have paid had it reused the stale basis
//!   wholesale, i.e. the quantity GradESTC's temporal-correlation bet
//!   is about: near 0 while the premise holds, rising toward 1 as the
//!   gradient subspace outruns the basis. The energy-coverage ratio is
//!   its complement, `‖M_prevᵀĜ‖²/‖Ĝ‖² = 1 − NRMSE²`.
//!
//! Sparse and quantized decodes carry no basis, so their NRMSE cell is
//! absent (empty in `diag.csv`), never faked.
//!
//! Alongside: the **stable rank** `Σσᵢ²/σ₁²` of the update's coefficient
//! matrix (the basis is orthonormal, so these are the singular values of
//! `Ĝ` itself — a direct low-rankness reading), and **bytes per unit
//! energy** (stored-float bytes ÷ `‖Ĝ‖²` — what a unit of gradient
//! energy costs on the wire under each compressor).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::compress::LayerUpdate;
use crate::linalg::{thin_svd_in, Backend, Mat};

/// One layer's fidelity measurement for one sampled arrival.
#[derive(Clone, Debug)]
pub struct FidelitySample {
    /// Tensor index.
    pub tensor: usize,
    /// Reconstruction NRMSE (see module docs); `None` when not defined
    /// for the payload variant or no previous basis exists yet.
    pub nrmse: Option<f64>,
    /// `1 − NRMSE²` where NRMSE is defined.
    pub energy_coverage: Option<f64>,
    /// Stable rank of the coefficient matrix (low-rank updates only).
    pub stable_rank: Option<f64>,
    /// Stored-float bytes of this layer's update.
    pub bytes: u64,
    /// Squared Frobenius energy of the decoded update.
    pub energy: f64,
}

/// Streaming fidelity tracker over the sampled clients.
pub struct Fidelity {
    backend: &'static dyn Backend,
    /// `(cid, tensor) ->` previous basis snapshot for that lane/layer.
    prev_basis: BTreeMap<(usize, usize), Arc<Mat>>,
}

impl Fidelity {
    /// Tracker running its small products through `backend`.
    pub fn new(backend: &'static dyn Backend) -> Self {
        Fidelity { backend, prev_basis: BTreeMap::new() }
    }

    /// Measure one layer of one sampled arrival.
    pub fn observe_layer(
        &mut self,
        cid: usize,
        tensor: usize,
        update: &LayerUpdate,
    ) -> FidelitySample {
        let bytes = 4 * update.stored_floats() as u64;
        match update {
            LayerUpdate::Dense(v) => FidelitySample {
                tensor,
                // Lossless decode: Ĝ = G exactly.
                nrmse: Some(0.0),
                energy_coverage: Some(1.0),
                stable_rank: None,
                bytes,
                energy: sumsq(v),
            },
            LayerUpdate::Sparse { values, .. } => FidelitySample {
                tensor,
                nrmse: None,
                energy_coverage: None,
                stable_rank: None,
                bytes,
                energy: sumsq(values),
            },
            LayerUpdate::QuantDense { .. } => FidelitySample {
                tensor,
                nrmse: None,
                energy_coverage: None,
                stable_rank: None,
                bytes,
                energy: sumsq(&update.to_dense()),
            },
            LayerUpdate::LowRank { coeffs, basis, .. } => {
                // M orthonormal ⇒ ‖Ĝ‖² = ‖A‖² and σ(Ĝ) = σ(A).
                let energy = sumsq(coeffs.as_slice());
                let stable_rank = {
                    let s = thin_svd_in(self.backend, coeffs, 0).s;
                    let top = s.first().map(|&x| x as f64).unwrap_or(0.0);
                    (top * top > 0.0).then(|| {
                        s.iter().map(|&x| x as f64 * x as f64).sum::<f64>() / (top * top)
                    })
                };
                let prev = self.prev_basis.insert((cid, tensor), Arc::clone(basis));
                let (nrmse, energy_coverage) = match prev {
                    None => (None, None),
                    Some(ref mp) if Arc::ptr_eq(mp, basis) => {
                        // Unchanged basis: span identical, projection exact.
                        (Some(0.0), Some(1.0))
                    }
                    Some(ref mp)
                        if mp.rows() == basis.rows() && energy > 0.0 =>
                    {
                        let c = self.backend.matmul_at_b(mp, basis);
                        let p = self.backend.matmul(&c, coeffs);
                        let captured = sumsq(p.as_slice());
                        let ratio = (captured / energy).clamp(0.0, 1.0);
                        (Some((1.0 - ratio).sqrt()), Some(ratio))
                    }
                    Some(_) => (None, None),
                };
                FidelitySample { tensor, nrmse, energy_coverage, stable_rank, bytes, energy }
            }
        }
    }

    /// Layers currently holding a previous-basis snapshot.
    pub fn tracked(&self) -> usize {
        self.prev_basis.len()
    }
}

fn sumsq(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64 * x as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SegmentGeom;
    use crate::linalg::{default_backend, mgs_orthonormalize};
    use crate::util::rng::Pcg64;

    fn lowrank(seed: u64, l: usize, k: usize, m: usize) -> LayerUpdate {
        let mut rng = Pcg64::seeded(seed);
        LayerUpdate::LowRank {
            coeffs: Mat::randn(k, m, &mut rng),
            basis: Arc::new(mgs_orthonormalize(&Mat::randn(l, k, &mut rng))),
            geom: SegmentGeom { l, m, conv: None },
        }
    }

    #[test]
    fn dense_is_exactly_lossless() {
        let mut f = Fidelity::new(default_backend());
        let s = f.observe_layer(0, 0, &LayerUpdate::Dense(vec![1.0, -2.0, 2.0]));
        assert_eq!(s.nrmse, Some(0.0));
        assert_eq!(s.energy_coverage, Some(1.0));
        assert!((s.energy - 9.0).abs() < 1e-12);
        assert_eq!(s.bytes, 12);
    }

    #[test]
    fn lowrank_unchanged_basis_has_zero_nrmse() {
        let mut f = Fidelity::new(default_backend());
        let u = lowrank(1, 20, 4, 6);
        assert!(f.observe_layer(2, 0, &u).nrmse.is_none(), "no previous basis yet");
        let s = f.observe_layer(2, 0, &u);
        assert_eq!(s.nrmse, Some(0.0), "same Arc: exact zero");
        assert_eq!(s.energy_coverage, Some(1.0));
        let sr = s.stable_rank.unwrap();
        assert!(sr >= 1.0 - 1e-9 && sr <= 4.0 + 1e-9, "stable rank in [1,k]: {sr}");
    }

    #[test]
    fn lowrank_rotated_basis_lands_in_unit_interval() {
        let mut f = Fidelity::new(default_backend());
        f.observe_layer(0, 0, &lowrank(2, 24, 4, 5));
        let s = f.observe_layer(0, 0, &lowrank(3, 24, 4, 5));
        let n = s.nrmse.unwrap();
        assert!((0.0..=1.0).contains(&n), "nrmse {n}");
        let cov = s.energy_coverage.unwrap();
        assert!((cov - (1.0 - n * n)).abs() < 1e-9, "coverage complements nrmse");
    }

    #[test]
    fn orthogonal_prev_basis_gives_nrmse_one() {
        // Basis in span{e0..e3}, previous in span{e4..e7}: zero coverage.
        let mk = |off: usize| {
            let mut m = Mat::zeros(16, 4);
            for j in 0..4 {
                m[(off + j, j)] = 1.0;
            }
            Arc::new(m)
        };
        let mut rng = Pcg64::seeded(4);
        let coeffs = Mat::randn(4, 5, &mut rng);
        let geom = SegmentGeom { l: 16, m: 5, conv: None };
        let mut f = Fidelity::new(default_backend());
        f.observe_layer(
            0,
            0,
            &LayerUpdate::LowRank { coeffs: coeffs.clone(), basis: mk(4), geom },
        );
        let s = f.observe_layer(0, 0, &LayerUpdate::LowRank { coeffs, basis: mk(0), geom });
        assert!((s.nrmse.unwrap() - 1.0).abs() < 1e-6);
        assert!(s.energy_coverage.unwrap() < 1e-9);
    }

    #[test]
    fn sparse_and_quant_report_energy_without_nrmse() {
        let mut f = Fidelity::new(default_backend());
        let s = f.observe_layer(
            0,
            0,
            &LayerUpdate::Sparse { indices: vec![0, 4], values: vec![3.0, 4.0], len: 8 },
        );
        assert!(s.nrmse.is_none());
        assert!((s.energy - 25.0).abs() < 1e-12);
        assert_eq!(s.bytes, 16, "indices + values stored floats");
    }
}
