//! Federated data partitioning (paper §V-A).
//!
//! * IID — a uniform random split.
//! * Dirichlet(α) — label-skew non-IID (Hsu et al. 2019): for each class,
//!   draw client proportions `p ~ Dir(α·1_C)` and deal that class's samples
//!   accordingly. α = 0.5 / 0.1 are the paper's settings; smaller α means
//!   more skew.

use crate::config::DataDistribution;
use crate::util::rng::Pcg64;

/// Per-client sample indices into a shared dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    /// `assignments[c]` = indices owned by client `c`.
    pub assignments: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of clients.
    pub fn num_clients(&self) -> usize {
        self.assignments.len()
    }

    /// Total assigned samples.
    pub fn total(&self) -> usize {
        self.assignments.iter().map(|a| a.len()).sum()
    }
}

/// Split `labels` across `num_clients` according to `dist`.
///
/// Every sample is assigned to exactly one client. Clients are guaranteed a
/// minimum of one sample each (re-dealing from the largest client if the
/// Dirichlet draw starves someone — training code divides by client dataset
/// size).
pub fn partition_indices(
    labels: &[u32],
    num_classes: usize,
    num_clients: usize,
    dist: DataDistribution,
    rng: &mut Pcg64,
) -> Partition {
    assert!(num_clients > 0);
    assert!(labels.len() >= num_clients, "fewer samples than clients");
    let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); num_clients];

    match dist {
        DataDistribution::Iid => {
            let mut idx: Vec<usize> = (0..labels.len()).collect();
            rng.shuffle(&mut idx);
            for (pos, i) in idx.into_iter().enumerate() {
                assignments[pos % num_clients].push(i);
            }
        }
        DataDistribution::Dirichlet(alpha) => {
            assert!(alpha > 0.0, "Dirichlet alpha must be positive");
            // Bucket sample indices per class.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
            for (i, &y) in labels.iter().enumerate() {
                by_class[y as usize].push(i);
            }
            for class_idx in by_class.iter_mut() {
                if class_idx.is_empty() {
                    continue;
                }
                rng.shuffle(class_idx);
                let props = rng.dirichlet(alpha, num_clients);
                // Largest-remainder apportionment of this class's samples.
                let n = class_idx.len();
                let mut counts: Vec<usize> =
                    props.iter().map(|&p| (p * n as f64).floor() as usize).collect();
                let mut rem: usize = n - counts.iter().sum::<usize>();
                // Assign remainders to the clients with the largest
                // fractional parts.
                let mut fracs: Vec<(f64, usize)> = props
                    .iter()
                    .enumerate()
                    .map(|(c, &p)| (p * n as f64 - (p * n as f64).floor(), c))
                    .collect();
                fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                for &(_, c) in fracs.iter().cycle().take(rem.min(n)) {
                    counts[c] += 1;
                    rem -= 1;
                    if rem == 0 {
                        break;
                    }
                }
                let mut cursor = 0;
                for (c, &cnt) in counts.iter().enumerate() {
                    assignments[c].extend_from_slice(&class_idx[cursor..cursor + cnt]);
                    cursor += cnt;
                }
            }
        }
    }

    // Starvation repair: every client gets at least one sample.
    loop {
        let empty = assignments.iter().position(|a| a.is_empty());
        let Some(e) = empty else { break };
        let donor = (0..num_clients)
            .max_by_key(|&c| assignments[c].len())
            .expect("at least one client");
        assert!(assignments[donor].len() > 1, "not enough samples to cover all clients");
        let moved = assignments[donor].pop().unwrap();
        assignments[e].push(moved);
    }

    Partition { assignments }
}

/// Label-distribution skew measure: mean total-variation distance between
/// each client's label histogram and the global histogram. 0 = IID-like,
/// →1 = fully disjoint. Used by tests and the fig7/fig8 harnesses to verify
/// the partitioner actually produces the intended heterogeneity.
pub fn label_skew(labels: &[u32], num_classes: usize, part: &Partition) -> f64 {
    let mut global = vec![0.0f64; num_classes];
    for &y in labels {
        global[y as usize] += 1.0;
    }
    let n = labels.len() as f64;
    global.iter_mut().for_each(|x| *x /= n);

    let mut total = 0.0;
    for a in &part.assignments {
        if a.is_empty() {
            continue;
        }
        let mut h = vec![0.0f64; num_classes];
        for &i in a {
            h[labels[i] as usize] += 1.0;
        }
        let m = a.len() as f64;
        h.iter_mut().for_each(|x| *x /= m);
        let tv: f64 =
            h.iter().zip(&global).map(|(&p, &q)| (p - q).abs()).sum::<f64>() / 2.0;
        total += tv;
    }
    total / part.assignments.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize, rng: &mut Pcg64) -> Vec<u32> {
        (0..n).map(|_| rng.index(classes) as u32).collect()
    }

    #[test]
    fn covers_all_samples_exactly_once() {
        let mut rng = Pcg64::seeded(1);
        let y = labels(1000, 10, &mut rng);
        for dist in [DataDistribution::Iid, DataDistribution::Dirichlet(0.5)] {
            let p = partition_indices(&y, 10, 8, dist, &mut rng);
            let mut all: Vec<usize> = p.assignments.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..1000).collect::<Vec<_>>(), "{dist:?}");
        }
    }

    #[test]
    fn iid_is_balanced() {
        let mut rng = Pcg64::seeded(2);
        let y = labels(1000, 10, &mut rng);
        let p = partition_indices(&y, 10, 8, DataDistribution::Iid, &mut rng);
        for a in &p.assignments {
            assert!((a.len() as i64 - 125).abs() <= 1);
        }
    }

    #[test]
    fn no_client_starves() {
        let mut rng = Pcg64::seeded(3);
        let y = labels(200, 10, &mut rng);
        let p = partition_indices(&y, 10, 50, DataDistribution::Dirichlet(0.05), &mut rng);
        assert!(p.assignments.iter().all(|a| !a.is_empty()));
        assert_eq!(p.total(), 200);
    }

    #[test]
    fn dirichlet_skew_ordering() {
        // Smaller alpha must produce more label skew than larger alpha,
        // and both more than IID — the paper's α=0.1 vs α=0.5 vs IID axis.
        let mut rng = Pcg64::seeded(4);
        let y = labels(5000, 10, &mut rng);
        let p_iid = partition_indices(&y, 10, 10, DataDistribution::Iid, &mut rng);
        let p_05 = partition_indices(&y, 10, 10, DataDistribution::Dirichlet(0.5), &mut rng);
        let p_01 = partition_indices(&y, 10, 10, DataDistribution::Dirichlet(0.1), &mut rng);
        let s_iid = label_skew(&y, 10, &p_iid);
        let s_05 = label_skew(&y, 10, &p_05);
        let s_01 = label_skew(&y, 10, &p_01);
        assert!(s_iid < s_05, "iid {s_iid} vs dir0.5 {s_05}");
        assert!(s_05 < s_01, "dir0.5 {s_05} vs dir0.1 {s_01}");
        assert!(s_01 > 0.4, "alpha=0.1 should be strongly skewed, got {s_01}");
    }

    #[test]
    fn deterministic() {
        let mut r1 = Pcg64::seeded(5);
        let mut r2 = Pcg64::seeded(5);
        let y = labels(300, 10, &mut Pcg64::seeded(9));
        let a = partition_indices(&y, 10, 6, DataDistribution::Dirichlet(0.3), &mut r1);
        let b = partition_indices(&y, 10, 6, DataDistribution::Dirichlet(0.3), &mut r2);
        assert_eq!(a.assignments, b.assignments);
    }
}
