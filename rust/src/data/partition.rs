//! Federated data partitioning (paper §V-A).
//!
//! * IID — a uniform random split.
//! * Dirichlet(α) — label-skew non-IID (Hsu et al. 2019): for each class,
//!   draw client proportions `p ~ Dir(α·1_C)` and deal that class's samples
//!   accordingly. α = 0.5 / 0.1 are the paper's settings; smaller α means
//!   more skew.

use crate::config::DataDistribution;
use crate::util::rng::Pcg64;

/// Per-client sample indices into a shared dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    /// `assignments[c]` = indices owned by client `c`.
    pub assignments: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of clients.
    pub fn num_clients(&self) -> usize {
        self.assignments.len()
    }

    /// Total assigned samples.
    pub fn total(&self) -> usize {
        self.assignments.iter().map(|a| a.len()).sum()
    }
}

/// Split `labels` across `num_clients` according to `dist`.
///
/// Every sample is assigned to exactly one client. Clients are guaranteed a
/// minimum of one sample each (re-dealing from the largest client if the
/// Dirichlet draw starves someone — training code divides by client dataset
/// size).
pub fn partition_indices(
    labels: &[u32],
    num_classes: usize,
    num_clients: usize,
    dist: DataDistribution,
    rng: &mut Pcg64,
) -> Partition {
    assert!(num_clients > 0);
    assert!(labels.len() >= num_clients, "fewer samples than clients");
    let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); num_clients];

    match dist {
        DataDistribution::Iid => {
            let mut idx: Vec<usize> = (0..labels.len()).collect();
            rng.shuffle(&mut idx);
            for (pos, i) in idx.into_iter().enumerate() {
                assignments[pos % num_clients].push(i);
            }
        }
        DataDistribution::Dirichlet(alpha) => {
            assert!(alpha > 0.0, "Dirichlet alpha must be positive");
            // Bucket sample indices per class.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
            for (i, &y) in labels.iter().enumerate() {
                by_class[y as usize].push(i);
            }
            for class_idx in by_class.iter_mut() {
                if class_idx.is_empty() {
                    continue;
                }
                rng.shuffle(class_idx);
                let props = rng.dirichlet(alpha, num_clients);
                // Largest-remainder apportionment of this class's samples.
                let n = class_idx.len();
                let mut counts: Vec<usize> =
                    props.iter().map(|&p| (p * n as f64).floor() as usize).collect();
                let mut rem: usize = n - counts.iter().sum::<usize>();
                // Assign remainders to the clients with the largest
                // fractional parts.
                let mut fracs: Vec<(f64, usize)> = props
                    .iter()
                    .enumerate()
                    .map(|(c, &p)| (p * n as f64 - (p * n as f64).floor(), c))
                    .collect();
                fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                for &(_, c) in fracs.iter().cycle().take(rem.min(n)) {
                    counts[c] += 1;
                    rem -= 1;
                    if rem == 0 {
                        break;
                    }
                }
                let mut cursor = 0;
                for (c, &cnt) in counts.iter().enumerate() {
                    assignments[c].extend_from_slice(&class_idx[cursor..cursor + cnt]);
                    cursor += cnt;
                }
            }
        }
    }

    // Starvation repair: every client gets at least one sample.
    loop {
        let empty = assignments.iter().position(|a| a.is_empty());
        let Some(e) = empty else { break };
        let donor = (0..num_clients)
            .max_by_key(|&c| assignments[c].len())
            .expect("at least one client");
        assert!(assignments[donor].len() > 1, "not enough samples to cover all clients");
        let moved = assignments[donor].pop().unwrap();
        assignments[e].push(moved);
    }

    Partition { assignments }
}

/// A labels-only shard plan: which labels each client's samples carry,
/// decided up front so per-client *pixel* materialization can happen
/// lazily, keyed purely by `(seed, cid)`.
///
/// The eager path drew the whole population's samples in one sequential
/// root-RNG walk, which forces every client's shard to exist before any
/// client can train. The plan keeps the cross-client coupling — the label
/// draw and the [`partition_indices`] split both need the global view —
/// but those are O(total) *integers*, not pixels. Everything heavy (the
/// per-sample mode weights and pixel noise) moves into
/// [`crate::data::synth::SynthGenerator::generate_with_labels`] on a
/// per-client RNG stream, so a sampled-never client costs a handful of
/// label bytes and nothing else, and materialization order cannot change
/// a shard's bits.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Every client's labels, client-major (client `c`'s labels are
    /// `labels[offsets[c]..offsets[c + 1]]`, in assignment order).
    labels: Vec<u32>,
    /// `num_clients + 1` prefix offsets into `labels`.
    offsets: Vec<usize>,
}

impl ShardPlan {
    /// Number of planned clients.
    pub fn num_clients(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Client `cid`'s sample labels, in shard order.
    pub fn labels_of(&self, cid: usize) -> &[u32] {
        &self.labels[self.offsets[cid]..self.offsets[cid + 1]]
    }

    /// Client `cid`'s shard size (its FedAvg weight) — available without
    /// materializing the shard.
    pub fn shard_len(&self, cid: usize) -> usize {
        self.offsets[cid + 1] - self.offsets[cid]
    }

    /// Total planned samples.
    pub fn total(&self) -> usize {
        self.labels.len()
    }
}

/// Plan the federated label split up front: draw `total` uniform labels
/// from `label_rng`, split them with [`partition_indices`] on `part_rng`,
/// and flatten to per-client label runs.
///
/// The two RNGs are dedicated streams (the caller forks them off the run
/// root), so the plan is a pure function of `(seed, total, num_clients,
/// dist)` — the contract that makes lazily materialized shards
/// bit-identical to eagerly materialized ones in any order.
pub fn plan_shards(
    total: usize,
    num_classes: usize,
    num_clients: usize,
    dist: DataDistribution,
    label_rng: &mut Pcg64,
    part_rng: &mut Pcg64,
) -> ShardPlan {
    let labels: Vec<u32> = (0..total).map(|_| label_rng.index(num_classes) as u32).collect();
    let part = partition_indices(&labels, num_classes, num_clients, dist, part_rng);
    let mut flat = Vec::with_capacity(total);
    let mut offsets = Vec::with_capacity(num_clients + 1);
    offsets.push(0);
    for a in &part.assignments {
        flat.extend(a.iter().map(|&i| labels[i]));
        offsets.push(flat.len());
    }
    ShardPlan { labels: flat, offsets }
}

/// Label-distribution skew measure: mean total-variation distance between
/// each client's label histogram and the global histogram. 0 = IID-like,
/// →1 = fully disjoint. Used by tests and the fig7/fig8 harnesses to verify
/// the partitioner actually produces the intended heterogeneity.
pub fn label_skew(labels: &[u32], num_classes: usize, part: &Partition) -> f64 {
    let mut global = vec![0.0f64; num_classes];
    for &y in labels {
        global[y as usize] += 1.0;
    }
    let n = labels.len() as f64;
    global.iter_mut().for_each(|x| *x /= n);

    let mut total = 0.0;
    for a in &part.assignments {
        if a.is_empty() {
            continue;
        }
        let mut h = vec![0.0f64; num_classes];
        for &i in a {
            h[labels[i] as usize] += 1.0;
        }
        let m = a.len() as f64;
        h.iter_mut().for_each(|x| *x /= m);
        let tv: f64 =
            h.iter().zip(&global).map(|(&p, &q)| (p - q).abs()).sum::<f64>() / 2.0;
        total += tv;
    }
    total / part.assignments.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize, rng: &mut Pcg64) -> Vec<u32> {
        (0..n).map(|_| rng.index(classes) as u32).collect()
    }

    #[test]
    fn covers_all_samples_exactly_once() {
        let mut rng = Pcg64::seeded(1);
        let y = labels(1000, 10, &mut rng);
        for dist in [DataDistribution::Iid, DataDistribution::Dirichlet(0.5)] {
            let p = partition_indices(&y, 10, 8, dist, &mut rng);
            let mut all: Vec<usize> = p.assignments.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..1000).collect::<Vec<_>>(), "{dist:?}");
        }
    }

    #[test]
    fn iid_is_balanced() {
        let mut rng = Pcg64::seeded(2);
        let y = labels(1000, 10, &mut rng);
        let p = partition_indices(&y, 10, 8, DataDistribution::Iid, &mut rng);
        for a in &p.assignments {
            assert!((a.len() as i64 - 125).abs() <= 1);
        }
    }

    #[test]
    fn no_client_starves() {
        let mut rng = Pcg64::seeded(3);
        let y = labels(200, 10, &mut rng);
        let p = partition_indices(&y, 10, 50, DataDistribution::Dirichlet(0.05), &mut rng);
        assert!(p.assignments.iter().all(|a| !a.is_empty()));
        assert_eq!(p.total(), 200);
    }

    #[test]
    fn dirichlet_skew_ordering() {
        // Smaller alpha must produce more label skew than larger alpha,
        // and both more than IID — the paper's α=0.1 vs α=0.5 vs IID axis.
        let mut rng = Pcg64::seeded(4);
        let y = labels(5000, 10, &mut rng);
        let p_iid = partition_indices(&y, 10, 10, DataDistribution::Iid, &mut rng);
        let p_05 = partition_indices(&y, 10, 10, DataDistribution::Dirichlet(0.5), &mut rng);
        let p_01 = partition_indices(&y, 10, 10, DataDistribution::Dirichlet(0.1), &mut rng);
        let s_iid = label_skew(&y, 10, &p_iid);
        let s_05 = label_skew(&y, 10, &p_05);
        let s_01 = label_skew(&y, 10, &p_01);
        assert!(s_iid < s_05, "iid {s_iid} vs dir0.5 {s_05}");
        assert!(s_05 < s_01, "dir0.5 {s_05} vs dir0.1 {s_01}");
        assert!(s_01 > 0.4, "alpha=0.1 should be strongly skewed, got {s_01}");
    }

    #[test]
    fn deterministic() {
        let mut r1 = Pcg64::seeded(5);
        let mut r2 = Pcg64::seeded(5);
        let y = labels(300, 10, &mut Pcg64::seeded(9));
        let a = partition_indices(&y, 10, 6, DataDistribution::Dirichlet(0.3), &mut r1);
        let b = partition_indices(&y, 10, 6, DataDistribution::Dirichlet(0.3), &mut r2);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn shard_plan_covers_every_sample_and_is_deterministic() {
        for dist in [DataDistribution::Iid, DataDistribution::Dirichlet(0.5)] {
            let plan = plan_shards(
                400,
                10,
                8,
                dist,
                &mut Pcg64::seeded(21),
                &mut Pcg64::seeded(22),
            );
            assert_eq!(plan.num_clients(), 8);
            assert_eq!(plan.total(), 400);
            let summed: usize = (0..8).map(|c| plan.shard_len(c)).sum();
            assert_eq!(summed, 400, "{dist:?}");
            assert!((0..8).all(|c| plan.shard_len(c) >= 1));
            let again = plan_shards(
                400,
                10,
                8,
                dist,
                &mut Pcg64::seeded(21),
                &mut Pcg64::seeded(22),
            );
            for c in 0..8 {
                assert_eq!(plan.labels_of(c), again.labels_of(c), "{dist:?} client {c}");
            }
        }
    }

    #[test]
    fn shard_plan_matches_partition_of_same_labels() {
        // The plan must be exactly "partition_indices over the drawn
        // labels, flattened" — the frozen reference relationship.
        let mut lrng = Pcg64::seeded(31);
        let mut prng = Pcg64::seeded(32);
        let plan =
            plan_shards(200, 10, 5, DataDistribution::Dirichlet(0.3), &mut lrng, &mut prng);
        // Re-derive with fresh RNGs at the same seeds.
        let mut lrng2 = Pcg64::seeded(31);
        let drawn: Vec<u32> = (0..200).map(|_| lrng2.index(10) as u32).collect();
        let part = partition_indices(
            &drawn,
            10,
            5,
            DataDistribution::Dirichlet(0.3),
            &mut Pcg64::seeded(32),
        );
        for c in 0..5 {
            let want: Vec<u32> = part.assignments[c].iter().map(|&i| drawn[i]).collect();
            assert_eq!(plan.labels_of(c), &want[..]);
        }
    }
}
