//! Synthetic byte corpus for the transformer end-to-end driver.
//!
//! A tiny-corpus stand-in: a stochastic grammar over "words" built from a
//! class-specific Markov chain of byte 5-grams. The resulting text has
//! genuine sequential structure (next-token entropy well below uniform), so
//! a language model's loss curve shows real learning — the e2e driver's
//! success criterion.

use crate::util::rng::Pcg64;

/// A generated corpus of token sequences.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// Flattened sequences, `[n, seq_len]` row-major, tokens in `[0,vocab)`.
    pub tokens: Vec<u32>,
    /// Sequence length.
    pub seq_len: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

impl Corpus {
    /// Number of sequences.
    pub fn len(&self) -> usize {
        self.tokens.len() / self.seq_len
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Sequence `i`.
    pub fn seq(&self, i: usize) -> &[u32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// Markov-chain corpus generator.
pub struct CorpusGenerator {
    vocab: usize,
    /// transition[s] = list of (next_token, cum_prob) — sparse rows.
    transitions: Vec<Vec<(u32, f64)>>,
}

impl CorpusGenerator {
    /// Build a generator whose chain has `branch` successors per state.
    ///
    /// Lower `branch` → lower entropy → easier to model.
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && branch >= 1);
        let mut rng = Pcg64::new(seed, 0xC0);
        let transitions = (0..vocab)
            .map(|_| {
                // Pick `branch` successor tokens with Zipf-ish weights.
                let succ = rng.sample_indices(vocab, branch.min(vocab));
                let weights: Vec<f64> =
                    (0..succ.len()).map(|r| 1.0 / (1.0 + r as f64)).collect();
                let total: f64 = weights.iter().sum();
                let mut cum = 0.0;
                succ.iter()
                    .zip(weights)
                    .map(|(&t, w)| {
                        cum += w / total;
                        (t as u32, cum)
                    })
                    .collect()
            })
            .collect();
        CorpusGenerator { vocab, transitions }
    }

    /// Sample `n` sequences of length `seq_len`.
    pub fn generate(&self, n: usize, seq_len: usize, rng: &mut Pcg64) -> Corpus {
        let mut tokens = Vec::with_capacity(n * seq_len);
        for _ in 0..n {
            let mut state = rng.index(self.vocab) as u32;
            tokens.push(state);
            for _ in 1..seq_len {
                let row = &self.transitions[state as usize];
                let u = rng.f64();
                let next = row
                    .iter()
                    .find(|&&(_, c)| u <= c)
                    .map(|&(t, _)| t)
                    .unwrap_or(row.last().unwrap().0);
                tokens.push(next);
                state = next;
            }
        }
        Corpus { tokens, seq_len, vocab: self.vocab }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let g = CorpusGenerator::new(256, 4, 1);
        let c = g.generate(10, 64, &mut Pcg64::seeded(1));
        assert_eq!(c.len(), 10);
        assert_eq!(c.seq(3).len(), 64);
        assert!(c.tokens.iter().all(|&t| t < 256));
    }

    #[test]
    fn deterministic() {
        let g = CorpusGenerator::new(64, 3, 5);
        let a = g.generate(5, 32, &mut Pcg64::seeded(2));
        let b = g.generate(5, 32, &mut Pcg64::seeded(2));
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn has_low_entropy_structure() {
        // Bigram conditional entropy must be far below log2(vocab): the
        // chain only has `branch` successors per state.
        let vocab = 64;
        let g = CorpusGenerator::new(vocab, 4, 7);
        let c = g.generate(200, 64, &mut Pcg64::seeded(3));
        let mut counts = vec![0.0f64; vocab * vocab];
        let mut marg = vec![0.0f64; vocab];
        for i in 0..c.len() {
            let s = c.seq(i);
            for w in s.windows(2) {
                counts[w[0] as usize * vocab + w[1] as usize] += 1.0;
                marg[w[0] as usize] += 1.0;
            }
        }
        let mut h = 0.0;
        let total: f64 = marg.iter().sum();
        for s in 0..vocab {
            if marg[s] == 0.0 {
                continue;
            }
            for t in 0..vocab {
                let c2 = counts[s * vocab + t];
                if c2 > 0.0 {
                    let p_joint = c2 / total;
                    let p_cond = c2 / marg[s];
                    h -= p_joint * p_cond.log2();
                }
            }
        }
        // 4 successors → entropy ≤ log2(4) = 2 bits ≪ log2(64) = 6 bits.
        assert!(h < 2.5, "conditional entropy {h}");
    }
}
