//! Data substrate: synthetic datasets and federated partitioning.
//!
//! Real MNIST/CIFAR are unavailable offline; [`synth`] generates
//! class-conditional image data with the same shapes and a tunable
//! difficulty (DESIGN.md §2.1 justifies why this preserves the paper's
//! claims). [`partition`] implements the IID and Dirichlet(α) label-skew
//! splits of §V-A. [`corpus`] generates the synthetic byte corpus for the
//! transformer end-to-end example.

pub mod corpus;
pub mod partition;
pub mod synth;

pub use partition::{partition_indices, plan_shards, Partition, ShardPlan};
pub use synth::{Dataset, SynthSpec};
