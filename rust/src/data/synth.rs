//! Synthetic class-conditional image datasets.
//!
//! Each class `c` owns a fixed random *template* image `T_c` plus a bank of
//! low-frequency *modes*; a sample is
//!
//! ```text
//! x = clip( T_c + Σ_j w_j · Mode_{c,j} + σ · noise )
//! ```
//!
//! with per-sample Gaussian mode weights `w` and pixel noise. The modes
//! give every class genuine intra-class variation, so classifiers cannot
//! memorize a single prototype and the SGD gradient stream stays
//! informative for hundreds of rounds — the property GradESTC's evaluation
//! depends on. Difficulty is controlled by template separation and noise.

use crate::config::DatasetKind;
use crate::util::rng::Pcg64;

/// Generation parameters for one dataset family.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Number of classes.
    pub classes: usize,
    /// Template scale (inter-class separation).
    pub template_scale: f32,
    /// Number of intra-class variation modes.
    pub modes: usize,
    /// Mode amplitude.
    pub mode_scale: f32,
    /// Pixel noise σ.
    pub noise: f32,
}

impl SynthSpec {
    /// Canonical spec per dataset kind (shapes match the real datasets).
    pub fn for_kind(kind: DatasetKind) -> SynthSpec {
        match kind {
            DatasetKind::SynthMnist => SynthSpec {
                h: 28,
                w: 28,
                c: 1,
                classes: 10,
                template_scale: 1.0,
                modes: 4,
                mode_scale: 0.45,
                noise: 0.25,
            },
            DatasetKind::SynthCifar10 => SynthSpec {
                h: 32,
                w: 32,
                c: 3,
                classes: 10,
                template_scale: 0.8,
                modes: 6,
                mode_scale: 0.55,
                noise: 0.35,
            },
            DatasetKind::SynthCifar100 => SynthSpec {
                h: 32,
                w: 32,
                c: 3,
                classes: 100,
                template_scale: 0.7,
                modes: 6,
                mode_scale: 0.5,
                noise: 0.35,
            },
            DatasetKind::TinyCorpus => {
                panic!("TinyCorpus is a text dataset; use data::corpus")
            }
        }
    }

    /// Flat feature count per sample.
    pub fn numel(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// A materialized labelled dataset (row-major `[n, h*w*c]` features).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature matrix, one sample per row (HWC flattened).
    pub x: Vec<f32>,
    /// Labels in `[0, classes)`.
    pub y: Vec<u32>,
    /// Per-sample feature count.
    pub features: usize,
    /// Number of classes.
    pub classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Sample `i`'s features.
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.features..(i + 1) * self.features]
    }

    /// Gather a subset by indices into a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.features);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.sample(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, features: self.features, classes: self.classes }
    }
}

/// Low-frequency spatial pattern: sum of a few random 2-D cosines. Smooth
/// structure compresses like natural images do (important: white-noise
/// templates would make conv gradients unnaturally high-rank).
fn smooth_pattern(spec: &SynthSpec, rng: &mut Pcg64) -> Vec<f32> {
    let n = spec.numel();
    let mut img = vec![0.0f32; n];
    let waves = 3;
    for _ in 0..waves {
        let fx = 0.5 + 2.5 * rng.f64(); // cycles across the image
        let fy = 0.5 + 2.5 * rng.f64();
        let phase_x = rng.f64() * std::f64::consts::TAU;
        let phase_y = rng.f64() * std::f64::consts::TAU;
        let amp = 0.4 + 0.6 * rng.f64();
        // Per-channel phase offset so channels decorrelate a little.
        let ch_phase: Vec<f64> = (0..spec.c).map(|_| rng.f64() * 1.0).collect();
        for hh in 0..spec.h {
            for ww in 0..spec.w {
                let vx = (fx * std::f64::consts::TAU * ww as f64 / spec.w as f64 + phase_x).cos();
                let vy = (fy * std::f64::consts::TAU * hh as f64 / spec.h as f64 + phase_y).cos();
                for cc in 0..spec.c {
                    let v = amp * vx * vy * (1.0 + 0.3 * ch_phase[cc]);
                    img[(hh * spec.w + ww) * spec.c + cc] += v as f32;
                }
            }
        }
    }
    img
}

/// Deterministic per-class generator state.
pub struct SynthGenerator {
    spec: SynthSpec,
    templates: Vec<Vec<f32>>,      // classes × numel
    modes: Vec<Vec<Vec<f32>>>,     // classes × modes × numel
}

impl SynthGenerator {
    /// Build class templates/modes from a seed. The same seed yields the
    /// same dataset family everywhere (clients, server, python tests).
    pub fn new(spec: SynthSpec, seed: u64) -> Self {
        let root = Pcg64::new(seed, 0xDA7A);
        let mut templates = Vec::with_capacity(spec.classes);
        let mut modes = Vec::with_capacity(spec.classes);
        for c in 0..spec.classes {
            let mut rc = root.fork(c as u64);
            let mut t = smooth_pattern(&spec, &mut rc);
            t.iter_mut().for_each(|v| *v *= spec.template_scale);
            templates.push(t);
            let mut class_modes = Vec::with_capacity(spec.modes);
            for _ in 0..spec.modes {
                class_modes.push(smooth_pattern(&spec, &mut rc));
            }
            modes.push(class_modes);
        }
        SynthGenerator { spec, templates, modes }
    }

    /// Dataset spec.
    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    /// Generate `n` labelled samples with uniformly-drawn labels.
    pub fn generate(&self, n: usize, rng: &mut Pcg64) -> Dataset {
        let labels: Vec<u32> = (0..n).map(|_| rng.index(self.spec.classes) as u32).collect();
        self.generate_with_labels(&labels, rng)
    }

    /// Generate one sample per provided label.
    pub fn generate_with_labels(&self, labels: &[u32], rng: &mut Pcg64) -> Dataset {
        let numel = self.spec.numel();
        let mut x = Vec::with_capacity(labels.len() * numel);
        for &label in labels {
            let c = label as usize;
            debug_assert!(c < self.spec.classes);
            let t = &self.templates[c];
            let weights: Vec<f32> =
                (0..self.spec.modes).map(|_| rng.normal() as f32 * self.spec.mode_scale).collect();
            for i in 0..numel {
                let mut v = t[i];
                for (j, w) in weights.iter().enumerate() {
                    v += w * self.modes[c][j][i];
                }
                v += self.spec.noise * rng.normal() as f32;
                x.push(v.clamp(-3.0, 3.0));
            }
        }
        Dataset { x, y: labels.to_vec(), features: numel, classes: self.spec.classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SynthSpec {
        SynthSpec::for_kind(DatasetKind::SynthMnist)
    }

    #[test]
    fn shapes_match_real_datasets() {
        assert_eq!(SynthSpec::for_kind(DatasetKind::SynthMnist).numel(), 28 * 28);
        assert_eq!(SynthSpec::for_kind(DatasetKind::SynthCifar10).numel(), 32 * 32 * 3);
        assert_eq!(SynthSpec::for_kind(DatasetKind::SynthCifar100).classes, 100);
    }

    #[test]
    fn deterministic_generation() {
        let g = SynthGenerator::new(spec(), 5);
        let a = g.generate(10, &mut Pcg64::seeded(1));
        let b = g.generate(10, &mut Pcg64::seeded(1));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_are_separated() {
        // Same-class samples must be closer (on average) than cross-class
        // samples — otherwise the dataset is unlearnable.
        let g = SynthGenerator::new(spec(), 7);
        let mut rng = Pcg64::seeded(2);
        let labels: Vec<u32> = (0..60).map(|i| (i % 3) as u32).collect();
        let d = g.generate_with_labels(&labels, &mut rng);
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>()
        };
        let (mut within, mut wn, mut across, mut an) = (0.0, 0, 0.0, 0);
        for i in 0..d.len() {
            for j in (i + 1)..d.len() {
                let dd = dist(d.sample(i), d.sample(j));
                if d.y[i] == d.y[j] {
                    within += dd;
                    wn += 1;
                } else {
                    across += dd;
                    an += 1;
                }
            }
        }
        assert!(within / (wn as f64) < across / (an as f64));
    }

    #[test]
    fn values_bounded() {
        let g = SynthGenerator::new(SynthSpec::for_kind(DatasetKind::SynthCifar10), 3);
        let d = g.generate(20, &mut Pcg64::seeded(3));
        assert!(d.x.iter().all(|&v| (-3.0..=3.0).contains(&v)));
    }

    #[test]
    fn subset_gathers() {
        let g = SynthGenerator::new(spec(), 11);
        let d = g.generate(10, &mut Pcg64::seeded(4));
        let s = d.subset(&[3, 7]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.sample(0), d.sample(3));
        assert_eq!(s.y[1], d.y[7]);
    }

    #[test]
    fn labels_cover_classes() {
        let g = SynthGenerator::new(spec(), 13);
        let d = g.generate(500, &mut Pcg64::seeded(5));
        let mut seen = vec![false; 10];
        for &y in &d.y {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
