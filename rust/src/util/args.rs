//! Minimal declarative CLI argument parser (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands; generates `--help` text from the declarations.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// A declarative argument parser for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    name: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
    positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl ArgSpec {
    /// New spec for a command called `name`.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        ArgSpec { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default.to_string()), is_flag: false });
        self
    }

    /// Declare `--name <value>` with no default (optional).
    pub fn opt_no_default(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true });
        self
    }

    /// Declare a required positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Render `--help` text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let d = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let v = if o.is_flag { String::new() } else { " <v>".to_string() };
            s.push_str(&format!("  --{}{v}  {}{d}\n", o.name, o.help));
        }
        s.push_str("  --help  print this message\n");
        s
    }

    /// Parse a token list. Returns `Err` with a message (or the help text)
    /// on malformed input / `--help`.
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?;
                if opt.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| format!("--{key} expects a value"))?,
                    };
                    out.values.insert(key, v);
                }
            } else {
                out.positionals.push(tok);
            }
        }
        if out.positionals.len() < self.positionals.len() {
            return Err(format!(
                "missing positional <{}>\n\n{}",
                self.positionals[out.positionals.len()].0,
                self.help_text()
            ));
        }
        Ok(out)
    }
}

impl Args {
    /// String value of `--key` (panics if undeclared and defaulted nowhere).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Required string value.
    pub fn str(&self, key: &str) -> &str {
        self.get(key).unwrap_or_else(|| panic!("missing --{key}"))
    }

    /// Parse `--key` as `T`.
    pub fn parse_as<T: std::str::FromStr>(&self, key: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(key).ok_or_else(|| format!("missing --{key}"))?;
        raw.parse::<T>().map_err(|e| format!("--{key}={raw}: {e}"))
    }

    /// `usize` convenience.
    pub fn usize(&self, key: &str) -> usize {
        self.parse_as(key).unwrap_or_else(|e| panic!("{e}"))
    }

    /// `f64` convenience.
    pub fn f64(&self, key: &str) -> f64 {
        self.parse_as(key).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Whether a flag was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("demo", "test command")
            .opt("rounds", "10", "number of rounds")
            .opt_no_default("seed", "rng seed")
            .flag("verbose", "chatty output")
            .positional("name", "experiment name")
    }

    fn sv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(sv(&["exp1"])).unwrap();
        assert_eq!(a.usize("rounds"), 10);
        assert_eq!(a.get("seed"), None);
        assert!(!a.has_flag("verbose"));
        assert_eq!(a.pos(0), Some("exp1"));
    }

    #[test]
    fn key_value_and_equals() {
        let a = spec().parse(sv(&["exp", "--rounds", "5", "--seed=99", "--verbose"])).unwrap();
        assert_eq!(a.usize("rounds"), 5);
        assert_eq!(a.str("seed"), "99");
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(sv(&["exp", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_positional_rejected() {
        assert!(spec().parse(sv(&[])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse(sv(&["exp", "--rounds"])).is_err());
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = spec().parse(sv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--rounds"));
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec().parse(sv(&["exp", "--verbose=1"])).is_err());
    }

    #[test]
    fn bad_parse_reported() {
        let a = spec().parse(sv(&["exp", "--rounds", "abc"])).unwrap();
        assert!(a.parse_as::<usize>("rounds").is_err());
    }
}
