//! Property-based testing mini-framework (offline stand-in for `proptest`).
//!
//! Provides seeded generators over the domains the crate's invariants live
//! in (matrix shapes, f32 vectors, layer configs) plus a [`check`] driver
//! with greedy shrinking on failure. Used by the L3 property tests on
//! coordinator/compressor invariants (routing, accounting, basis
//! orthogonality, codec round-trips).

use crate::util::rng::Pcg64;

/// A value generator: produces a case from RNG, and can shrink a failing
/// case toward smaller ones.
pub trait Gen {
    /// Generated value type.
    type Value: Clone + std::fmt::Debug;
    /// Draw one value.
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate smaller versions of `v` (tried in order). Default: none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` against `cases` generated values; on failure, shrink greedily
/// and panic with the minimal counterexample.
pub fn check<G: Gen>(name: &str, seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Pcg64::new(seed, stream_of(name));
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if prop(&v) {
            continue;
        }
        // Shrink.
        let mut minimal = v.clone();
        let mut improved = true;
        let mut steps = 0;
        while improved && steps < 1000 {
            improved = false;
            for cand in gen.shrink(&minimal) {
                steps += 1;
                if !prop(&cand) {
                    minimal = cand;
                    improved = true;
                    break;
                }
            }
        }
        panic!(
            "property '{name}' failed at case {case} (seed {seed}).\n\
             original: {v:?}\nminimal after {steps} shrink steps: {minimal:?}"
        );
    }
}

/// Tiny stable FNV-1a hash so each property gets its own RNG stream.
fn stream_of(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Integers in `[lo, hi]`, shrinking toward `lo`.
pub struct IntRange {
    /// Inclusive lower bound.
    pub lo: usize,
    /// Inclusive upper bound.
    pub hi: usize,
}

impl Gen for IntRange {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.lo + rng.index(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f32 vectors with length in `[min_len, max_len]`, entries ~ scale·N(0,1);
/// shrinks by halving length and zeroing entries.
pub struct VecF32 {
    /// Minimum length.
    pub min_len: usize,
    /// Maximum length.
    pub max_len: usize,
    /// Entry scale.
    pub scale: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let n = self.min_len + rng.index(self.max_len - self.min_len + 1);
        let mut v = rng.normal_vec(n);
        v.iter_mut().for_each(|x| *x *= self.scale);
        v
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            let mut z = v.clone();
            for x in z.iter_mut() {
                *x = 0.0;
            }
            out.push(z);
            // Zero just the first half: often isolates the offending entry.
            let mut hz = v.clone();
            for x in hz.iter_mut().take(v.len() / 2) {
                *x = 0.0;
            }
            out.push(hz);
        }
        out
    }
}

/// Pairs of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

/// Matrix-shape generator `(rows, cols)` with bounded area, shrinking both
/// dims; used heavily by linalg/compressor properties.
pub struct ShapeGen {
    /// Minimum of each dimension.
    pub min_dim: usize,
    /// Maximum of each dimension.
    pub max_dim: usize,
}

impl Gen for ShapeGen {
    type Value = (usize, usize);
    fn generate(&self, rng: &mut Pcg64) -> (usize, usize) {
        let r = IntRange { lo: self.min_dim, hi: self.max_dim };
        (r.generate(rng), r.generate(rng))
    }
    fn shrink(&self, v: &(usize, usize)) -> Vec<(usize, usize)> {
        let r = IntRange { lo: self.min_dim, hi: self.max_dim };
        let mut out = Vec::new();
        for a in r.shrink(&v.0) {
            out.push((a, v.1));
        }
        for b in r.shrink(&v.1) {
            out.push((v.0, b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check("ints_in_range", 1, 200, &IntRange { lo: 3, hi: 9 }, |&v| (3..=9).contains(&v));
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_panics_with_counterexample() {
        check("always_fails", 2, 10, &IntRange { lo: 0, hi: 100 }, |_| false);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property "v < 50" fails for v >= 50; minimal shrink should land at
        // exactly 50 via lo/midpoint/decrement moves. We capture the panic
        // message and check the minimal value.
        let res = std::panic::catch_unwind(|| {
            check("lt_50", 3, 500, &IntRange { lo: 0, hi: 1000 }, |&v| v < 50);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal"), "{msg}");
        // The minimal counterexample should be 50.
        assert!(msg.contains("steps: 50"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecF32 { min_len: 2, max_len: 5, scale: 1.0 };
        let mut rng = Pcg64::seeded(7);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((2..=5).contains(&v.len()));
        }
    }

    #[test]
    fn pair_shrinks_componentwise() {
        let g = Pair(IntRange { lo: 0, hi: 10 }, IntRange { lo: 0, hi: 10 });
        let shr = g.shrink(&(5, 7));
        assert!(shr.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shr.iter().any(|&(a, b)| a == 5 && b < 7));
    }
}
