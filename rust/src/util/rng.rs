//! Deterministic random number generation.
//!
//! A PCG64 (DXSM) generator plus the distributions the simulator needs:
//! uniform floats, standard normals (Box–Muller), gamma/Dirichlet (for
//! non-IID data partitioning), permutations and subset sampling.
//!
//! Everything in the repository that consumes randomness is seeded through
//! this type, so whole experiments are bit-reproducible from a single seed.
//! The same generator is re-implemented in `python/compile/synthdata.py`
//! (tests assert cross-language agreement on the first outputs).

/// PCG64-DXSM pseudo-random generator.
///
/// 128-bit state / 128-bit increment variant with the DXSM output mixer —
/// the same generator family NumPy uses by default. Deterministic,
/// splittable via [`Pcg64::fork`].
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.
    ///
    /// Distinct `(seed, stream)` pairs produce statistically independent
    /// sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (((stream as u128) << 1) | 1) ^ 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator; `tag` distinguishes children.
    ///
    /// Used to hand each simulated client / layer its own stream without
    /// coupling their sequences.
    pub fn fork(&self, tag: u64) -> Self {
        // Mix the parent's state into the child seed so forks of forks stay
        // decorrelated.
        let s = (self.state >> 64) as u64 ^ (self.state as u64);
        Self::new(s.wrapping_add(0x9e37_79b9_7f4a_7c15).wrapping_mul(tag | 1), tag)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // DXSM output function on the *pre-advance* state.
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi = hi.wrapping_mul(lo);
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        hi
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / 16777216.0)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection, unbiased).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (single value; pairs are not cached so
    /// the stream position stays easy to reason about cross-language).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// `n` standard-normal f32 samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Marsaglia–Tsang gamma sampler, `shape > 0`, unit scale.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Sample from `Dirichlet(alpha * 1_k)` — symmetric concentration.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            // Degenerate draw (all ~0): put mass on a random coordinate, the
            // limiting behaviour of Dirichlet as alpha -> 0.
            let i = self.index(k);
            v.iter_mut().for_each(|x| *x = 0.0);
            v[i] = 1.0;
            return v;
        }
        v.iter_mut().for_each(|x| *x /= s);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices out of `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_decorrelates() {
        let root = Pcg64::seeded(1);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg64::seeded(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            // each bucket ~10k; allow 5% tolerance
            assert!((9_500..10_500).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg64::seeded(13);
        for &shape in &[0.3, 1.0, 2.5, 10.0] {
            let n = 40_000;
            let m: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() / shape < 0.07, "shape={shape} mean={m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::seeded(17);
        for &a in &[0.1, 0.5, 5.0] {
            let v = r.dirichlet(a, 10);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(19);
        let s = r.sample_indices(50, 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
