//! General-purpose substrates the crate owns outright.
//!
//! The build environment is fully offline, so widely-used crates
//! (`rand`, `clap`, `criterion`, `proptest`, `rayon`) are unavailable;
//! this module provides the small, tested subsets we actually need:
//!
//! * [`rng`] — deterministic PCG64 RNG with Gaussian/Dirichlet sampling.
//! * [`args`] — a minimal declarative CLI argument parser.
//! * [`bench`] — a micro-benchmark harness (used by `cargo bench` targets).
//! * [`prop`] — a property-based testing mini-framework with shrinking.
//! * [`pool`] — a scoped worker pool over std threads.

pub mod args;
pub mod bench;
pub mod pool;
pub mod prop;
pub mod rng;
