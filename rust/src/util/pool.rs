//! Scoped data-parallel helpers over std threads (offline stand-in for
//! `rayon`).
//!
//! The FL simulator fans client work (local training, compression) across a
//! fixed worker count; [`parallel_map`] is the single primitive everything
//! uses. Work is chunked statically — client workloads are homogeneous, so
//! static chunking beats a work-stealing queue we would otherwise have to
//! build.

/// Map `f` over `items` using up to `workers` threads, preserving order.
///
/// Falls back to a plain sequential map when `workers <= 1` or the input is
/// tiny (threads cost more than they save).
pub fn parallel_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let workers = workers.min(n);
    // Pre-size the output; each worker writes disjoint slots.
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);

    // Hand each worker a contiguous (index, item) chunk.
    let mut indexed: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let chunk = n.div_ceil(workers);
    let out_slots = &mut out;

    std::thread::scope(|scope| {
        let mut remaining: &mut [Option<R>] = out_slots;
        let mut handled = 0usize;
        let mut chunks: Vec<(Vec<(usize, T)>, &mut [Option<R>])> = Vec::new();
        while !indexed.is_empty() {
            let take = chunk.min(indexed.len());
            let batch: Vec<(usize, T)> = indexed.drain(..take).collect();
            let (head, tail) = remaining.split_at_mut(take);
            remaining = tail;
            handled += take;
            chunks.push((batch, head));
        }
        debug_assert_eq!(handled, n);
        for (batch, slots) in chunks {
            let f = &f;
            scope.spawn(move || {
                for ((_, item), slot) in batch.into_iter().zip(slots.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });

    out.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

/// Number of workers to use by default: respects `GRADESTC_WORKERS`,
/// otherwise available parallelism (capped at 16).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("GRADESTC_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v: Vec<usize> = (0..1000).collect();
        let r = parallel_map(8, v, |x| x * 2);
        assert_eq!(r, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_fallback() {
        let r = parallel_map(1, vec![1, 2, 3], |x| x + 1);
        assert_eq!(r, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let r: Vec<i32> = parallel_map(4, Vec::<i32>::new(), |x| x);
        assert!(r.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let r = parallel_map(16, vec![5, 6], |x| x);
        assert_eq!(r, vec![5, 6]);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }
}
