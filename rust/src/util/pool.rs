//! Scoped data-parallel helpers over std threads (offline stand-in for
//! `rayon`).
//!
//! Two primitives back the round engine ([`crate::coordinator::engine`]):
//!
//! * [`parallel_map`] — fan a vector of independent work items across a
//!   fixed worker count, preserving input order. The engine's per-client
//!   phase runs one item per participant lane (local SGD → compress →
//!   decompress).
//! * [`chunked_reduce`] — run a reduction callback over disjoint fixed-size
//!   chunks of output slices (the engine's FedAvg accumulation). Chunk
//!   geometry depends only on the chunk length — never on the worker count —
//!   so a callback that is a pure function of `(slot, offset, chunk)` yields
//!   bit-identical results at every parallelism level.
//!
//! Work is chunked statically — client workloads are homogeneous, so static
//! chunking beats a work-stealing queue we would otherwise have to build.
//! The default worker count respects the `GRADESTC_WORKERS` environment
//! variable (see [`default_workers`]); per-run counts come from
//! `ExperimentConfig::workers`.

/// Map `f` over `items` using up to `workers` threads, preserving order.
///
/// Falls back to a plain sequential map when `workers <= 1` or the input is
/// tiny (threads cost more than they save).
pub fn parallel_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let workers = workers.min(n);
    // Pre-size the output; each worker writes disjoint slots.
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);

    // Hand each worker a contiguous (index, item) chunk.
    let mut indexed: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let chunk = n.div_ceil(workers);
    let out_slots = &mut out;

    std::thread::scope(|scope| {
        let mut remaining: &mut [Option<R>] = out_slots;
        let mut handled = 0usize;
        let mut chunks: Vec<(Vec<(usize, T)>, &mut [Option<R>])> = Vec::new();
        while !indexed.is_empty() {
            let take = chunk.min(indexed.len());
            let batch: Vec<(usize, T)> = indexed.drain(..take).collect();
            let (head, tail) = remaining.split_at_mut(take);
            remaining = tail;
            handled += take;
            chunks.push((batch, head));
        }
        debug_assert_eq!(handled, n);
        for (batch, slots) in chunks {
            let f = &f;
            scope.spawn(move || {
                for ((_, item), slot) in batch.into_iter().zip(slots.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });

    out.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

/// Deterministic chunked reduction over a set of mutable output slices.
///
/// Every slice in `outputs` is cut into `chunk_len`-element chunks and
/// `f(slot, offset, chunk)` runs once per chunk across up to `workers`
/// threads, where `slot` is the slice's index in `outputs` and `offset` the
/// chunk's starting element within that slice. Chunk boundaries depend only
/// on `chunk_len`, never on `workers`, so any `f` that is a pure function of
/// its arguments produces bit-identical output for every worker count — the
/// property the round engine's weighted FedAvg reduction relies on.
pub fn chunked_reduce<T, F>(workers: usize, outputs: Vec<&mut [T]>, chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunked_reduce: chunk_len must be positive");
    let mut units: Vec<(usize, usize, &mut [T])> = Vec::new();
    for (slot, slice) in outputs.into_iter().enumerate() {
        let mut offset = 0usize;
        for chunk in slice.chunks_mut(chunk_len) {
            let len = chunk.len();
            units.push((slot, offset, chunk));
            offset += len;
        }
    }
    parallel_map(workers, units, |(slot, offset, chunk)| f(slot, offset, chunk));
}

/// Number of workers to use by default: respects `GRADESTC_WORKERS`,
/// otherwise available parallelism (capped at 16).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("GRADESTC_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v: Vec<usize> = (0..1000).collect();
        let r = parallel_map(8, v, |x| x * 2);
        assert_eq!(r, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_fallback() {
        let r = parallel_map(1, vec![1, 2, 3], |x| x + 1);
        assert_eq!(r, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let r: Vec<i32> = parallel_map(4, Vec::<i32>::new(), |x| x);
        assert!(r.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let r = parallel_map(16, vec![5, 6], |x| x);
        assert_eq!(r, vec![5, 6]);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn chunked_reduce_covers_every_chunk_once() {
        let mut a = vec![0u32; 10];
        let mut b = vec![0u32; 3];
        chunked_reduce(4, vec![&mut a[..], &mut b[..]], 4, |slot, offset, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += (slot as u32) * 1000 + (offset + i) as u32 + 1;
            }
        });
        let expect_a: Vec<u32> = (0..10).map(|i| i + 1).collect();
        let expect_b: Vec<u32> = (0..3).map(|i| 1000 + i + 1).collect();
        assert_eq!(a, expect_a);
        assert_eq!(b, expect_b);
    }

    #[test]
    fn chunked_reduce_bitwise_stable_across_worker_counts() {
        // A float accumulation whose result depends on per-element add order:
        // identical chunk geometry must give identical bits for any workers.
        let terms: Vec<Vec<f32>> = (0..7)
            .map(|t| (0..100).map(|i| ((t * 31 + i) as f32).sin() * 1e-3).collect())
            .collect();
        let run = |workers: usize| -> Vec<u32> {
            let mut out = vec![0.0f32; 100];
            chunked_reduce(workers, vec![&mut out[..]], 16, |_slot, offset, chunk| {
                for term in &terms {
                    let src = &term[offset..offset + chunk.len()];
                    for (d, &v) in chunk.iter_mut().zip(src) {
                        *d += v;
                    }
                }
            });
            out.into_iter().map(f32::to_bits).collect()
        };
        let seq = run(1);
        assert_eq!(seq, run(2));
        assert_eq!(seq, run(8));
    }

    #[test]
    fn chunked_reduce_empty_slices_ok() {
        let mut a: Vec<f32> = Vec::new();
        chunked_reduce(4, vec![&mut a[..]], 8, |_, _, _| panic!("no chunks expected"));
        assert!(a.is_empty());
    }
}
