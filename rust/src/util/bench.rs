//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` declare `harness = false` and
//! drive this module directly. The harness does warmup, adaptive iteration
//! counts targeting a fixed measurement budget, and reports median /
//! mean ± stddev / min over sampled batches, plus optional throughput.
//!
//! Output is both human-readable and machine-parseable (one `BENCHLINE ...`
//! per benchmark), which the perf tooling in EXPERIMENTS.md consumes.

use std::time::{Duration, Instant};

/// One benchmark's aggregated statistics (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark id.
    pub name: String,
    /// Median ns/iter over samples.
    pub median_ns: f64,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Standard deviation of sample means.
    pub stddev_ns: f64,
    /// Fastest sample.
    pub min_ns: f64,
    /// Items/bytes processed per iteration, if declared (for throughput).
    pub throughput: Option<(f64, &'static str)>,
}

impl Stats {
    /// Human-readable single line.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{:<44} median {:>12}  mean {:>12} ± {:>10}  min {:>12}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
        );
        if let Some((amount, unit)) = self.throughput {
            let per_sec = amount / (self.median_ns * 1e-9);
            s.push_str(&format!("  {:>12}/s", fmt_qty(per_sec, unit)));
        }
        s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_qty(x: f64, unit: &str) -> String {
    if x >= 1e9 {
        format!("{:.2} G{unit}", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M{unit}", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} K{unit}", x / 1e3)
    } else {
        format!("{x:.1} {unit}")
    }
}

/// Benchmark runner for a suite of related benches.
pub struct Bencher {
    suite: String,
    warmup: Duration,
    measure: Duration,
    samples: usize,
    results: Vec<Stats>,
}

impl Bencher {
    /// New suite. Budgets default to 0.5 s warmup + 1.5 s measurement per
    /// bench, 12 samples; override with [`Bencher::budget`].
    pub fn new(suite: &str) -> Self {
        // Honor a quick mode for CI-ish runs: GRADESTC_BENCH_FAST=1
        let fast = std::env::var("GRADESTC_BENCH_FAST").ok().as_deref() == Some("1");
        Bencher {
            suite: suite.to_string(),
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_millis(1500) },
            samples: if fast { 5 } else { 12 },
            results: Vec::new(),
        }
    }

    /// Override time budgets.
    pub fn budget(mut self, warmup: Duration, measure: Duration, samples: usize) -> Self {
        self.warmup = warmup;
        self.measure = measure;
        self.samples = samples.max(3);
        self
    }

    /// Run one benchmark: `f` is called repeatedly; it should perform one
    /// unit of work and return a value (use [`std::hint::black_box`] inside
    /// if needed — the harness black-boxes the return).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Stats {
        self.bench_with_throughput(name, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Like [`Bencher::bench`] but annotates items-per-iteration for
    /// throughput reporting, e.g. `Some((bytes as f64, "B"))`.
    pub fn bench_with_throughput(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        mut f: impl FnMut(),
    ) -> &Stats {
        // Warmup & calibration: find iters/sample so one sample ~ measure/samples.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let sample_budget = self.measure.as_secs_f64() / self.samples as f64;
        let iters = ((sample_budget / per_iter).ceil() as u64).max(1);

        let mut sample_means: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_means.push(s.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        sample_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sample_means[sample_means.len() / 2];
        let mean = sample_means.iter().sum::<f64>() / sample_means.len() as f64;
        let var = sample_means.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / sample_means.len() as f64;
        let stats = Stats {
            name: format!("{}/{}", self.suite, name),
            median_ns: median,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            min_ns: sample_means[0],
            throughput,
        };
        println!("{}", stats.render());
        println!(
            "BENCHLINE {} median_ns={:.1} mean_ns={:.1} stddev_ns={:.1} min_ns={:.1}",
            stats.name, stats.median_ns, stats.mean_ns, stats.stddev_ns, stats.min_ns
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Serialize the suite's results as the `BENCH_<suite>.json`
    /// trajectory format (hand-rolled — the hermetic build has no serde).
    /// `extra` is spliced verbatim after the benches array for
    /// suite-specific sections (e.g. the scale bench's `"memory"` object);
    /// pass `""` for none, otherwise start it with `,\n  `.
    pub fn to_json(&self, extra: &str) -> String {
        let mut json = format!("{{\n  \"suite\": \"{}\",\n  \"benches\": [\n", self.suite);
        for (i, s) in self.results.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \
                 \"stddev_ns\": {:.1}, \"min_ns\": {:.1}}}{}\n",
                s.name,
                s.median_ns,
                s.mean_ns,
                s.stddev_ns,
                s.min_ns,
                if i + 1 == self.results.len() { "" } else { "," }
            ));
        }
        json.push_str("  ]");
        json.push_str(extra);
        json.push_str("\n}\n");
        json
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let mut b = Bencher::new("t").budget(
            Duration::from_millis(10),
            Duration::from_millis(30),
            4,
        );
        let s = b.bench("noop_sum", || (0..100u64).sum::<u64>()).clone();
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
    }

    #[test]
    fn throughput_rendered() {
        let mut b = Bencher::new("t").budget(
            Duration::from_millis(5),
            Duration::from_millis(20),
            3,
        );
        let s = b
            .bench_with_throughput("copy", Some((1024.0, "B")), || {
                let v = vec![0u8; 1024];
                std::hint::black_box(v);
            })
            .clone();
        assert!(s.render().contains("/s"));
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_qty(2e9, "B").contains("G"));
    }
}
