//! Differentiable ops for the native trainer.
//!
//! Each [`Op`] caches what its backward needs during `forward`. Parameter
//! gradients accumulate into a [`ParamStore`] aligned with the model's
//! layer table. Conv runs as im2col + the crate's blocked GEMM, matching
//! XLA's NHWC/HWIO semantics (including its SAME-padding rule).

use crate::linalg::{matmul, matmul_a_bt, matmul_at_b, Mat};
use crate::model::params::ParamStore;

use super::tensor::Tensor;

/// Padding mode, matching XLA's conv semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    /// No padding; output shrinks by `k-1`.
    Valid,
    /// Output = ceil(input/stride); zero padding split before/after.
    Same,
}

fn out_dim(input: usize, k: usize, stride: usize, padding: Padding) -> (usize, usize) {
    // Returns (output size, pad_before).
    match padding {
        Padding::Valid => ((input - k) / stride + 1, 0),
        Padding::Same => {
            let out = input.div_ceil(stride);
            let pad_total = ((out - 1) * stride + k).saturating_sub(input);
            (out, pad_total / 2)
        }
    }
}

/// im2col: NHWC input → `[B·OH·OW, kh·kw·C]` patch matrix.
fn im2col(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    padding: Padding,
) -> (Mat, usize, usize) {
    let (b, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (oh, ph) = out_dim(h, kh, stride, padding);
    let (ow, pw) = out_dim(w, kw, stride, padding);
    let mut cols = Mat::zeros(b * oh * ow, kh * kw * c);
    for bi in 0..b {
        let xoff = bi * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                let dst = cols.row_mut(row);
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        let doff = (ky * kw + kx) * c;
                        dst[doff..doff + c].copy_from_slice(&x.data[src..src + c]);
                    }
                }
            }
        }
    }
    (cols, oh, ow)
}

/// col2im: scatter-add patch-gradients back to input layout.
fn col2im(
    dcols: &Mat,
    dims: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    padding: Padding,
) -> Tensor {
    let (b, h, w, c) = (dims[0], dims[1], dims[2], dims[3]);
    let (oh, ph) = out_dim(h, kh, stride, padding);
    let (ow, pw) = out_dim(w, kw, stride, padding);
    let mut dx = Tensor::zeros(dims.to_vec());
    for bi in 0..b {
        let xoff = bi * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                let src_row = dcols.row(row);
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = xoff + ((iy as usize) * w + ix as usize) * c;
                        let soff = (ky * kw + kx) * c;
                        for ci in 0..c {
                            dx.data[dst + ci] += src_row[soff + ci];
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Mean softmax cross-entropy over the batch; returns (loss, dlogits).
pub fn softmax_xent_mean(logits: &Tensor, labels: &[u32]) -> (f64, Tensor) {
    let b = logits.dims[0];
    let k = logits.dims[1];
    assert_eq!(labels.len(), b);
    let mut dlogits = Tensor::zeros(logits.dims.clone());
    let mut loss = 0.0f64;
    for bi in 0..b {
        let row = &logits.data[bi * k..(bi + 1) * k];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = row.iter().map(|&x| ((x - maxv) as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        let label = labels[bi] as usize;
        loss += z.ln() - (row[label] - maxv) as f64;
        let drow = &mut dlogits.data[bi * k..(bi + 1) * k];
        for (j, e) in exps.iter().enumerate() {
            drow[j] = ((e / z) as f32 - if j == label { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    (loss / b as f64, dlogits)
}

/// A differentiable operation with cached state.
pub trait Op {
    /// Forward; may cache activations for backward.
    fn forward(&mut self, params: &ParamStore, x: Tensor) -> Tensor;
    /// Backward: gradient w.r.t. input; parameter grads accumulate.
    fn backward(&mut self, params: &ParamStore, grads: &mut ParamStore, dy: Tensor) -> Tensor;
}

/// 2-D convolution (+bias).
pub struct Conv {
    /// Weight tensor index (HWIO).
    pub w_idx: usize,
    /// Bias tensor index.
    pub b_idx: usize,
    /// Kernel height/width, in/out channels.
    pub kdims: (usize, usize, usize, usize),
    /// Stride.
    pub stride: usize,
    /// Padding mode.
    pub padding: Padding,
    cache: Option<(Mat, Vec<usize>, usize, usize)>, // cols, x dims, oh, ow
}

impl Conv {
    /// New conv op.
    pub fn new(
        w_idx: usize,
        b_idx: usize,
        kdims: (usize, usize, usize, usize),
        stride: usize,
        padding: Padding,
    ) -> Self {
        Conv { w_idx, b_idx, kdims, stride, padding, cache: None }
    }
}

impl Op for Conv {
    fn forward(&mut self, params: &ParamStore, x: Tensor) -> Tensor {
        let (kh, kw, ci, co) = self.kdims;
        debug_assert_eq!(x.dims[3], ci, "conv input channels");
        let (cols, oh, ow) = im2col(&x, kh, kw, self.stride, self.padding);
        let wmat = Mat::from_vec(kh * kw * ci, co, params.tensor(self.w_idx).to_vec());
        let mut y = matmul(&cols, &wmat);
        let bias = params.tensor(self.b_idx);
        for r in 0..y.rows() {
            for (v, &bv) in y.row_mut(r).iter_mut().zip(bias) {
                *v += bv;
            }
        }
        let b = x.dims[0];
        let out = Tensor::new(y.into_vec(), vec![b, oh, ow, co]);
        self.cache = Some((cols, x.dims.clone(), oh, ow));
        out
    }

    fn backward(&mut self, params: &ParamStore, grads: &mut ParamStore, dy: Tensor) -> Tensor {
        let (kh, kw, ci, co) = self.kdims;
        let (cols, xdims, oh, ow) = self.cache.take().expect("forward before backward");
        let b = xdims[0];
        let dy_mat = Mat::from_vec(b * oh * ow, co, dy.data);
        // db = Σ rows of dY.
        {
            let db = grads.tensor_mut(self.b_idx);
            for r in 0..dy_mat.rows() {
                for (d, &v) in db.iter_mut().zip(dy_mat.row(r)) {
                    *d += v;
                }
            }
        }
        // dW = colsᵀ · dY.
        let dw = matmul_at_b(&cols, &dy_mat);
        {
            let gw = grads.tensor_mut(self.w_idx);
            for (g, &v) in gw.iter_mut().zip(dw.as_slice()) {
                *g += v;
            }
        }
        // dX = col2im(dY · Wᵀ).
        let wmat = Mat::from_vec(kh * kw * ci, co, params.tensor(self.w_idx).to_vec());
        let dcols = matmul_a_bt(&dy_mat, &wmat);
        col2im(&dcols, &xdims, kh, kw, self.stride, self.padding)
    }
}

/// ReLU.
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    /// New ReLU.
    pub fn new() -> Self {
        Relu { mask: Vec::new() }
    }
}

impl Default for Relu {
    fn default() -> Self {
        Self::new()
    }
}

impl Op for Relu {
    fn forward(&mut self, _p: &ParamStore, mut x: Tensor) -> Tensor {
        self.mask = x.data.iter().map(|&v| v > 0.0).collect();
        for v in &mut x.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        x
    }

    fn backward(&mut self, _p: &ParamStore, _g: &mut ParamStore, mut dy: Tensor) -> Tensor {
        for (d, &m) in dy.data.iter_mut().zip(&self.mask) {
            if !m {
                *d = 0.0;
            }
        }
        dy
    }
}

/// 2×2 average pooling, stride 2 (VALID).
pub struct AvgPool2 {
    in_dims: Vec<usize>,
}

impl AvgPool2 {
    /// New pool op.
    pub fn new() -> Self {
        AvgPool2 { in_dims: Vec::new() }
    }
}

impl Default for AvgPool2 {
    fn default() -> Self {
        Self::new()
    }
}

impl Op for AvgPool2 {
    fn forward(&mut self, _p: &ParamStore, x: Tensor) -> Tensor {
        let (b, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut y = Tensor::zeros(vec![b, oh, ow, c]);
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ci in 0..c {
                        let mut s = 0.0f32;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                s += x.data
                                    [((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ci];
                            }
                        }
                        y.data[((bi * oh + oy) * ow + ox) * c + ci] = s / 4.0;
                    }
                }
            }
        }
        self.in_dims = x.dims.clone();
        y
    }

    fn backward(&mut self, _p: &ParamStore, _g: &mut ParamStore, dy: Tensor) -> Tensor {
        let (b, h, w, c) =
            (self.in_dims[0], self.in_dims[1], self.in_dims[2], self.in_dims[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut dx = Tensor::zeros(self.in_dims.clone());
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ci in 0..c {
                        let g = dy.data[((bi * oh + oy) * ow + ox) * c + ci] / 4.0;
                        for ddy in 0..2 {
                            for ddx in 0..2 {
                                dx.data[((bi * h + oy * 2 + ddy) * w + ox * 2 + ddx) * c
                                    + ci] += g;
                            }
                        }
                    }
                }
            }
        }
        dx
    }
}

/// Global mean pool over H and W: `[B,H,W,C] → [B,C]`.
pub struct GlobalMeanPool {
    in_dims: Vec<usize>,
}

impl GlobalMeanPool {
    /// New op.
    pub fn new() -> Self {
        GlobalMeanPool { in_dims: Vec::new() }
    }
}

impl Default for GlobalMeanPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Op for GlobalMeanPool {
    fn forward(&mut self, _p: &ParamStore, x: Tensor) -> Tensor {
        let (b, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
        let mut y = Tensor::zeros(vec![b, c]);
        let scale = 1.0 / (h * w) as f32;
        for bi in 0..b {
            for p in 0..h * w {
                for ci in 0..c {
                    y.data[bi * c + ci] += x.data[(bi * h * w + p) * c + ci] * scale;
                }
            }
        }
        self.in_dims = x.dims.clone();
        y
    }

    fn backward(&mut self, _p: &ParamStore, _g: &mut ParamStore, dy: Tensor) -> Tensor {
        let (b, h, w, c) =
            (self.in_dims[0], self.in_dims[1], self.in_dims[2], self.in_dims[3]);
        let mut dx = Tensor::zeros(self.in_dims.clone());
        let scale = 1.0 / (h * w) as f32;
        for bi in 0..b {
            for p in 0..h * w {
                for ci in 0..c {
                    dx.data[(bi * h * w + p) * c + ci] = dy.data[bi * c + ci] * scale;
                }
            }
        }
        dx
    }
}

/// Flatten `[B, ...] → [B, F]` (NHWC row-major — matches jnp reshape).
pub struct Flatten {
    in_dims: Vec<usize>,
}

impl Flatten {
    /// New op.
    pub fn new() -> Self {
        Flatten { in_dims: Vec::new() }
    }
}

impl Default for Flatten {
    fn default() -> Self {
        Self::new()
    }
}

impl Op for Flatten {
    fn forward(&mut self, _p: &ParamStore, x: Tensor) -> Tensor {
        self.in_dims = x.dims.clone();
        let b = x.dims[0];
        let f = x.numel() / b;
        x.reshape(vec![b, f])
    }

    fn backward(&mut self, _p: &ParamStore, _g: &mut ParamStore, dy: Tensor) -> Tensor {
        dy.reshape(self.in_dims.clone())
    }
}

/// Dense layer: `y = x·W + b`.
pub struct Dense {
    /// Weight tensor index (`[in, out]`).
    pub w_idx: usize,
    /// Bias tensor index.
    pub b_idx: usize,
    /// (in, out).
    pub dims: (usize, usize),
    cache_x: Option<Mat>,
}

impl Dense {
    /// New dense op.
    pub fn new(w_idx: usize, b_idx: usize, dims: (usize, usize)) -> Self {
        Dense { w_idx, b_idx, dims, cache_x: None }
    }
}

impl Op for Dense {
    fn forward(&mut self, params: &ParamStore, x: Tensor) -> Tensor {
        let (din, dout) = self.dims;
        let b = x.dims[0];
        debug_assert_eq!(x.dims[1], din);
        let xm = Mat::from_vec(b, din, x.data);
        let w = Mat::from_vec(din, dout, params.tensor(self.w_idx).to_vec());
        let mut y = matmul(&xm, &w);
        let bias = params.tensor(self.b_idx);
        for r in 0..b {
            for (v, &bv) in y.row_mut(r).iter_mut().zip(bias) {
                *v += bv;
            }
        }
        self.cache_x = Some(xm);
        Tensor::new(y.into_vec(), vec![b, dout])
    }

    fn backward(&mut self, params: &ParamStore, grads: &mut ParamStore, dy: Tensor) -> Tensor {
        let (din, dout) = self.dims;
        let b = dy.dims[0];
        let dym = Mat::from_vec(b, dout, dy.data);
        let xm = self.cache_x.take().expect("forward before backward");
        {
            let db = grads.tensor_mut(self.b_idx);
            for r in 0..b {
                for (d, &v) in db.iter_mut().zip(dym.row(r)) {
                    *d += v;
                }
            }
        }
        let dw = matmul_at_b(&xm, &dym);
        {
            let gw = grads.tensor_mut(self.w_idx);
            for (g, &v) in gw.iter_mut().zip(dw.as_slice()) {
                *g += v;
            }
        }
        let w = Mat::from_vec(din, dout, params.tensor(self.w_idx).to_vec());
        let dx = matmul_a_bt(&dym, &w);
        Tensor::new(dx.into_vec(), vec![b, din])
    }
}

/// Residual block: `y = relu(x + inner(x))` where `inner` is an op stack.
pub struct Residual {
    /// Inner op stack.
    pub inner: Vec<Box<dyn Op>>,
    mask: Vec<bool>,
}

impl Residual {
    /// New residual block.
    pub fn new(inner: Vec<Box<dyn Op>>) -> Self {
        Residual { inner, mask: Vec::new() }
    }
}

impl Op for Residual {
    fn forward(&mut self, params: &ParamStore, x: Tensor) -> Tensor {
        let mut h = x.clone();
        for op in self.inner.iter_mut() {
            h = op.forward(params, h);
        }
        debug_assert_eq!(h.dims, x.dims);
        let mut y = x;
        for (v, &hv) in y.data.iter_mut().zip(&h.data) {
            *v += hv;
        }
        self.mask = y.data.iter().map(|&v| v > 0.0).collect();
        for v in &mut y.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        y
    }

    fn backward(&mut self, params: &ParamStore, grads: &mut ParamStore, mut dy: Tensor) -> Tensor {
        for (d, &m) in dy.data.iter_mut().zip(&self.mask) {
            if !m {
                *d = 0.0;
            }
        }
        // d(inner path)
        let mut dinner = dy.clone();
        for op in self.inner.iter_mut().rev() {
            dinner = op.backward(params, grads, dinner);
        }
        // dx = skip + inner
        for (d, &v) in dy.data.iter_mut().zip(&dinner.data) {
            *d += v;
        }
        dy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::model::meta::layer_table;
    use crate::util::rng::Pcg64;

    /// Finite-difference gradient check for a single-op "model".
    fn grad_check_conv(stride: usize, padding: Padding) {
        let meta = layer_table(ModelKind::LeNet5); // store shape donor
        let mut rng = Pcg64::seeded(7);
        // Tiny conv: 3x3x2x3 on a 2x5x5x2 input.
        let (kh, kw, ci, co) = (3, 3, 2, 3);
        let mut params = ParamStore::zeros_like(&meta);
        // Hijack tensors 0 (conv1.kernel 150) and 1 (bias 6): big enough.
        let w: Vec<f32> = rng.normal_vec(kh * kw * ci * co);
        let bias: Vec<f32> = rng.normal_vec(co);
        params.tensor_mut(0)[..w.len()].copy_from_slice(&w);
        params.tensor_mut(1)[..co].copy_from_slice(&bias);
        // But Conv reads the whole tensor — build a dedicated tiny store
        // instead via from_tensors on a fake meta. Simpler: craft Mat-sized
        // vectors directly in a 2-tensor store.
        let fake_meta = crate::model::meta::ModelMeta {
            name: "t",
            layers: vec![
                crate::model::meta::LayerMeta {
                    name: "w".into(),
                    shape: vec![kh, kw, ci, co],
                    role: crate::model::meta::LayerRole::ConvKernel,
                },
                crate::model::meta::LayerMeta {
                    name: "b".into(),
                    shape: vec![co],
                    role: crate::model::meta::LayerRole::Bias,
                },
            ],
            input_shape: vec![5, 5, ci],
            num_classes: 2,
        };
        let mut p = ParamStore::from_tensors(&fake_meta, vec![w, bias]);
        let x = Tensor::new(rng.normal_vec(2 * 5 * 5 * ci), vec![2, 5, 5, ci]);
        // Loss = sum(conv(x)^2)/2 → dY = Y.
        let mut conv = Conv::new(0, 1, (kh, kw, ci, co), stride, padding);
        let y = conv.forward(&p, x.clone());
        let mut grads = ParamStore::zeros_like(&fake_meta);
        let dy = y.clone();
        let dx = conv.backward(&p, &mut grads, dy);

        // FD check on a few weight coords.
        let eps = 1e-3f32;
        let loss = |p: &ParamStore, x: &Tensor| -> f64 {
            let mut c = Conv::new(0, 1, (kh, kw, ci, co), stride, padding);
            let y = c.forward(p, x.clone());
            y.data.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
        };
        for &ci2 in &[0usize, 7, 23, 51] {
            let orig = p.tensor(0)[ci2];
            p.tensor_mut(0)[ci2] = orig + eps;
            let lp = loss(&p, &x);
            p.tensor_mut(0)[ci2] = orig - eps;
            let lm = loss(&p, &x);
            p.tensor_mut(0)[ci2] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grads.tensor(0)[ci2] as f64;
            assert!(
                (fd - an).abs() < 0.02 * (1.0 + fd.abs()),
                "w[{ci2}] fd {fd} vs an {an} (stride {stride}, {padding:?})"
            );
        }
        // FD check on input coords.
        for &xi in &[0usize, 13, 49] {
            let mut xp = x.clone();
            xp.data[xi] += eps;
            let lp = loss(&p, &xp);
            xp.data[xi] = x.data[xi] - eps;
            let lm = loss(&p, &xp);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = dx.data[xi] as f64;
            assert!(
                (fd - an).abs() < 0.02 * (1.0 + fd.abs()),
                "x[{xi}] fd {fd} vs an {an}"
            );
        }
    }

    #[test]
    fn conv_gradients_valid() {
        grad_check_conv(1, Padding::Valid);
    }

    #[test]
    fn conv_gradients_same() {
        grad_check_conv(1, Padding::Same);
    }

    #[test]
    fn conv_gradients_strided_same() {
        grad_check_conv(2, Padding::Same);
    }

    #[test]
    fn softmax_xent_matches_fd() {
        let mut rng = Pcg64::seeded(3);
        let logits = Tensor::new(rng.normal_vec(4 * 5), vec![4, 5]);
        let labels = vec![0u32, 3, 2, 4];
        let (l0, d) = softmax_xent_mean(&logits, &labels);
        assert!(l0 > 0.0);
        let eps = 1e-3f32;
        for &i in &[0usize, 7, 19] {
            let mut lp = logits.clone();
            lp.data[i] += eps;
            let (l1, _) = softmax_xent_mean(&lp, &labels);
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - d.data[i] as f64).abs() < 1e-2,
                "fd {fd} vs {}",
                d.data[i]
            );
        }
    }

    #[test]
    fn avgpool_preserves_mean_and_grads() {
        let mut rng = Pcg64::seeded(5);
        let x = Tensor::new(rng.normal_vec(1 * 4 * 4 * 2), vec![1, 4, 4, 2]);
        let mut pool = AvgPool2::new();
        let meta = layer_table(ModelKind::LeNet5);
        let p = ParamStore::zeros_like(&meta);
        let y = pool.forward(&p, x.clone());
        assert_eq!(y.dims, vec![1, 2, 2, 2]);
        let xmean: f32 = x.data.iter().sum::<f32>() / x.numel() as f32;
        let ymean: f32 = y.data.iter().sum::<f32>() / y.numel() as f32;
        assert!((xmean - ymean).abs() < 1e-5);
        // Backward of ones: every input gets 1/4.
        let mut g = ParamStore::zeros_like(&meta);
        let dy = Tensor::new(vec![1.0; 8], vec![1, 2, 2, 2]);
        let dx = pool.backward(&p, &mut g, dy);
        assert!(dx.data.iter().all(|&v| (v - 0.25).abs() < 1e-6));
    }

    #[test]
    fn same_padding_matches_xla_rule() {
        // H=5, k=3, s=2 → out=3, pad_total = (3-1)*2+3-5 = 2, before=1.
        assert_eq!(out_dim(5, 3, 2, Padding::Same), (3, 1));
        // H=32, k=3, s=1 → out=32, pad 1 before.
        assert_eq!(out_dim(32, 3, 1, Padding::Same), (32, 1));
        // Valid: H=28, k=5 → 24.
        assert_eq!(out_dim(28, 5, 1, Padding::Valid), (24, 0));
    }

    #[test]
    fn residual_identity_when_inner_zero() {
        // Inner conv with zero weights → y = relu(x).
        let fake_meta = crate::model::meta::ModelMeta {
            name: "t",
            layers: vec![
                crate::model::meta::LayerMeta {
                    name: "w".into(),
                    shape: vec![3, 3, 2, 2],
                    role: crate::model::meta::LayerRole::ConvKernel,
                },
                crate::model::meta::LayerMeta {
                    name: "b".into(),
                    shape: vec![2],
                    role: crate::model::meta::LayerRole::Bias,
                },
            ],
            input_shape: vec![4, 4, 2],
            num_classes: 2,
        };
        let p = ParamStore::zeros_like(&fake_meta);
        let mut rng = Pcg64::seeded(9);
        let x = Tensor::new(rng.normal_vec(32), vec![1, 4, 4, 2]);
        let mut res = Residual::new(vec![Box::new(Conv::new(
            0,
            1,
            (3, 3, 2, 2),
            1,
            Padding::Same,
        ))]);
        let y = res.forward(&p, x.clone());
        for (yv, xv) in y.data.iter().zip(&x.data) {
            assert_eq!(*yv, xv.max(0.0));
        }
    }
}
