//! From-scratch neural-network substrate (native training backend).
//!
//! A minimal define-by-stack framework: a model is a list of [`ops::Op`]s
//! holding parameter indices into the shared [`ParamStore`]; forward caches
//! what backward needs; backward walks the stack in reverse, writing
//! parameter gradients into a gradient store. Conv layers run as im2col +
//! the crate's blocked GEMM.
//!
//! Semantics are kept *identical* to the JAX L2 graphs (NHWC data, HWIO
//! kernels, `x @ W + b` dense, valid/same padding, avg-pooling, softmax
//! cross-entropy), so the two backends are interchangeable and
//! cross-checked: per-op gradients against finite differences here, and
//! whole-model agreement against the XLA artifacts in
//! `rust/tests/xla_runtime.rs`.

pub mod builder;
pub mod ops;
pub mod tensor;

pub use builder::build_model;
pub use tensor::Tensor;

use anyhow::{anyhow, Result};

use crate::config::ModelKind;
use crate::coordinator::trainer::{epoch_batches, Trainer};
use crate::data::synth::Dataset;
use crate::model::meta::ModelMeta;
use crate::model::params::ParamStore;
use crate::util::rng::Pcg64;

/// Native Rust trainer implementing the same step semantics as the XLA
/// artifacts.
///
/// Holds only immutable architecture metadata, so it is `Sync` and the
/// round engine shares one instance by `&self` across worker threads
/// (every mutable buffer is allocated per `local_train` call) — this is
/// the [`crate::coordinator::trainer::ParallelTrainer`] backend.
pub struct NativeTrainer {
    kind: ModelKind,
    meta: ModelMeta,
}

impl NativeTrainer {
    /// Build for a model kind. The transformer is XLA-only (its native
    /// backward is out of scope — see DESIGN.md §6).
    pub fn new(kind: ModelKind, meta: &ModelMeta) -> Result<Self> {
        if matches!(kind, ModelKind::TinyTransformer) {
            return Err(anyhow!(
                "TinyTransformer requires the XLA backend (use_xla = true)"
            ));
        }
        Ok(NativeTrainer { kind, meta: meta.clone() })
    }

    fn batch_tensors(&self, data: &Dataset, idx: &[usize]) -> (Tensor, Vec<u32>) {
        let (h, w, c) = (
            self.meta.input_shape[0],
            self.meta.input_shape[1],
            self.meta.input_shape[2],
        );
        let mut x = Vec::with_capacity(idx.len() * h * w * c);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(data.sample(i));
            y.push(data.y[i]);
        }
        (Tensor::new(x, vec![idx.len(), h, w, c]), y)
    }

    /// One forward/backward pass; returns (loss, grads).
    pub fn loss_and_grads(
        &self,
        params: &ParamStore,
        x: Tensor,
        y: &[u32],
    ) -> (f64, ParamStore) {
        let mut model = build_model(self.kind, &self.meta);
        let mut grads = ParamStore::zeros_like(&self.meta);
        let logits = {
            let mut h = x;
            for op in model.iter_mut() {
                h = op.forward(params, h);
            }
            h
        };
        let (loss, dlogits) = ops::softmax_xent_mean(&logits, y);
        let mut dy = dlogits;
        for op in model.iter_mut().rev() {
            dy = op.backward(params, &mut grads, dy);
        }
        (loss, grads)
    }

    fn forward_logits(&self, params: &ParamStore, x: Tensor) -> Tensor {
        let mut model = build_model(self.kind, &self.meta);
        let mut h = x;
        for op in model.iter_mut() {
            h = op.forward(params, h);
        }
        h
    }
}

impl Trainer for NativeTrainer {
    fn local_train(
        &self,
        start: &ParamStore,
        data: &Dataset,
        epochs: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg64,
    ) -> Result<(ParamStore, f64)> {
        let mut params = start.clone();
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        for _ in 0..epochs {
            for idx in epoch_batches(data.len(), batch, rng) {
                let (x, y) = self.batch_tensors(data, &idx);
                let (loss, grads) = self.loss_and_grads(&params, x, &y);
                params.axpy(-lr, &grads);
                loss_sum += loss;
                steps += 1;
            }
        }
        Ok((params, loss_sum / steps.max(1) as f64))
    }

    fn evaluate(&self, params: &ParamStore, data: &Dataset) -> Result<(f64, f64)> {
        // Evaluate in chunks to bound memory.
        let chunk = 64usize;
        let mut correct = 0usize;
        let mut loss_sum = 0.0f64;
        let mut seen = 0usize;
        let mut i = 0;
        while i < data.len() {
            let j = (i + chunk).min(data.len());
            let idx: Vec<usize> = (i..j).collect();
            let (x, y) = self.batch_tensors(data, &idx);
            let logits = self.forward_logits(params, x);
            let classes = logits.dims[1];
            for (bi, &label) in y.iter().enumerate() {
                let row = &logits.data[bi * classes..(bi + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                if pred == label as usize {
                    correct += 1;
                }
            }
            let (l, _) = ops::softmax_xent_mean(&logits, &y);
            loss_sum += l * (j - i) as f64;
            seen += j - i;
            i = j;
        }
        Ok((loss_sum / seen.max(1) as f64, correct as f64 / seen.max(1) as f64))
    }

    fn grads(
        &self,
        params: &ParamStore,
        data: &Dataset,
        batch: usize,
        rng: &mut Pcg64,
    ) -> Result<(Vec<Vec<f32>>, f64)> {
        let idx = epoch_batches(data.len(), batch, rng)
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("empty dataset"))?;
        let (x, y) = self.batch_tensors(data, &idx);
        let (loss, grads) = self.loss_and_grads(params, x, &y);
        let tensors = (0..grads.len()).map(|i| grads.tensor(i).to_vec()).collect();
        Ok((tensors, loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthGenerator, SynthSpec};
    use crate::model::meta::layer_table;

    #[test]
    fn lenet_trains_on_synth_mnist() {
        let meta = layer_table(ModelKind::LeNet5);
        let t = NativeTrainer::new(ModelKind::LeNet5, &meta).unwrap();
        let spec = SynthSpec::for_kind(crate::config::DatasetKind::SynthMnist);
        let gen = SynthGenerator::new(spec, 3);
        let mut rng = Pcg64::seeded(1);
        let train = gen.generate(256, &mut rng);
        let test = gen.generate(128, &mut rng);
        let params = ParamStore::init(&meta, &Pcg64::seeded(5));
        let (acc0_loss, acc0) = t.evaluate(&params, &test).unwrap();
        let mut p = params;
        let mut last_loss = f64::INFINITY;
        for _ in 0..3 {
            let (np, loss) = t.local_train(&p, &train, 1, 32, 0.05, &mut rng).unwrap();
            p = np;
            last_loss = loss;
        }
        let (loss1, acc1) = t.evaluate(&p, &test).unwrap();
        assert!(
            acc1 > acc0 + 0.1,
            "accuracy did not improve: {acc0} -> {acc1} (loss {acc0_loss} -> {loss1}, train {last_loss})"
        );
    }

    #[test]
    fn transformer_rejected() {
        let meta = layer_table(ModelKind::TinyTransformer);
        assert!(NativeTrainer::new(ModelKind::TinyTransformer, &meta).is_err());
    }

    #[test]
    fn native_trainer_is_shareable_across_workers() {
        // The round engine's parallel per-client phase requires the native
        // backend to be Sync and a ParallelTrainer; regressing this (e.g.
        // by adding interior-mutable caches) must fail loudly.
        fn assert_sync<T: Sync>() {}
        fn assert_parallel<T: crate::coordinator::trainer::ParallelTrainer>() {}
        assert_sync::<NativeTrainer>();
        assert_parallel::<NativeTrainer>();
    }
}
