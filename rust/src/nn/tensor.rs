//! Dense NHWC tensor for the native trainer.

/// Flat f32 tensor with explicit dims (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions (e.g. `[B, H, W, C]` or `[B, F]`).
    pub dims: Vec<usize>,
}

impl Tensor {
    /// Wrap data + dims (shape-checked).
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Self {
        assert_eq!(
            data.len(),
            dims.iter().product::<usize>(),
            "Tensor::new: data {} != dims {:?}",
            data.len(),
            dims
        );
        Tensor { data, dims }
    }

    /// Zero tensor.
    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor { data: vec![0.0; n], dims }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, dims: Vec<usize>) -> Self {
        assert_eq!(self.numel(), dims.iter().product::<usize>());
        self.dims = dims;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.numel(), 4);
        let r = t.reshape(vec![4]);
        assert_eq!(r.dims, vec![4]);
    }

    #[test]
    #[should_panic]
    fn bad_shape() {
        let _ = Tensor::new(vec![1.0], vec![2]);
    }
}
