//! Model builders: assemble op stacks matching `python/compile/model.py`.

use crate::config::ModelKind;
use crate::model::meta::ModelMeta;

use super::ops::{AvgPool2, Conv, Dense, Flatten, GlobalMeanPool, Op, Padding, Relu, Residual};

fn conv_of(meta: &ModelMeta, name: &str, stride: usize, padding: Padding) -> Conv {
    let w = meta
        .index_of(&format!("{name}.kernel"))
        .unwrap_or_else(|| panic!("missing layer {name}.kernel"));
    let b = meta.index_of(&format!("{name}.bias")).unwrap();
    let s = &meta.layers[w].shape;
    Conv::new(w, b, (s[0], s[1], s[2], s[3]), stride, padding)
}

fn dense_of(meta: &ModelMeta, name: &str) -> Dense {
    let w = meta
        .index_of(&format!("{name}.kernel"))
        .unwrap_or_else(|| panic!("missing layer {name}.kernel"));
    let b = meta.index_of(&format!("{name}.bias")).unwrap();
    let s = &meta.layers[w].shape;
    Dense::new(w, b, (s[0], s[1]))
}

fn res_block(meta: &ModelMeta, name: &str) -> Residual {
    // y = relu(x + conv2(relu(conv1(x)))) — matches model.py's `block`.
    Residual::new(vec![
        Box::new(conv_of(meta, &format!("{name}.conv1"), 1, Padding::Same)),
        Box::new(Relu::new()),
        Box::new(conv_of(meta, &format!("{name}.conv2"), 1, Padding::Same)),
    ])
}

/// Build the native op stack for a model kind (vision models only).
pub fn build_model(kind: ModelKind, meta: &ModelMeta) -> Vec<Box<dyn Op>> {
    match kind {
        ModelKind::LeNet5 => vec![
            Box::new(conv_of(meta, "conv1", 1, Padding::Valid)),
            Box::new(Relu::new()),
            Box::new(AvgPool2::new()),
            Box::new(conv_of(meta, "conv2", 1, Padding::Valid)),
            Box::new(Relu::new()),
            Box::new(AvgPool2::new()),
            Box::new(Flatten::new()),
            Box::new(dense_of(meta, "fc1")),
            Box::new(Relu::new()),
            Box::new(dense_of(meta, "fc2")),
            Box::new(Relu::new()),
            Box::new(dense_of(meta, "classifier")),
        ],
        ModelKind::ResNetLite => vec![
            Box::new(conv_of(meta, "conv_in", 1, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(res_block(meta, "stage1.block0")),
            Box::new(res_block(meta, "stage1.block1")),
            Box::new(conv_of(meta, "down1", 2, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(res_block(meta, "stage2.block0")),
            Box::new(res_block(meta, "stage2.block1")),
            Box::new(conv_of(meta, "down2", 2, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(res_block(meta, "stage3.block0")),
            Box::new(res_block(meta, "stage3.block1")),
            Box::new(GlobalMeanPool::new()),
            Box::new(dense_of(meta, "classifier")),
        ],
        ModelKind::AlexNetLite => vec![
            Box::new(conv_of(meta, "conv1", 1, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(AvgPool2::new()),
            Box::new(conv_of(meta, "conv2", 1, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(AvgPool2::new()),
            Box::new(conv_of(meta, "conv3", 1, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(conv_of(meta, "conv4", 1, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(conv_of(meta, "conv5", 1, Padding::Same)),
            Box::new(Relu::new()),
            Box::new(AvgPool2::new()),
            Box::new(Flatten::new()),
            Box::new(dense_of(meta, "fc1")),
            Box::new(Relu::new()),
            Box::new(dense_of(meta, "fc2")),
            Box::new(Relu::new()),
            Box::new(dense_of(meta, "classifier")),
        ],
        ModelKind::TinyTransformer => {
            panic!("TinyTransformer has no native builder (XLA-only)")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::layer_table;
    use crate::model::params::ParamStore;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn forward_shape(kind: ModelKind, input: Vec<usize>, expect_classes: usize) {
        let meta = layer_table(kind);
        let params = ParamStore::init(&meta, &Pcg64::seeded(1));
        let mut rng = Pcg64::seeded(2);
        let n: usize = input.iter().product();
        let x = Tensor::new(rng.normal_vec(n), input);
        let mut model = build_model(kind, &meta);
        let mut h = x;
        for op in model.iter_mut() {
            h = op.forward(&params, h);
        }
        assert_eq!(h.dims, vec![2, expect_classes]);
        assert!(h.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lenet_shapes() {
        forward_shape(ModelKind::LeNet5, vec![2, 28, 28, 1], 10);
    }

    #[test]
    fn resnetlite_shapes() {
        forward_shape(ModelKind::ResNetLite, vec![2, 32, 32, 3], 10);
    }

    #[test]
    fn alexnetlite_shapes() {
        forward_shape(ModelKind::AlexNetLite, vec![2, 32, 32, 3], 100);
    }

    #[test]
    fn whole_model_gradient_check_lenet() {
        // End-to-end finite-difference check through convs, pools, dense.
        let meta = layer_table(ModelKind::LeNet5);
        let mut params = ParamStore::init(&meta, &Pcg64::seeded(3));
        let trainer =
            crate::nn::NativeTrainer::new(ModelKind::LeNet5, &meta).unwrap();
        let mut rng = Pcg64::seeded(4);
        let x = Tensor::new(rng.normal_vec(2 * 28 * 28), vec![2, 28, 28, 1]);
        let y = vec![3u32, 7];
        let (_, grads) = trainer.loss_and_grads(&params, x.clone(), &y);
        let eps = 1e-2f32;
        // Check a few coordinates in each kind of tensor.
        for (ti, ci) in [(0usize, 10usize), (2, 100), (4, 1000), (8, 40), (9, 3)] {
            let orig = params.tensor(ti)[ci];
            params.tensor_mut(ti)[ci] = orig + eps;
            let (lp, _) = trainer.loss_and_grads(&params, x.clone(), &y);
            params.tensor_mut(ti)[ci] = orig - eps;
            let (lm, _) = trainer.loss_and_grads(&params, x.clone(), &y);
            params.tensor_mut(ti)[ci] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grads.tensor(ti)[ci] as f64;
            assert!(
                (fd - an).abs() < 5e-3 + 0.05 * fd.abs().max(an.abs()),
                "tensor {ti}[{ci}]: fd {fd} vs an {an}"
            );
        }
    }
}
