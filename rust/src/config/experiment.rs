//! Typed experiment configuration + named presets.
//!
//! One [`ExperimentConfig`] fully determines a simulated FL run: dataset,
//! model, client population, data distribution, compressor, and evaluation
//! schedule. Configs round-trip through [`Json`] so experiment scripts and
//! results stay self-describing.

use super::json::Json;
use crate::linalg::BackendKind;
use crate::net::NetConfig;
use crate::sched::{AvailConfig, SchedConfig, SchedKind};

/// Which synthetic dataset family to train on (DESIGN.md §2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28×28×1, 10 classes — stands in for MNIST.
    SynthMnist,
    /// 32×32×3, 10 classes — stands in for CIFAR-10.
    SynthCifar10,
    /// 32×32×3, 100 classes — stands in for CIFAR-100.
    SynthCifar100,
    /// Synthetic token corpus for the transformer end-to-end example.
    TinyCorpus,
}

/// Model architecture (defined in `python/compile/model.py`; layer metadata
/// mirrored in `rust/src/model/meta.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Faithful LeNet-5 (paper Table II row 1).
    LeNet5,
    /// Residual CNN scaled for CPU (stands in for ResNet18).
    ResNetLite,
    /// Conv+FC stack with AlexNet's parameter skew (stands in for AlexNet).
    AlexNetLite,
    /// Decoder-only transformer LM for the e2e driver.
    TinyTransformer,
}

/// Client data distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DataDistribution {
    /// Uniform random split.
    Iid,
    /// Dirichlet(α) label-skew split (Hsu et al.); α=0.5 / 0.1 in the paper.
    Dirichlet(f64),
}

/// GradESTC hyperparameters (paper §III).
#[derive(Clone, Debug, PartialEq)]
pub struct GradEstcParams {
    /// Number of retained basis vectors `k`. When a per-layer table is not
    /// given, every compressed layer uses this k.
    pub k: usize,
    /// Dynamic-d slope α (paper: 1.3).
    pub alpha: f64,
    /// Dynamic-d intercept β (paper: 1).
    pub beta: f64,
    /// Fraction of model parameters that must live in compressed layers
    /// (layers are picked largest-first until the fraction is covered;
    /// paper compresses layers covering 92–99% of parameters).
    pub coverage: f64,
    /// Ablation switch: never update the basis after init (GradESTC-first).
    pub freeze_after_init: bool,
    /// Ablation switch: replace the full basis every round (GradESTC-all).
    pub replace_all: bool,
    /// Ablation switch: disable dynamic d, keep d = k (GradESTC-k).
    pub fixed_d: bool,
    /// Extension (paper future work): local error-feedback accumulation.
    pub error_feedback: bool,
}

impl Default for GradEstcParams {
    fn default() -> Self {
        GradEstcParams {
            k: 32,
            alpha: 1.3,
            beta: 1.0,
            coverage: 0.9,
            freeze_after_init: false,
            replace_all: false,
            fixed_d: false,
            error_feedback: false,
        }
    }
}

/// Which uplink compressor the clients run.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorKind {
    /// Uncompressed FedAvg baseline.
    None,
    /// Magnitude Top-k sparsification; `frac` = fraction of entries kept.
    TopK {
        /// Kept fraction of entries (paper uses k=10% / 20%).
        frac: f64,
    },
    /// FedPAQ-style stochastic uniform quantization to `bits` bits.
    FedPaq {
        /// Quantization bit width (paper: 8).
        bits: u8,
    },
    /// 1-bit SignSGD with per-tensor scale.
    SignSgd,
    /// SVDFed-style shared global basis with error-triggered refresh.
    SvdFed {
        /// Basis rank per layer.
        k: usize,
        /// Relative-error threshold triggering a basis re-fit (plays the
        /// role of the paper's γ).
        gamma: f64,
    },
    /// FedQClip-style clipped quantization.
    FedQClip {
        /// Quantization bit width.
        bits: u8,
        /// Clip multiplier on the update RMS norm.
        clip: f64,
    },
    /// The paper's method.
    GradEstc(GradEstcParams),
}

impl CompressorKind {
    /// Short stable name for logs/CSV.
    pub fn name(&self) -> &'static str {
        match self {
            CompressorKind::None => "fedavg",
            CompressorKind::TopK { .. } => "topk",
            CompressorKind::FedPaq { .. } => "fedpaq",
            CompressorKind::SignSgd => "signsgd",
            CompressorKind::SvdFed { .. } => "svdfed",
            CompressorKind::FedQClip { .. } => "fedqclip",
            CompressorKind::GradEstc(p) => {
                if p.freeze_after_init {
                    "gradestc-first"
                } else if p.replace_all {
                    "gradestc-all"
                } else if p.fixed_d {
                    "gradestc-k"
                } else {
                    "gradestc"
                }
            }
        }
    }
}

/// Virtual-lane knobs: lazy materialization, the LRU residency cap, and
/// the frozen legacy shard path (`"lanes"` JSON object, `--lanes` /
/// `--lane-cap` / `--legacy-shards` on the CLI).
#[derive(Clone, Debug, PartialEq)]
pub struct LaneConfig {
    /// Materialize a client lane (shard + RNG stream + compressor pair)
    /// only on first dispatch, derived purely from `(seed, cid)` — so a
    /// sampled-never client costs ~0 bytes. `false` materializes every
    /// lane in `Simulation::build` through the same per-client derivation
    /// (parallelized across `workers`); lazy and eager runs are
    /// bit-identical.
    pub lazy: bool,
    /// Upper bound on *resident* (materialized, not in-flight) lanes;
    /// least-recently-dispatched lanes beyond the cap are evicted and
    /// re-materialized on demand from `(seed, cid)`. `0` = unbounded.
    /// Requires `lazy`. In-flight lanes are pinned and never evicted, so
    /// the bound is enforced net of pins.
    pub max_resident: usize,
    /// Frozen reference: generate shards with the pre-plan sequential
    /// root-RNG walk (one global pixel walk + index partition). Eager
    /// only — incompatible with `lazy`/`max_resident`. Kept so the old
    /// keying stays runnable for regression archaeology.
    pub legacy_shards: bool,
}

impl Default for LaneConfig {
    fn default() -> Self {
        LaneConfig { lazy: true, max_resident: 0, legacy_shards: false }
    }
}

impl LaneConfig {
    /// Range-check the knobs; returns a description of the first problem.
    /// Called by `Simulation::build` so bad CLI/JSON values surface as
    /// config errors, not panics.
    pub fn validate(&self) -> Result<(), String> {
        if self.legacy_shards && self.lazy {
            return Err("lanes.legacy_shards requires eager lanes (lanes.lazy = false)".into());
        }
        if self.max_resident > 0 && !self.lazy {
            return Err(
                "lanes.max_resident requires lanes.lazy (eviction re-materializes lazily)".into(),
            );
        }
        Ok(())
    }
}

/// Complete specification of one simulated FL experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Experiment id used in result paths.
    pub name: String,
    /// Dataset family.
    pub dataset: DatasetKind,
    /// Model architecture.
    pub model: ModelKind,
    /// Client data split.
    pub distribution: DataDistribution,
    /// Total number of clients (paper: 10 / 50).
    pub num_clients: usize,
    /// Fraction of clients sampled per round (paper: 1.0 / 0.2).
    pub participation: f64,
    /// Global rounds.
    pub rounds: usize,
    /// Local epochs per round (paper: 1 / 3 / 5 / 7).
    pub local_epochs: usize,
    /// Minibatch size.
    pub batch_size: usize,
    /// SGD learning rate (paper: 0.01).
    pub lr: f32,
    /// Training samples per client.
    pub samples_per_client: usize,
    /// Held-out test samples (server-side evaluation).
    pub test_samples: usize,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    /// Accuracy threshold for the "uplink at threshold" metric, as a
    /// fraction of the run's best accuracy (paper uses a fixed near-
    /// convergence level; 0.95·best is the scaled analog and is also
    /// reported at explicit levels by the harness).
    pub threshold_frac: f64,
    /// Uplink compressor under test.
    pub compressor: CompressorKind,
    /// RNG seed for the entire run.
    pub seed: u64,
    /// Execute local training through XLA artifacts (requires
    /// `make artifacts`); otherwise the native Rust trainer is used.
    pub use_xla: bool,
    /// Artifacts directory (manifest + HLO text).
    pub artifacts_dir: String,
    /// Worker threads for the round engine's per-client phase and FedAvg
    /// reduction. `0` = auto (the `GRADESTC_WORKERS` environment variable,
    /// else available parallelism); `1` = fully sequential. Results are
    /// bit-identical for every value.
    pub workers: usize,
    /// Simulated network: per-client link profiles (heterogeneous when
    /// `het_spread > 0`), client-dropout rate, and straggler deadline. The
    /// default is byte-identical to the pre-transport accounting.
    pub net: NetConfig,
    /// Round scheduler ([`crate::sched`]): sync (lockstep, the default —
    /// bit-identical to the pre-scheduler engine), semi-sync (deadline +
    /// straggler rollover), or async buffered (`k` arrivals per apply,
    /// staleness-discounted), plus the per-dispatch compute-time draw.
    pub sched: SchedConfig,
    /// Compute backend for the linalg hot path ([`crate::linalg`]):
    /// `Auto` (blocked unless `GRADESTC_BACKEND` overrides), `Scalar`
    /// (the frozen reference loops), or `Blocked` (cache-blocked,
    /// SIMD-friendly kernels). Results are bit-identical at any worker
    /// count for every choice; scalar vs blocked differ within ≤1e-5
    /// relative on reassociated reductions.
    pub backend: BackendKind,
    /// Virtual client lanes ([`crate::coordinator::lanes`]): lazy
    /// `(seed, cid)`-derived materialization (the default), the LRU
    /// residency cap for 10⁶-client populations, and the frozen legacy
    /// shard walk. Lazy and eager runs are bit-identical.
    pub lanes: LaneConfig,
}

impl ExperimentConfig {
    /// Small, fast preset used by `examples/quickstart.rs` and tests.
    pub fn preset_quickstart() -> Self {
        ExperimentConfig {
            name: "quickstart".into(),
            dataset: DatasetKind::SynthMnist,
            model: ModelKind::LeNet5,
            distribution: DataDistribution::Iid,
            num_clients: 4,
            participation: 1.0,
            rounds: 8,
            local_epochs: 1,
            batch_size: 32,
            lr: 0.05,
            samples_per_client: 128,
            test_samples: 256,
            eval_every: 1,
            threshold_frac: 0.95,
            compressor: CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
            seed: 7,
            use_xla: false,
            artifacts_dir: "artifacts".into(),
            workers: 1,
            net: NetConfig::default(),
            sched: SchedConfig::default(),
            backend: BackendKind::Auto,
            lanes: LaneConfig::default(),
        }
    }

    /// Paper Table III cell: `dataset × distribution × method`, scaled.
    pub fn preset_table3(
        dataset: DatasetKind,
        distribution: DataDistribution,
        compressor: CompressorKind,
        rounds: usize,
        seed: u64,
    ) -> Self {
        let (model, samples, batch) = match dataset {
            DatasetKind::SynthMnist => (ModelKind::LeNet5, 512, 32),
            DatasetKind::SynthCifar10 => (ModelKind::ResNetLite, 384, 32),
            DatasetKind::SynthCifar100 => (ModelKind::AlexNetLite, 384, 32),
            DatasetKind::TinyCorpus => (ModelKind::TinyTransformer, 256, 16),
        };
        let dist_tag = match distribution {
            DataDistribution::Iid => "iid".to_string(),
            DataDistribution::Dirichlet(a) => format!("dir{a}"),
        };
        ExperimentConfig {
            name: format!("table3-{:?}-{}-{}", dataset, dist_tag, compressor.name()),
            dataset,
            model,
            distribution,
            num_clients: 10,
            participation: 1.0,
            rounds,
            local_epochs: 1,
            batch_size: batch,
            lr: 0.03,
            samples_per_client: samples,
            test_samples: 512,
            eval_every: 1,
            threshold_frac: 0.95,
            compressor,
            seed,
            use_xla: false,
            artifacts_dir: "artifacts".into(),
            workers: 1,
            net: NetConfig::default(),
            sched: SchedConfig::default(),
            backend: BackendKind::Auto,
            lanes: LaneConfig::default(),
        }
    }

    /// The effective worker count: `workers`, or the process-wide default
    /// ([`crate::util::pool::default_workers`]: `GRADESTC_WORKERS`, else
    /// available parallelism) when set to `0`.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::pool::default_workers()
        } else {
            self.workers
        }
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        let dist = match self.distribution {
            DataDistribution::Iid => Json::str("iid"),
            DataDistribution::Dirichlet(a) => {
                Json::obj(vec![("dirichlet", Json::num(a))])
            }
        };
        let comp = match &self.compressor {
            CompressorKind::None => Json::str("fedavg"),
            CompressorKind::TopK { frac } => {
                Json::obj(vec![("topk", Json::obj(vec![("frac", Json::num(*frac))]))])
            }
            CompressorKind::FedPaq { bits } => {
                Json::obj(vec![("fedpaq", Json::obj(vec![("bits", Json::num(*bits as f64))]))])
            }
            CompressorKind::SignSgd => Json::str("signsgd"),
            CompressorKind::SvdFed { k, gamma } => Json::obj(vec![(
                "svdfed",
                Json::obj(vec![("k", Json::num(*k as f64)), ("gamma", Json::num(*gamma))]),
            )]),
            CompressorKind::FedQClip { bits, clip } => Json::obj(vec![(
                "fedqclip",
                Json::obj(vec![("bits", Json::num(*bits as f64)), ("clip", Json::num(*clip))]),
            )]),
            CompressorKind::GradEstc(p) => Json::obj(vec![(
                "gradestc",
                Json::obj(vec![
                    ("k", Json::num(p.k as f64)),
                    ("alpha", Json::num(p.alpha)),
                    ("beta", Json::num(p.beta)),
                    ("coverage", Json::num(p.coverage)),
                    ("freeze_after_init", Json::Bool(p.freeze_after_init)),
                    ("replace_all", Json::Bool(p.replace_all)),
                    ("fixed_d", Json::Bool(p.fixed_d)),
                    ("error_feedback", Json::Bool(p.error_feedback)),
                ]),
            )]),
        };
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("dataset", Json::str(dataset_name(self.dataset))),
            ("model", Json::str(model_name(self.model))),
            ("distribution", dist),
            ("num_clients", Json::num(self.num_clients as f64)),
            ("participation", Json::num(self.participation)),
            ("rounds", Json::num(self.rounds as f64)),
            ("local_epochs", Json::num(self.local_epochs as f64)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("samples_per_client", Json::num(self.samples_per_client as f64)),
            ("test_samples", Json::num(self.test_samples as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("threshold_frac", Json::num(self.threshold_frac)),
            ("compressor", comp),
            ("seed", Json::num(self.seed as f64)),
            ("use_xla", Json::Bool(self.use_xla)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
            ("workers", Json::num(self.workers as f64)),
            ("net", net_to_json(&self.net)),
            ("sched", sched_to_json(&self.sched)),
            ("backend", Json::str(self.backend.name())),
            ("lanes", lanes_to_json(&self.lanes)),
        ])
    }

    /// Parse from JSON (inverse of [`ExperimentConfig::to_json`]).
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let dataset = parse_dataset(j.req("dataset")?.as_str().ok_or("dataset must be str")?)?;
        let model = parse_model(j.req("model")?.as_str().ok_or("model must be str")?)?;
        let distribution = match j.req("distribution")? {
            Json::Str(s) if s == "iid" => DataDistribution::Iid,
            v => {
                let a = v
                    .get("dirichlet")
                    .and_then(|x| x.as_f64())
                    .ok_or("bad distribution")?;
                DataDistribution::Dirichlet(a)
            }
        };
        let compressor = parse_compressor(j.req("compressor")?)?;
        Ok(ExperimentConfig {
            name: j.req("name")?.as_str().ok_or("name")?.to_string(),
            dataset,
            model,
            distribution,
            num_clients: j.req("num_clients")?.as_usize().ok_or("num_clients")?,
            participation: j.req("participation")?.as_f64().ok_or("participation")?,
            rounds: j.req("rounds")?.as_usize().ok_or("rounds")?,
            local_epochs: j.req("local_epochs")?.as_usize().ok_or("local_epochs")?,
            batch_size: j.req("batch_size")?.as_usize().ok_or("batch_size")?,
            lr: j.req("lr")?.as_f64().ok_or("lr")? as f32,
            samples_per_client: j.req("samples_per_client")?.as_usize().ok_or("spc")?,
            test_samples: j.req("test_samples")?.as_usize().ok_or("test_samples")?,
            eval_every: j.req("eval_every")?.as_usize().ok_or("eval_every")?,
            threshold_frac: j.req("threshold_frac")?.as_f64().ok_or("threshold_frac")?,
            compressor,
            seed: j.req("seed")?.as_f64().ok_or("seed")? as u64,
            use_xla: j.req("use_xla")?.as_bool().ok_or("use_xla")?,
            artifacts_dir: j.req("artifacts_dir")?.as_str().ok_or("artifacts_dir")?.to_string(),
            // Optional for backward compatibility with pre-engine configs:
            // absent means sequential, the old behaviour.
            workers: j.get("workers").and_then(|v| v.as_usize()).unwrap_or(1),
            // Optional for backward compatibility with pre-transport
            // configs: absent means the ideal-network default.
            net: j.get("net").map(parse_net).transpose()?.unwrap_or_default(),
            // Optional for backward compatibility with pre-scheduler
            // configs: absent means the synchronous lockstep default.
            sched: j.get("sched").map(parse_sched).transpose()?.unwrap_or_default(),
            // Optional for backward compatibility with pre-backend
            // configs: absent means Auto (blocked unless the
            // GRADESTC_BACKEND environment variable overrides).
            backend: j
                .get("backend")
                .map(|v| {
                    BackendKind::parse(v.as_str().ok_or("backend must be a string")?)
                })
                .transpose()?
                .unwrap_or_default(),
            // Optional for backward compatibility with pre-virtual-lane
            // configs: absent means the lazy default (bit-identical to
            // the eager build, so old configs replay unchanged).
            lanes: j.get("lanes").map(parse_lanes).transpose()?.unwrap_or_default(),
        })
    }
}

fn net_to_json(n: &NetConfig) -> Json {
    Json::obj(vec![
        ("uplink_mbps", Json::num(n.uplink_mbps)),
        ("downlink_mbps", Json::num(n.downlink_mbps)),
        ("latency_ms", Json::num(n.latency_ms)),
        ("het_spread", Json::num(n.het_spread)),
        ("dropout", Json::num(n.dropout)),
        ("deadline_s", Json::num(n.deadline_s)),
    ])
}

fn sched_to_json(s: &SchedConfig) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("kind", Json::str(s.kind.name()))];
    if let SchedKind::Async { k, staleness_p } = s.kind {
        fields.push(("k", Json::num(k as f64)));
        fields.push(("staleness", Json::num(staleness_p)));
    }
    fields.push(("compute_base_s", Json::num(s.compute_base_s)));
    fields.push(("compute_spread", Json::num(s.compute_spread)));
    fields.push((
        "avail",
        Json::obj(vec![
            ("duty", Json::num(s.avail.duty)),
            ("period_s", Json::num(s.avail.period_s)),
            ("churn_per_s", Json::num(s.avail.churn_per_s)),
            ("outage_s", Json::num(s.avail.outage_s)),
        ]),
    ));
    fields.push(("concurrency", Json::num(s.concurrency as f64)));
    fields.push(("adaptive_k", Json::Bool(s.adaptive_k)));
    fields.push(("lr_tau", Json::num(s.lr_tau)));
    Json::obj(fields)
}

fn parse_avail(j: &Json) -> Result<AvailConfig, String> {
    let d = AvailConfig::default();
    let f = |key: &str, dv: f64| -> Result<f64, String> {
        match j.get(key) {
            Some(v) => v.as_f64().ok_or_else(|| format!("sched.avail.{key} must be a number")),
            None => Ok(dv),
        }
    };
    Ok(AvailConfig {
        duty: f("duty", d.duty)?,
        period_s: f("period_s", d.period_s)?,
        churn_per_s: f("churn_per_s", d.churn_per_s)?,
        outage_s: f("outage_s", d.outage_s)?,
    })
}

fn parse_sched(j: &Json) -> Result<SchedConfig, String> {
    let d = SchedConfig::default();
    let f = |key: &str, dv: f64| -> Result<f64, String> {
        match j.get(key) {
            Some(v) => v.as_f64().ok_or_else(|| format!("sched.{key} must be a number")),
            None => Ok(dv),
        }
    };
    let kind = match j.get("kind") {
        None => SchedKind::Sync,
        Some(v) => match v.as_str().ok_or("sched.kind must be a string")? {
            "sync" => SchedKind::Sync,
            "semisync" => SchedKind::SemiSync,
            "async" => SchedKind::Async {
                k: j.get("k")
                    .map(|v| v.as_usize().ok_or("sched.k must be a positive integer"))
                    .transpose()?
                    .unwrap_or(crate::sched::DEFAULT_ASYNC_K),
                staleness_p: f("staleness", crate::sched::DEFAULT_STALENESS_P)?,
            },
            other => return Err(format!("unknown sched.kind '{other}'")),
        },
    };
    Ok(SchedConfig {
        kind,
        compute_base_s: f("compute_base_s", d.compute_base_s)?,
        compute_spread: f("compute_spread", d.compute_spread)?,
        // Optional for backward compatibility with pre-plane-10 configs:
        // absent means always-on, concurrency 1, adaptive features off.
        avail: j.get("avail").map(parse_avail).transpose()?.unwrap_or_default(),
        concurrency: j
            .get("concurrency")
            .map(|v| v.as_usize().ok_or("sched.concurrency must be a positive integer"))
            .transpose()?
            .unwrap_or(d.concurrency),
        adaptive_k: j
            .get("adaptive_k")
            .map(|v| v.as_bool().ok_or("sched.adaptive_k must be a bool"))
            .transpose()?
            .unwrap_or(d.adaptive_k),
        lr_tau: f("lr_tau", d.lr_tau)?,
    })
}

fn lanes_to_json(l: &LaneConfig) -> Json {
    Json::obj(vec![
        ("lazy", Json::Bool(l.lazy)),
        ("max_resident", Json::num(l.max_resident as f64)),
        ("legacy_shards", Json::Bool(l.legacy_shards)),
    ])
}

fn parse_lanes(j: &Json) -> Result<LaneConfig, String> {
    let d = LaneConfig::default();
    let b = |key: &str, dv: bool| -> Result<bool, String> {
        match j.get(key) {
            Some(v) => v.as_bool().ok_or_else(|| format!("lanes.{key} must be a bool")),
            None => Ok(dv),
        }
    };
    Ok(LaneConfig {
        lazy: b("lazy", d.lazy)?,
        max_resident: match j.get("max_resident") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| "lanes.max_resident must be a non-negative integer".to_string())?,
            None => d.max_resident,
        },
        legacy_shards: b("legacy_shards", d.legacy_shards)?,
    })
}

fn parse_net(j: &Json) -> Result<NetConfig, String> {
    let d = NetConfig::default();
    let f = |key: &str, dv: f64| -> Result<f64, String> {
        match j.get(key) {
            Some(v) => v.as_f64().ok_or_else(|| format!("net.{key} must be a number")),
            None => Ok(dv),
        }
    };
    Ok(NetConfig {
        uplink_mbps: f("uplink_mbps", d.uplink_mbps)?,
        downlink_mbps: f("downlink_mbps", d.downlink_mbps)?,
        latency_ms: f("latency_ms", d.latency_ms)?,
        het_spread: f("het_spread", d.het_spread)?,
        dropout: f("dropout", d.dropout)?,
        deadline_s: f("deadline_s", d.deadline_s)?,
    })
}

/// Stable dataset name for configs/paths.
pub fn dataset_name(d: DatasetKind) -> &'static str {
    match d {
        DatasetKind::SynthMnist => "synth-mnist",
        DatasetKind::SynthCifar10 => "synth-cifar10",
        DatasetKind::SynthCifar100 => "synth-cifar100",
        DatasetKind::TinyCorpus => "tiny-corpus",
    }
}

/// Stable model name for configs/paths (must match `python/compile/model.py`).
pub fn model_name(m: ModelKind) -> &'static str {
    match m {
        ModelKind::LeNet5 => "lenet5",
        ModelKind::ResNetLite => "resnetlite",
        ModelKind::AlexNetLite => "alexnetlite",
        ModelKind::TinyTransformer => "tinytransformer",
    }
}

fn parse_dataset(s: &str) -> Result<DatasetKind, String> {
    Ok(match s {
        "synth-mnist" => DatasetKind::SynthMnist,
        "synth-cifar10" => DatasetKind::SynthCifar10,
        "synth-cifar100" => DatasetKind::SynthCifar100,
        "tiny-corpus" => DatasetKind::TinyCorpus,
        _ => return Err(format!("unknown dataset '{s}'")),
    })
}

fn parse_model(s: &str) -> Result<ModelKind, String> {
    Ok(match s {
        "lenet5" => ModelKind::LeNet5,
        "resnetlite" => ModelKind::ResNetLite,
        "alexnetlite" => ModelKind::AlexNetLite,
        "tinytransformer" => ModelKind::TinyTransformer,
        _ => return Err(format!("unknown model '{s}'")),
    })
}

fn parse_compressor(j: &Json) -> Result<CompressorKind, String> {
    match j {
        Json::Str(s) if s == "fedavg" => Ok(CompressorKind::None),
        Json::Str(s) if s == "signsgd" => Ok(CompressorKind::SignSgd),
        Json::Obj(_) => {
            if let Some(t) = j.get("topk") {
                Ok(CompressorKind::TopK { frac: t.req("frac")?.as_f64().ok_or("frac")? })
            } else if let Some(t) = j.get("fedpaq") {
                Ok(CompressorKind::FedPaq {
                    bits: t.req("bits")?.as_usize().ok_or("bits")? as u8,
                })
            } else if let Some(t) = j.get("svdfed") {
                Ok(CompressorKind::SvdFed {
                    k: t.req("k")?.as_usize().ok_or("k")?,
                    gamma: t.req("gamma")?.as_f64().ok_or("gamma")?,
                })
            } else if let Some(t) = j.get("fedqclip") {
                Ok(CompressorKind::FedQClip {
                    bits: t.req("bits")?.as_usize().ok_or("bits")? as u8,
                    clip: t.req("clip")?.as_f64().ok_or("clip")?,
                })
            } else if let Some(t) = j.get("gradestc") {
                Ok(CompressorKind::GradEstc(GradEstcParams {
                    k: t.req("k")?.as_usize().ok_or("k")?,
                    alpha: t.req("alpha")?.as_f64().ok_or("alpha")?,
                    beta: t.req("beta")?.as_f64().ok_or("beta")?,
                    coverage: t.req("coverage")?.as_f64().ok_or("coverage")?,
                    freeze_after_init: t.req("freeze_after_init")?.as_bool().ok_or("fai")?,
                    replace_all: t.req("replace_all")?.as_bool().ok_or("ra")?,
                    fixed_d: t.req("fixed_d")?.as_bool().ok_or("fd")?,
                    error_feedback: t.req("error_feedback")?.as_bool().ok_or("ef")?,
                }))
            } else {
                Err("unknown compressor object".into())
            }
        }
        _ => Err("bad compressor".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_all_compressors() {
        let comps = vec![
            CompressorKind::None,
            CompressorKind::TopK { frac: 0.1 },
            CompressorKind::FedPaq { bits: 8 },
            CompressorKind::SignSgd,
            CompressorKind::SvdFed { k: 16, gamma: 0.3 },
            CompressorKind::FedQClip { bits: 8, clip: 2.0 },
            CompressorKind::GradEstc(GradEstcParams::default()),
        ];
        for c in comps {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.compressor = c;
            let j = cfg.to_json();
            let back = ExperimentConfig::from_json(&j).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn json_roundtrip_distributions() {
        for d in [DataDistribution::Iid, DataDistribution::Dirichlet(0.1)] {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.distribution = d;
            let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn table3_preset_matches_paper_shape() {
        let cfg = ExperimentConfig::preset_table3(
            DatasetKind::SynthCifar10,
            DataDistribution::Dirichlet(0.5),
            CompressorKind::None,
            30,
            1,
        );
        assert_eq!(cfg.num_clients, 10); // paper §V-A: 10 clients
        assert_eq!(cfg.participation, 1.0); // all participate
        assert_eq!(cfg.local_epochs, 1); // one local epoch
        assert_eq!(cfg.model, ModelKind::ResNetLite);
    }

    #[test]
    fn compressor_names_stable() {
        assert_eq!(CompressorKind::None.name(), "fedavg");
        let mut p = GradEstcParams::default();
        assert_eq!(CompressorKind::GradEstc(p.clone()).name(), "gradestc");
        p.fixed_d = true;
        assert_eq!(CompressorKind::GradEstc(p.clone()).name(), "gradestc-k");
        p.fixed_d = false;
        p.replace_all = true;
        assert_eq!(CompressorKind::GradEstc(p.clone()).name(), "gradestc-all");
        p.replace_all = false;
        p.freeze_after_init = true;
        assert_eq!(CompressorKind::GradEstc(p).name(), "gradestc-first");
    }

    #[test]
    fn workers_roundtrips_and_defaults() {
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.workers = 8;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.workers, 8);

        // Pre-engine configs (no "workers" field) parse as sequential.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("workers");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.workers, 1);
    }

    #[test]
    fn net_roundtrips_and_defaults() {
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.net = NetConfig {
            uplink_mbps: 2.5,
            downlink_mbps: 20.0,
            latency_ms: 80.0,
            het_spread: 0.4,
            dropout: 0.15,
            deadline_s: 12.0,
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // Pre-transport configs (no "net" field) parse as the ideal
        // default network.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("net");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.net, NetConfig::default());

        // A partial net object fills the rest from the default.
        if let Json::Obj(m) = &mut j {
            m.insert("net".into(), Json::obj(vec![("dropout", Json::num(0.3))]));
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.net.dropout, 0.3);
        assert_eq!(back.net.uplink_mbps, NetConfig::default().uplink_mbps);
    }

    #[test]
    fn lanes_roundtrips_and_defaults() {
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.lanes = LaneConfig { lazy: true, max_resident: 128, legacy_shards: false };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        cfg.lanes = LaneConfig { lazy: false, max_resident: 0, legacy_shards: true };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // Pre-virtual-lane configs (no "lanes" field) parse as the default
        // lazy/unbounded lane plan.
        let mut j = ExperimentConfig::preset_quickstart().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("lanes");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.lanes, LaneConfig::default());

        // A partial lanes object fills the rest from the default.
        if let Json::Obj(m) = &mut j {
            m.insert(
                "lanes".into(),
                Json::obj(vec![("max_resident", Json::num(64.0))]),
            );
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.lanes.max_resident, 64);
        assert_eq!(back.lanes.lazy, LaneConfig::default().lazy);
        assert_eq!(back.lanes.legacy_shards, LaneConfig::default().legacy_shards);

        // Invalid combinations are rejected by validate().
        assert!(LaneConfig::default().validate().is_ok());
        let bad = LaneConfig { lazy: true, max_resident: 0, legacy_shards: true };
        assert!(bad.validate().is_err());
        let bad = LaneConfig { lazy: false, max_resident: 8, legacy_shards: false };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn sched_roundtrips_and_defaults() {
        for kind in [
            SchedKind::Sync,
            SchedKind::SemiSync,
            SchedKind::Async { k: 4, staleness_p: 1.0 },
        ] {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.sched = SchedConfig {
                kind,
                compute_base_s: 0.5,
                compute_spread: 0.3,
                ..Default::default()
            };
            let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg);
        }

        // The plane-10 knobs round-trip too (availability, concurrency,
        // adaptive server).
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.sched = SchedConfig {
            kind: SchedKind::Async { k: 4, staleness_p: 0.5 },
            avail: AvailConfig { duty: 0.6, period_s: 12.0, churn_per_s: 0.02, outage_s: 3.0 },
            concurrency: 2,
            adaptive_k: true,
            lr_tau: 0.5,
            ..Default::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // Pre-scheduler configs (no "sched" field) parse as lockstep sync.
        let mut j = ExperimentConfig::preset_quickstart().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("sched");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.sched, SchedConfig::default());

        // A partial sched object fills the rest from the default.
        if let Json::Obj(m) = &mut j {
            m.insert("sched".into(), Json::obj(vec![("kind", Json::str("async"))]));
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(
            back.sched.kind,
            SchedKind::Async {
                k: crate::sched::DEFAULT_ASYNC_K,
                staleness_p: crate::sched::DEFAULT_STALENESS_P
            }
        );
        assert_eq!(back.sched.compute_base_s, 0.0);

        // Garbage kinds are rejected.
        if let Json::Obj(m) = &mut j {
            m.insert("sched".into(), Json::obj(vec![("kind", Json::str("warp"))]));
        }
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn backend_roundtrips_and_defaults() {
        for b in [BackendKind::Auto, BackendKind::Scalar, BackendKind::Blocked] {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.backend = b;
            let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg);
        }

        // Pre-backend configs (no "backend" field) parse as Auto.
        let mut j = ExperimentConfig::preset_quickstart().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("backend");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.backend, BackendKind::Auto);

        // Garbage backends are rejected.
        if let Json::Obj(m) = &mut j {
            m.insert("backend".into(), Json::str("abacus"));
        }
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn resolved_workers_auto_and_explicit() {
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.workers = 3;
        assert_eq!(cfg.resolved_workers(), 3);
        cfg.workers = 0;
        assert!(cfg.resolved_workers() >= 1); // auto: env / hardware
    }

    #[test]
    fn bad_json_rejected() {
        assert!(ExperimentConfig::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = ExperimentConfig::preset_quickstart().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("dataset".into(), Json::str("nope"));
        }
        assert!(ExperimentConfig::from_json(&j).is_err());
    }
}
