//! Strict JSON parser and writer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as `f64`; the artifact
//! manifests and experiment configs this crate deals in never need 64-bit
//! integer exactness beyond 2^53.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (order-stable via BTreeMap).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document. Trailing non-whitespace is an error.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)] // Display would imply parse/format symmetry we don't want
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    /// Field of an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required object field, with a path-aware error.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            // Surrogate pairs are rare in our configs; map
                            // lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{txt}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"d": "x\n\"y\""}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "b": false, "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.req("zzz").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(Json::parse("-3.25").unwrap().as_f64(), Some(-3.25));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("2.5E-2").unwrap().as_f64(), Some(0.025));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""line\nbreak A""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nbreak A"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }

    #[test]
    fn pretty_print_stable() {
        let v = Json::obj(vec![("b", Json::num(2.0)), ("a", Json::num(1.0))]);
        let p = v.to_pretty();
        assert!(p.contains("\"a\": 1"));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_decimal() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.5).to_string(), "5.5");
    }
}
