//! Configuration system.
//!
//! * [`json`] — a strict JSON parser/serializer (offline stand-in for
//!   `serde_json`) used for experiment configs, artifact manifests and
//!   result files.
//! * [`experiment`] — the typed experiment configuration schema plus named
//!   presets mirroring every experiment in the paper (Table II/III setups,
//!   Fig. 7/8/9 variants, Table IV ablations).

pub mod experiment;
pub mod json;

pub use experiment::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams, LaneConfig,
    ModelKind,
};
pub use json::Json;
// The network knobs live with the net subsystem, the scheduler knobs with
// the sched plane, and the compute-backend selector with linalg;
// re-exported here because they are part of the experiment schema.
pub use crate::linalg::BackendKind;
pub use crate::net::NetConfig;
pub use crate::sched::{AvailConfig, SchedConfig, SchedKind};
