//! Model substrate: architecture metadata, parameter storage, and the
//! WHDC flatten/segment transform the compressor operates on.
//!
//! The source of truth for each architecture lives here ([`meta`]) and is
//! mirrored by `python/compile/model.py`; `make artifacts` emits a manifest
//! and the integration tests assert both sides agree layer-by-layer.

pub mod meta;
pub mod params;
pub mod reshape;

pub use meta::{layer_table, LayerMeta, LayerRole, ModelMeta};
pub use params::ParamStore;
pub use reshape::{segment_matrix, unsegment_matrix};
